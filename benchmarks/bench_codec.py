"""B6 -- checkpoint codec (beyond-paper, TPU-native): blockwise int8
quantization + XOR delta on the commit path.  Measures encode throughput and
the bytes that actually cross the agent fabric, using two *real* adjacent
training checkpoints (one optimizer step apart) so the delta structure is
representative.
"""
from __future__ import annotations

import time
import zlib

import jax
import numpy as np

from repro.configs import get_config
from repro.kernels.ckpt_codec import quantize, quantize_delta
from repro.optim import AdamWConfig
from repro.train import make_train_state, make_train_step

from .common import fmt_bytes, save


def _flat_params(state) -> np.ndarray:
    leaves = [np.asarray(x, np.float32).ravel()
              for x in jax.tree.leaves(state.params)]
    return np.concatenate(leaves)


def _z(b: bytes) -> int:
    return len(zlib.compress(b, 1))


def run(verbose: bool = True) -> dict:
    cfg = get_config("qwen2.5-3b", tiny=True)
    state = make_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-4)))
    batch = {"tokens": jax.numpy.zeros((4, 64), jax.numpy.int32),
             "labels": jax.numpy.zeros((4, 64), jax.numpy.int32)}
    state1, _ = step(state, batch)

    x0 = _flat_params(state)
    x1 = _flat_params(state1)
    raw = x1.nbytes

    # throughput (XLA path on CPU; the Pallas kernel is the TPU path)
    q0, s0 = map(np.asarray, quantize(x0, impl="xla"))
    t0 = time.monotonic()
    for _ in range(5):
        q1, s1 = quantize(x1, impl="xla")
        jax.block_until_ready(q1)
    enc_tp = 5 * raw / (time.monotonic() - t0)
    q1, s1 = map(np.asarray, (q1, s1))
    t0 = time.monotonic()
    d, sd, qd = quantize_delta(x1, q0, impl="xla")
    jax.block_until_ready(d)
    d = np.asarray(d)

    sizes = {
        "raw_f32": raw,
        "zlib(raw_f32)": _z(x1.tobytes()),
        "int8+scales": q1.nbytes + s1.nbytes,
        "zlib(int8)": _z(q1.tobytes()) + s1.nbytes,
        "zlib(xor_delta_int8)": _z(d.tobytes()) + np.asarray(sd).nbytes,
    }
    out = {"bytes": sizes, "encode_Bps": enc_tp,
           "ratio_int8": raw / sizes["int8+scales"],
           "ratio_delta": raw / sizes["zlib(xor_delta_int8)"]}
    save("b6_codec", out)
    if verbose:
        print(f"\nB6 checkpoint codec ({fmt_bytes(raw)} param snapshot, "
              f"encode {fmt_bytes(enc_tp)}/s on CPU-XLA):")
        for k, v in sizes.items():
            print(f"  {k:22s}: {fmt_bytes(v)}  ({raw / v:.2f}x)")
    return out


if __name__ == "__main__":
    run()
