"""B2 -- application-perceived commit cost: iCheck non-blocking commit vs a
blocking PFS write (paper SSII: "the application does not need to block for
data transfer [but] can continue the execution immediately").

The async path costs the app only the host-side snapshot serialization; the
RDMA drain to agents and the L1->PFS writeback happen behind its back.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import block_parts, fmt_bytes, save

PAYLOAD = 128 << 20
PARTS = 16
PFS_BW = 10e9
NIC_BW = 25e9
STEPS = 5


def run(verbose: bool = True, payload: int = PAYLOAD, parts_n: int = PARTS,
        steps: int = STEPS, nodes: int = 4) -> dict:
    data = np.random.default_rng(0).standard_normal(
        payload // 4).astype(np.float32)
    parts = block_parts(data, parts_n)

    with ICheckCluster(n_icheck_nodes=nodes, node_memory=8 << 30,
                       nic_bandwidth=NIC_BW, pfs_bandwidth=PFS_BW) as c:
        client = ICheckClient("app", c.controller, ranks=parts_n).init(
            ckpt_bytes_estimate=payload)
        client.add_adapt("x", data.shape, "float32", num_parts=parts_n)

        async_block_wall = []
        async_total_sim = []
        for step in range(steps):
            t0 = time.monotonic()
            sim0 = c.clock.now()
            h = client.commit(step, {"x": parts})   # returns immediately
            app_sim_stall = c.clock.now() - sim0    # sim time the app lost
            async_block_wall.append(
                (time.monotonic() - t0, app_sim_stall))
            h.wait(timeout=120)
            async_total_sim.append(h.sim_duration)
        client.finalize()
        c.controller.wait_for_drains(timeout=60)

    # blocking baseline: the app stalls for the fabric transfer AND the
    # PFS write before resuming (no agents, no overlap)
    blocking_sim = payload / NIC_BW + payload / PFS_BW

    wall = float(np.mean([w for w, _ in async_block_wall]))
    sim_stall = float(np.mean([s for _, s in async_block_wall]))
    out = {
        "payload": payload,
        "async_app_stall_sim_s": sim_stall,
        "async_host_serialize_wall_s": wall,
        "async_transfer_sim_s_hidden": float(np.mean(async_total_sim)),
        "blocking_app_stall_sim_s": blocking_sim,
        "hidden_fraction": 1.0 - sim_stall / blocking_sim,
    }
    save("b2_async_overlap", out)
    if verbose:
        print(f"\nB2 app-perceived commit cost ({fmt_bytes(payload)}):")
        print(f"  blocking (NIC+PFS in the app's critical path): "
              f"{blocking_sim:.3f} s stall per checkpoint")
        print(f"  iCheck async commit: {sim_stall:.4f} s fabric stall "
              f"({out['async_transfer_sim_s_hidden']:.3f} s of transfer "
              f"hidden behind compute; host-side snapshot serialize "
              f"{wall*1e3:.0f} ms wall, overlappable via D2H async copy)")
    return out


def run_smoke(verbose: bool = True) -> dict:
    """Seconds-scale perf canary for CI: tiny payload, two steps."""
    return run(verbose=verbose, payload=4 << 20, parts_n=4, steps=2, nodes=2)


if __name__ == "__main__":
    run()
