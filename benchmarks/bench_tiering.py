"""B9 -- storage lifecycle tiering: watermark demotion vs reactive RM
escalation, and the L3 (remote object store) cold-restart path.

Two experiments:

  * **capacity pressure** (paper §III-A interaction 1): the same commit
    workload runs against (a) the reactive baseline — L1 only, a full node
    raises ``CapacityError`` mid-commit and the controller escalates to the
    RM for more nodes — and (b) the lifecycle subsystem — a node-local
    spill tier plus watermark-driven demotion that moves cold shards down
    *before* commits hit the wall.  The lifecycle leg must finish with
    **zero** RM escalations (and a single node) where the reactive leg
    pays for extra nodes and straggler-retried commits.

  * **L3 cold restart**: after a checkpoint trickles L2→L3, L1 and the PFS
    copies are dropped; the restart must be served from the object store
    (request-latency bound), and promote-on-read must repopulate the PFS so
    the *next* restart runs at PFS speed again.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import block_parts, fmt_bytes, save

# capacity-pressure experiment
PRESSURE_NODE_MEM = 8 << 20
PRESSURE_PAYLOAD = 5 << 20
PRESSURE_COMMITS = 6
PRESSURE_PARTS = 4

# L3 restart experiment
RESTART_PAYLOAD = 32 << 20
RESTART_PARTS = 8
PFS_BW = 10e9
L3_BW = 2e9
L3_LATENCY = 0.03

ESCALATION_EVENTS = ("capacity_grow", "node_request_denied")


def _pressure_leg(lifecycle: bool, payload: int, n_commits: int,
                  node_mem: int) -> dict:
    """One leg of the capacity-pressure comparison; only the storage
    lifecycle config differs (spill tier + watermarks vs bare L1)."""
    data = np.arange(payload // 4, dtype=np.float32)
    kwargs = dict(spill_bytes=16 * payload, watermark_high=0.5,
                  watermark_low=0.2) if lifecycle else {}
    with ICheckCluster(n_icheck_nodes=1, n_spare_nodes=2,
                       node_memory=node_mem, keep_l1=1,
                       adaptive_interval=False, **kwargs) as c:
        client = ICheckClient("app", c.controller,
                              ranks=PRESSURE_PARTS).init(
            ckpt_bytes_estimate=payload)
        client.add_adapt("x", data.shape, "float32",
                         num_parts=PRESSURE_PARTS)
        commit_sim_s = 0.0
        retries = 0
        for step in range(n_commits):
            h = client.commit(step, {"x": block_parts(data + step,
                                                      PRESSURE_PARTS)},
                              blocking=True)
            commit_sim_s += h.sim_duration
            retries += h.retries
            c.controller.wait_for_drains(timeout=60)
        events = [e["event"] for e in c.controller.events]
        meta, parts, level = client.restart()
        got = np.concatenate([parts["x"][i] for i in range(PRESSURE_PARTS)])
        np.testing.assert_array_equal(got, data + meta.step)
        life = c.telemetry.snapshot()["lifecycle"]
        client.finalize()
        return {
            "escalations": sum(events.count(n) for n in ESCALATION_EVENTS),
            "nodes": len(c.controller.managers()),
            "commit_sim_s": commit_sim_s,
            "commit_rate_Bps": n_commits * payload / max(commit_sim_s, 1e-12),
            "retries": retries,
            "demotions": life["shard_demotions"],
            "watermark_crossings": life["watermark_crossings_high"],
        }


def _l3_restart_legs(payload: int, parts: int) -> dict:
    """Commit → drain → trickle, then time restarts as tiers are evicted:
    L2, then L3 (cold), then L2 again via promote-on-read."""
    data = np.arange(payload // 4, dtype=np.float32)
    rows = {}
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=4 * payload, pfs_bandwidth=PFS_BW,
                       l3=True, l3_bandwidth=L3_BW,
                       l3_request_latency=L3_LATENCY,
                       adaptive_interval=False) as c:
        client = ICheckClient("app", c.controller, ranks=parts).init(
            ckpt_bytes_estimate=payload)
        client.add_adapt("x", data.shape, "float32", num_parts=parts)
        client.commit(0, {"x": block_parts(data, parts)}, blocking=True)
        c.controller.wait_for_drains(timeout=60)
        c.controller.wait_for_uploads(timeout=60)

        def timed_restart(expect_level: str) -> dict:
            t0 = c.clock.now()
            meta, out, level = client.restart()
            dur = c.clock.now() - t0
            assert level == expect_level, (level, expect_level)
            got = np.concatenate([out["x"][i] for i in range(parts)])
            np.testing.assert_array_equal(got, data)
            return {"sim_s": dur, "level": level,
                    "rate_Bps": payload / max(dur, 1e-12)}

        # evict L1 everywhere (kill agents and scrub node stores — the
        # health monitor would otherwise re-serve RAM through replacements)
        for mgr in c.controller.managers():
            for agent in list(mgr.agents()):
                c.fault.kill_agent(agent.agent_id)
            mgr.store.drop_checkpoint("app", 0)
        rows["l2"] = timed_restart("l2")

        # evict the PFS copy: only the object store can serve it now
        c.pfs.drop_checkpoint("app", 0)
        rows["l3_cold"] = timed_restart("l3")

        # promote-on-read repopulated the PFS: next restart is L2 again
        rows["l2_after_promote"] = timed_restart("l2")

        snap = c.telemetry.snapshot()
        rows["l3_cost"] = snap["l3"]
        rows["prometheus"] = c.telemetry.prometheus()
        client.finalize()
    return rows


def _run(payload_pressure: int, n_commits: int, payload_restart: int,
         parts_restart: int, verbose: bool, tag: str,
         node_mem: int = PRESSURE_NODE_MEM,
         prometheus_out: str = "") -> dict:
    reactive = _pressure_leg(False, payload_pressure, n_commits, node_mem)
    lifecycle = _pressure_leg(True, payload_pressure, n_commits, node_mem)
    restart = _l3_restart_legs(payload_restart, parts_restart)
    prometheus = restart.pop("prometheus")
    if prometheus_out:
        with open(prometheus_out, "w") as f:
            f.write(prometheus)
    out = {
        "pressure": {
            "node_memory": node_mem,
            "payload": payload_pressure,
            "commits": n_commits,
            "reactive": reactive,
            "lifecycle": lifecycle,
        },
        "l3_restart": {"payload": payload_restart, **restart},
    }
    save(f"b9_tiering{tag}", out)
    if verbose:
        print(f"\nB9 capacity pressure ({fmt_bytes(payload_pressure)} ckpt "
              f"x{n_commits} on a {fmt_bytes(node_mem)} node):")
        for name, leg in (("reactive", reactive), ("lifecycle", lifecycle)):
            print(f"  {name:10s} escalations={leg['escalations']} "
                  f"nodes={leg['nodes']} retries={leg['retries']} "
                  f"demotions={leg['demotions']} "
                  f"commit={fmt_bytes(leg['commit_rate_Bps'])}/s")
        print(f"B9 restart ladder ({fmt_bytes(payload_restart)}):")
        for name in ("l2", "l3_cold", "l2_after_promote"):
            r = restart[name]
            print(f"  {name:17s}: {r['sim_s']:.3f}s sim "
                  f"({fmt_bytes(r['rate_Bps'])}/s, from {r['level']})")
        cost = restart["l3_cost"]
        print(f"  L3 bill: ${cost['total_usd']:.6f} "
              f"({cost['put_requests']} PUT / {cost['get_requests']} GET, "
              f"{fmt_bytes(cost['bytes_in'])} in / "
              f"{fmt_bytes(cost['bytes_out'])} out)")
        if prometheus_out:
            print(f"  [prometheus metrics written to {prometheus_out}]")
    # the claims this benchmark exists to demonstrate, enforced:
    assert lifecycle["escalations"] == 0, \
        "watermark demotion must eliminate capacity-pressure RM escalations"
    assert reactive["escalations"] >= 1, \
        "the reactive baseline must actually hit capacity pressure"
    assert lifecycle["nodes"] == 1
    assert restart["l3_cold"]["sim_s"] > restart["l2"]["sim_s"], \
        "object-store restart must cost more than PFS restart"
    assert restart["l2_after_promote"]["level"] == "l2"
    return out


def run(verbose: bool = True) -> dict:
    return _run(PRESSURE_PAYLOAD, PRESSURE_COMMITS, RESTART_PAYLOAD,
                RESTART_PARTS, verbose, tag="")


def run_smoke(verbose: bool = True) -> dict:
    """Seconds-scale CI canary; also dumps the TelemetryService's Prometheus
    exposition to BENCH_prometheus.txt for the perf-job artifact."""
    return _run(PRESSURE_PAYLOAD // 4, 4, RESTART_PAYLOAD // 8,
                RESTART_PARTS // 2, verbose, tag="_smoke",
                node_mem=PRESSURE_NODE_MEM // 4,
                prometheus_out=os.path.join(os.getcwd(),
                                            "BENCH_prometheus.txt"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run_smoke() if args.smoke else run()


if __name__ == "__main__":
    main()
