"""B7 -- roofline table: aggregates the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json produced by ``python -m repro.launch.dryrun``
and prints, per (arch x shape x mesh): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

from .common import save

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "dryrun")

SKIPS = [
    # long_500k requires sub-quadratic attention (assignment): skipped for
    # pure full-attention archs, run for SSM/hybrid
    (a, "long_500k") for a in
    ("dbrx-132b", "qwen3-moe-235b-a22b", "seamless-m4t-medium", "yi-6b",
     "phi3-medium-14b", "deepseek-7b", "qwen2.5-3b", "pixtral-12b")
]


def load(pattern: str = "*.json"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        a = json.load(open(f))
        r = a["roofline"]
        mesh = "2x16x16" if a["mesh"].get("pod") else "16x16"
        rows.append({
            "arch": a["arch"], "shape": a["shape"], "mesh": mesh,
            "tag": a.get("tag", ""),
            "mem_GiB": a["memory"]["peak_bytes_per_device"] / 2**30,
            "t_compute": r["t_compute"], "t_memory": r["t_memory"],
            "t_collective": r["t_collective"], "dominant": r["dominant"],
            "useful": r["useful_flop_ratio"],
            "fraction": r["roofline_fraction"],
            "compile_s": a["compile_s"],
        })
    return rows


def run(verbose: bool = True) -> dict:
    rows = load()
    if not rows:
        print("\nB7 roofline: no dry-run artifacts found -- run "
              "`python -m repro.launch.dryrun --all --both-meshes` first")
        return {"rows": []}
    out = {"rows": rows, "skipped_cells": SKIPS}
    save("b7_roofline", out)
    if verbose:
        print("\nB7 roofline (from the compiled multi-pod dry-run; "
              "t_* in seconds/step at v5e peak):")
        hdr = (f"  {'arch':21s} {'shape':11s} {'mesh':7s} {'GiB':>6s} "
               f"{'t_comp':>7s} {'t_mem':>7s} {'t_coll':>7s} "
               f"{'dominant':>10s} {'useful':>6s} {'frac':>6s}")
        print(hdr)
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                             r["mesh"], r["tag"])):
            tag = f" [{r['tag']}]" if r["tag"] else ""
            print(f"  {r['arch']:21s} {r['shape']:11s} {r['mesh']:7s} "
                  f"{r['mem_GiB']:6.1f} {r['t_compute']:7.3f} "
                  f"{r['t_memory']:7.3f} {r['t_collective']:7.3f} "
                  f"{r['dominant']:>10s} {r['useful']:6.2f} "
                  f"{r['fraction']:6.3f}{tag}")
        print(f"  ({len(SKIPS)} long_500k cells skipped per assignment: "
              f"full-attention archs)")
    return out


if __name__ == "__main__":
    run()
