"""B3 -- data redistribution on resize (paper SSIII-B): BLOCK / CYCLIC
N -> M re-partitioning served from agent memory, vs the naive baseline of
gathering the whole array everywhere.

iCheck moves only the slices each new part actually needs; we count the
bytes each new rank pulls and the end-to-end simulated time.
"""
from __future__ import annotations

import numpy as np

from repro.core import ICheckClient, ICheckCluster, PartitionScheme
from repro.core import plan as planlib
from repro.core.types import PartitionDesc

from .common import fmt_bytes, save

N = 8 << 20             # elements (32 MiB f32)


def _parts(arr, desc):
    return {i: p for i, p in enumerate(planlib.split_array(arr, desc))}


def run(verbose: bool = True) -> dict:
    rng = np.random.default_rng(0)
    data = rng.standard_normal(N).astype(np.float32)
    results = []
    for scheme in (PartitionScheme.BLOCK, PartitionScheme.CYCLIC):
        for old_p, new_p in ((8, 12), (8, 4), (16, 24)):
            desc = PartitionDesc(scheme=scheme, num_parts=old_p, block=4096)
            with ICheckCluster(n_icheck_nodes=4, node_memory=8 << 30) as c:
                client = ICheckClient("app", c.controller,
                                      ranks=old_p).init(
                    ckpt_bytes_estimate=data.nbytes)
                client.add_adapt("x", data.shape, "float32", scheme=scheme,
                                 num_parts=old_p, block=4096)
                client.commit(0, {"x": _parts(data, desc)}, blocking=True,
                              drain=False)
                t0 = c.clock.now()
                new_parts = client.redistribute("x", new_p)
                sim_s = c.clock.now() - t0
                # verify correctness: reassemble equals original
                new_desc = desc.renumbered(new_p)
                rebuilt = planlib.assemble_array(
                    [new_parts[i] for i in range(new_p)], new_desc,
                    data.shape)
                np.testing.assert_array_equal(rebuilt, data)
                moves = c.controller.plan_for_resize("app", "x", new_p)
                moved = sum(mv.length * 4 for mv in moves)
                client.finalize()
            naive = data.nbytes * new_p          # everyone gathers everything
            results.append({
                "scheme": scheme.value, "old": old_p, "new": new_p,
                "bytes_moved": moved, "bytes_naive": naive,
                "sim_s": sim_s, "saving": naive / max(moved, 1),
            })
    out = {"elements": N, "rows": results}
    save("b3_redistribution", out)
    if verbose:
        print(f"\nB3 redistribution ({fmt_bytes(data.nbytes)} array):")
        for r in results:
            print(f"  {r['scheme']:6s} {r['old']:3d}->{r['new']:3d}: moved "
                  f"{fmt_bytes(r['bytes_moved'])} vs naive "
                  f"{fmt_bytes(r['bytes_naive'])} ({r['saving']:.1f}x less), "
                  f"{r['sim_s']:.3f}s sim")
    return out


if __name__ == "__main__":
    run()
