"""B3 -- data redistribution on resize (paper SSIII-B): BLOCK / CYCLIC
N -> M re-partitioning, peer-to-peer vs the client funnel.

Two legs per case:

  * ``via="client"`` — the legacy funnel: the adapt window gathers every
    needed source shard through one process (O(array) bytes over one NIC),
    decodes, and applies the moves host-side.  This is the baseline and the
    permanent fallback path.
  * ``via="peer"``   — agents execute pre-staged transfer programs among
    themselves (slice reads over the simulated fabric, intra-node via the
    memory bus, cross-node concurrently across NICs); the client then
    fetches only the parts its local new ranks own.

The smoke variant (CI perf gate) runs the 16→24 cross-node BLOCK case and
exports ``b3_peer_speedup`` (client-funnel sim time / peer sim time, must
stay ≥3x) and ``b3_bytes_through_client_reduction`` (funnel bytes through
the client / peer bytes through the client) — both higher-is-better and
enforced by ``benchmarks/check_regression.py``.  It also appends the new
``icheck_redist*`` gauges to ``BENCH_prometheus.txt``.

A third leg measures the *zero-stall* (two-phase) resize: the base
checkpoint streams to the new partition while the app keeps committing
q8-deltas; the cutover replays only the tail frames.  Exported as
``b3_stall_s`` (the bounded cutover stall, lower-is-better in the gate) and
``b3_overlap_steps`` (commits absorbed during the window); the leg asserts
the stall is ≥5x smaller than the equivalent stop-the-world window.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import ICheckClient, ICheckCluster, PartitionScheme
from repro.core import plan as planlib
from repro.core.types import PartitionDesc

from .common import FixedCountPolicy, fmt_bytes, save

N = 8 << 20             # elements (32 MiB f32)
SMOKE_N = 2 << 20       # elements (8 MiB f32)
NODES = 4


def _parts(arr, desc):
    return {i: p for i, p in enumerate(planlib.split_array(arr, desc))}


def _leg(data: np.ndarray, scheme: PartitionScheme, old_p: int, new_p: int,
         via: str) -> dict:
    """One redistribution on a fresh cluster; returns the
    ``redistribution_done`` accounting + verification against the oracle.

    The peer leg fetches only the parts of the client's *local* new ranks
    (``new_p // NODES`` of them) — the other ranks pull their own parts
    straight from the owning agents.  The client leg is the funnel: it must
    materialize every part to serve the app, so it gathers everything.
    """
    desc = PartitionDesc(scheme=scheme, num_parts=old_p, block=4096)
    new_desc = desc.renumbered(new_p)
    local = list(range(max(1, new_p // NODES))) if via == "peer" else None
    with ICheckCluster(n_icheck_nodes=NODES, node_memory=8 << 30,
                       policy=FixedCountPolicy(NODES),
                       adaptive_interval=False) as c:
        client = ICheckClient("app", c.controller, ranks=old_p).init(
            ckpt_bytes_estimate=data.nbytes)
        client.add_adapt("x", data.shape, "float32", scheme=scheme,
                         num_parts=old_p, block=4096)
        client.commit(0, {"x": _parts(data, desc)}, blocking=True,
                      drain=False)
        new_parts = client.redistribute("x", new_p, parts_needed=local,
                                        via=via)
        done = [e for e in c.controller.events
                if e["event"] == "redistribution_done"][-1]
        assert done["via"] == via, \
            f"{via} leg fell back: {done['via']}"
        # correctness: every materialized part matches the oracle split
        oracle = planlib.split_array(data, new_desc)
        for p, arr in new_parts.items():
            np.testing.assert_array_equal(arr, oracle[p])
        moves = c.controller.plan_for_resize("app", "x", new_p)
        client.finalize()
    return {
        "via": via, "sim_s": done["sim_s"],
        "bytes_through_client": done["bytes_through_client"],
        "bytes_moved": done["bytes_moved"],
        "peer_hops": done["peer_hops"],
        "cross_reads": done["cross_reads"],
        "plan_bytes": sum(mv.length * 4 for mv in moves),
        "local_parts": len(new_parts),
    }


def _stall_leg(data: np.ndarray, old_p: int, new_p: int,
               window_commits: int = 3) -> dict:
    """Zero-stall resize vs stop-the-world on the same cluster.

    Commits a q8-delta base, opens an overlap window (16→24 BLOCK), keeps
    committing mutated deltas while the base streams, then cuts over and
    compares the bounded stall against a stop-the-world peer window of the
    same head state.  Verifies the overlap result is bit-identical to the
    client funnel restored from the head.
    """
    desc = PartitionDesc(scheme=PartitionScheme.BLOCK, num_parts=old_p,
                         block=4096)
    local = list(range(max(1, new_p // NODES)))
    buf = data.copy()
    with ICheckCluster(n_icheck_nodes=NODES, node_memory=8 << 30,
                       policy=FixedCountPolicy(NODES),
                       adaptive_interval=False) as c:
        client = ICheckClient("app", c.controller, ranks=old_p,
                              codec="q8-delta").init(
            ckpt_bytes_estimate=buf.nbytes)
        client.add_adapt("x", buf.shape, "float32", num_parts=old_p,
                         block=4096)
        client.commit(0, {"x": _parts(buf, desc)}, blocking=True,
                      drain=False)
        handle = client.redistribute("x", new_p, parts_needed=local,
                                     overlap=True)
        # the app keeps stepping: each "step" mutates ~1% of the array and
        # commits a q8-delta against the chain the window holds open
        chunk = max(1, buf.size // 100)
        for i in range(1, window_commits + 1):
            lo = (i * chunk) % max(1, buf.size - chunk)
            buf[lo:lo + chunk] += np.float32(0.25 * i)
            client.commit(i, {"x": _parts(buf, desc)}, blocking=True,
                          drain=False)
        assert handle.wait(60), "overlap stream did not land"
        out = handle.cutover()
        done = [e for e in c.controller.events
                if e["event"] == "redistribution_done"][-1]
        assert done["via"] == "peer", f"overlap fell back: {done}"
        assert not done["rehydrated"], "no chain reset happened: the " \
            "cutover must replay the tail, not re-hydrate"
        assert done["tail_frames"] == window_commits, \
            f"expected {window_commits} tail frames, got " \
            f"{done['tail_frames']}"
        # bit-identity vs the funnel restored from the same head
        oracle = client.redistribute("x", new_p, parts_needed=local,
                                     via="client")
        for p in local:
            np.testing.assert_array_equal(out[p], oracle[p])
        # stop-the-world comparator: one blocking peer window of the same
        # head state (full chain stream + local part fetch, app stalled)
        client.redistribute("x", new_p, parts_needed=local, via="peer")
        sw = [e for e in c.controller.events
              if e["event"] == "redistribution_done"
              and e["via"] == "peer"][-1]
        client.finalize()
    return {
        "old": old_p, "new": new_p,
        "stall_s": done["stall_s"],
        "overlap_sim_s": done["overlap_sim_s"],
        "overlap_steps": done["overlap_commits"],
        "tail_frames": done["tail_frames"],
        "bytes_through_client": done["bytes_through_client"],
        "stop_world_s": sw["sim_s"],
        "stall_reduction": sw["sim_s"] / max(done["stall_s"], 1e-12),
    }


def _case(data, scheme, old_p, new_p) -> dict:
    client_leg = _leg(data, scheme, old_p, new_p, "client")
    peer_leg = _leg(data, scheme, old_p, new_p, "peer")
    naive = data.nbytes * new_p          # everyone gathers everything
    return {
        "scheme": scheme.value, "old": old_p, "new": new_p,
        "bytes_moved": client_leg["plan_bytes"], "bytes_naive": naive,
        "saving": naive / max(client_leg["plan_bytes"], 1),
        "client": client_leg, "peer": peer_leg,
        "peer_speedup": client_leg["sim_s"] / max(peer_leg["sim_s"], 1e-12),
        "bytes_through_client_reduction":
            client_leg["bytes_through_client"]
            / max(peer_leg["bytes_through_client"], 1),
    }


def _print_rows(nbytes: int, rows) -> None:
    print(f"\nB3 redistribution ({fmt_bytes(nbytes)} array, "
          f"{NODES} iCheck nodes):")
    for r in rows:
        print(f"  {r['scheme']:6s} {r['old']:3d}->{r['new']:3d}: "
              f"client {r['client']['sim_s'] * 1e3:7.3f}ms  "
              f"peer {r['peer']['sim_s'] * 1e3:7.3f}ms "
              f"({r['peer_speedup']:4.1f}x)  thru-client "
              f"{fmt_bytes(r['client']['bytes_through_client'])} -> "
              f"{fmt_bytes(r['peer']['bytes_through_client'])} "
              f"({r['bytes_through_client_reduction']:.1f}x less, "
              f"{r['peer']['peer_hops']} peer hops)")


def _print_stall(stall: dict) -> None:
    print(f"  zero-stall {stall['old']:3d}->{stall['new']:3d}: "
          f"stop-the-world {stall['stop_world_s'] * 1e3:7.3f}ms  "
          f"cutover stall {stall['stall_s'] * 1e3:7.3f}ms "
          f"({stall['stall_reduction']:4.1f}x less, "
          f"{stall['overlap_steps']} commits absorbed, "
          f"{stall['tail_frames']} tail frames replayed)")


def run(verbose: bool = True) -> dict:
    rng = np.random.default_rng(0)
    data = rng.standard_normal(N).astype(np.float32)
    results = []
    for scheme in (PartitionScheme.BLOCK, PartitionScheme.CYCLIC):
        for old_p, new_p in ((8, 12), (8, 4), (16, 24)):
            results.append(_case(data, scheme, old_p, new_p))
    stall = _stall_leg(data, 16, 24)
    out = {"elements": N, "rows": results, "stall": stall}
    save("b3_redistribution", out)
    if verbose:
        _print_rows(data.nbytes, results)
        _print_stall(stall)
    return out


def run_smoke(verbose: bool = True) -> dict:
    """CI perf canary: the 16→24 cross-node BLOCK case, peer vs client,
    plus the zero-stall overlap leg."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal(SMOKE_N).astype(np.float32)
    row = _case(data, PartitionScheme.BLOCK, 16, 24)
    # the claims this benchmark exists to demonstrate, enforced:
    assert row["peer_speedup"] >= 3.0, \
        f"peer path must be >=3x faster than the client funnel " \
        f"(got {row['peer_speedup']:.2f}x)"
    local_bytes = sum(
        p.nbytes for p in planlib.split_array(
            data, PartitionDesc(scheme=PartitionScheme.BLOCK,
                                num_parts=24))[:24 // NODES])
    assert row["peer"]["bytes_through_client"] == local_bytes, \
        "peer path must funnel exactly the local new ranks' parts " \
        "through the client"
    stall = _stall_leg(data, 16, 24)
    assert stall["stall_reduction"] >= 5.0, \
        f"zero-stall cutover must be >=5x shorter than the " \
        f"stop-the-world window (got {stall['stall_reduction']:.2f}x)"
    out = {"elements": SMOKE_N, "rows": [row], "stall": stall}
    save("b3_redistribution_smoke", out)
    if verbose:
        _print_rows(data.nbytes, [row])
        _print_stall(stall)
    _append_prometheus(verbose)
    return out


def _append_prometheus(verbose: bool) -> None:
    """Append the redistribution gauges to BENCH_prometheus.txt (a tiny
    dedicated cluster runs one peer redistribution to populate them)."""
    path = os.path.join(os.getcwd(), "BENCH_prometheus.txt")
    rng = np.random.default_rng(1)
    data = rng.standard_normal(1 << 16).astype(np.float32)
    desc = PartitionDesc(scheme=PartitionScheme.BLOCK, num_parts=4)
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=64 << 20,
                       adaptive_interval=False) as c:
        client = ICheckClient("app", c.controller, ranks=4).init()
        client.add_adapt("x", data.shape, "float32", num_parts=4)
        client.commit(0, {"x": _parts(data, desc)}, blocking=True,
                      drain=False)
        client.redistribute("x", 6, parts_needed=[0])
        prom = c.telemetry.prometheus()
        client.finalize()
    redist = [line for line in prom.splitlines()
              if "icheck_redist" in line]
    with open(path, "a") as f:
        f.write("\n# ---- b3: peer redistribution gauges ----\n")
        f.write("\n".join(redist) + "\n")
    if verbose:
        print(f"  [redistribution gauges appended to {path}]")


if __name__ == "__main__":
    run()
