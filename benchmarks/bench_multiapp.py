"""B4 -- multi-application adaptivity (paper SSII/SSIV): one iCheck instance
serving three applications with different checkpoint freq x size profiles,
static single-agent placement vs the adaptive policy.

Metric: per-app mean commit transfer time and the aggregate checkpoint
throughput; the adaptive policy gives demanding apps more agents on less
loaded nodes, which SCR/CRAFT-class fixed-resource libraries cannot do.
"""
from __future__ import annotations

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import block_parts, fmt_bytes, save

NIC_BW = 1e9      # modest NIC so the apps' demand profiles actually differ

APPS = [
    # (name, payload, parts, commits, ckpt_interval_s)
    ("small-frequent", 16 << 20, 8, 6, 0.25),
    ("large-rare", 256 << 20, 16, 2, 0.25),
    ("medium", 64 << 20, 8, 3, 0.25),
]


def _run_policy(policy: str) -> dict:
    per_app = {}
    with ICheckCluster(n_icheck_nodes=4, n_spare_nodes=2,
                       node_memory=8 << 30, policy=policy,
                       nic_bandwidth=NIC_BW) as c:
        clients = {}
        datas = {}
        for name, payload, parts, commits, interval in APPS:
            rng = np.random.default_rng(hash(name) % 2**31)
            data = rng.standard_normal(payload // 4).astype(np.float32)
            cl = ICheckClient(name, c.controller, ranks=parts,
                              ckpt_interval_s=interval).init(
                ckpt_bytes_estimate=payload)
            cl.add_adapt("x", data.shape, "float32", num_parts=parts)
            clients[name] = cl
            datas[name] = block_parts(data, parts)
        for name, payload, parts, commits, interval in APPS:
            sims = []
            for step in range(commits):
                h = clients[name].commit(step, {"x": datas[name]},
                                         blocking=True, drain=False)
                sims.append(h.sim_duration)
            per_app[name] = {
                "mean_commit_sim_s": float(np.mean(sims)),
                "agents": len(c.controller.agents_for(name)),
                "bytes": payload,
                "interval_s": interval,
            }
        for cl in clients.values():
            cl.finalize()
    total_bytes = sum(a[1] * a[3] for a in APPS)
    total_sim = sum(per_app[a[0]]["mean_commit_sim_s"] * a[3] for a in APPS)
    return {"per_app": per_app, "total_bytes": total_bytes,
            "total_sim_s": total_sim,
            "agg_rate_Bps": total_bytes / max(total_sim, 1e-9)}


def run(verbose: bool = True) -> dict:
    static = _run_policy("static")
    adaptive = _run_policy("adaptive")
    out = {"static": static, "adaptive": adaptive,
           "speedup": static["total_sim_s"] / max(adaptive["total_sim_s"],
                                                  1e-9)}
    save("b4_multiapp", out)
    if verbose:
        print("\nB4 multi-app adaptivity (3 apps, 4 iCheck nodes):")
        for pol, res in (("static", static), ("adaptive", adaptive)):
            print(f"  {pol}:")
            for name, r in res["per_app"].items():
                print(f"    {name:15s} agents={r['agents']} commit="
                      f"{r['mean_commit_sim_s']:.3f}s sim")
            print(f"    aggregate rate {fmt_bytes(res['agg_rate_Bps'])}/s")
        print(f"  adaptive vs static: {out['speedup']:.2f}x faster "
              f"checkpoint path")
    return out


if __name__ == "__main__":
    run()
