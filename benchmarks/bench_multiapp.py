"""B4 -- multi-application adaptivity (paper SSII/SSIV): one iCheck instance
serving three applications with different checkpoint freq x size profiles,
static single-agent placement vs the adaptive policy.

Metric: per-app mean commit transfer time and the aggregate checkpoint
throughput; the adaptive policy gives demanding apps more agents on less
loaded nodes, which SCR/CRAFT-class fixed-resource libraries cannot do.
Per-app commit latencies are read from the TelemetryService (the bus-fed
metrics exporter), not from ad-hoc audit scans.

``--adaptive`` (B4A in the driver) runs the closed-loop interval benchmark:
the same three apps under one shared fixed checkpoint interval vs the
per-app Young/Daly IntervalController — apps with different commit costs
get different solved cadences, and the aggregate wasted-work + checkpoint
overhead drops.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import (block_parts, failure_schedule, fmt_bytes,
                     run_ckpt_workload, save)

NIC_BW = 1e9      # modest NIC so the apps' demand profiles actually differ

APPS = [
    # (name, payload, parts, commits, ckpt_interval_s)
    ("small-frequent", 16 << 20, 8, 6, 0.25),
    ("large-rare", 256 << 20, 16, 2, 0.25),
    ("medium", 64 << 20, 8, 3, 0.25),
]


def _run_policy(policy: str) -> dict:
    per_app = {}
    with ICheckCluster(n_icheck_nodes=4, n_spare_nodes=2,
                       node_memory=8 << 30, policy=policy,
                       nic_bandwidth=NIC_BW,
                       adaptive_interval=False) as c:
        clients = {}
        datas = {}
        for name, payload, parts, commits, interval in APPS:
            rng = np.random.default_rng(hash(name) % 2**31)
            data = rng.standard_normal(payload // 4).astype(np.float32)
            cl = ICheckClient(name, c.controller, ranks=parts,
                              ckpt_interval_s=interval).init(
                ckpt_bytes_estimate=payload)
            cl.add_adapt("x", data.shape, "float32", num_parts=parts)
            clients[name] = cl
            datas[name] = block_parts(data, parts)
        for name, payload, parts, commits, interval in APPS:
            for step in range(commits):
                clients[name].commit(step, {"x": datas[name]},
                                     blocking=True, drain=False)
        # per-app commit stats straight from the bus-fed telemetry (the
        # unbiased mean, not the EWMA, so scale-ups mid-run don't skew the
        # static-vs-adaptive comparison)
        snap = c.telemetry.snapshot()["per_app"]
        for name, payload, parts, commits, interval in APPS:
            per_app[name] = {
                "mean_commit_sim_s": snap[name]["mean_commit_latency_s"],
                "commits": snap[name]["commits"],
                "agents": len(c.controller.agents_for(name)),
                "bytes": payload,
                "interval_s": interval,
            }
        for cl in clients.values():
            cl.finalize()
    total_bytes = sum(a[1] * a[3] for a in APPS)
    total_sim = sum(per_app[a[0]]["mean_commit_sim_s"] * a[3] for a in APPS)
    return {"per_app": per_app, "total_bytes": total_bytes,
            "total_sim_s": total_sim,
            "agg_rate_Bps": total_bytes / max(total_sim, 1e-9)}


def run(verbose: bool = True) -> dict:
    static = _run_policy("static")
    adaptive = _run_policy("adaptive")
    out = {"static": static, "adaptive": adaptive,
           "speedup": static["total_sim_s"] / max(adaptive["total_sim_s"],
                                                  1e-9)}
    save("b4_multiapp", out)
    if verbose:
        print("\nB4 multi-app adaptivity (3 apps, 4 iCheck nodes):")
        for pol, res in (("static", static), ("adaptive", adaptive)):
            print(f"  {pol}:")
            for name, r in res["per_app"].items():
                print(f"    {name:15s} agents={r['agents']} commit="
                      f"{r['mean_commit_sim_s']:.3f}s sim")
            print(f"    aggregate rate {fmt_bytes(res['agg_rate_Bps'])}/s")
        print(f"  adaptive vs static: {out['speedup']:.2f}x faster "
              f"checkpoint path")
    return out


# ---------------------------------------------------------------- adaptive
ADAPTIVE_APPS = [
    # (name, payload, parts): different commit costs -> different optima
    ("small", 8 << 20, 4),
    ("large", 96 << 20, 8),
    ("medium", 32 << 20, 8),
]
ADAPTIVE_MTBF_S = 25.0
ADAPTIVE_WORK_S = 90.0
FIXED_INTERVAL_S = 15.0


def _interval_policy_run(adaptive: bool, seed: int,
                         total_work_s: float) -> dict:
    per_app = {}
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=2 << 30, nic_bandwidth=1e9,
                       adaptive_interval=adaptive,
                       default_mtbf_s=300.0) as c:
        for i, (name, payload, parts_n) in enumerate(ADAPTIVE_APPS):
            data = np.random.default_rng(i).standard_normal(
                payload // 4).astype(np.float32)
            cl = ICheckClient(name, c.controller, ranks=parts_n,
                              ckpt_interval_s=FIXED_INTERVAL_S).init(
                ckpt_bytes_estimate=payload)
            cl.add_adapt("x", data.shape, "float32", num_parts=parts_n)
            parts = {"x": block_parts(data, parts_n)}
            failures = failure_schedule(ADAPTIVE_MTBF_S, 4.0 * total_work_s,
                                        seed=seed + i, t0=c.clock.now())
            res = run_ckpt_workload(c, cl, parts, total_work_s, failures,
                                    interval_fn=lambda c=cl:
                                    c.ckpt_interval_s)
            res["telemetry"] = c.telemetry.snapshot()["per_app"][name]
            per_app[name] = res
            cl.finalize()
    total = sum(r["total_overhead_s"] for r in per_app.values())
    return {"per_app": per_app, "total_overhead_s": total}


def run_adaptive(verbose: bool = True,
                 total_work_s: float = ADAPTIVE_WORK_S,
                 seed: int = 0) -> dict:
    fixed = _interval_policy_run(False, seed, total_work_s)
    adaptive = _interval_policy_run(True, seed, total_work_s)
    out = {
        "injected_mtbf_s": ADAPTIVE_MTBF_S,
        "fixed_interval_s": FIXED_INTERVAL_S,
        "fixed": fixed,
        "adaptive": adaptive,
        "overhead_reduction": 1.0 - adaptive["total_overhead_s"]
        / max(fixed["total_overhead_s"], 1e-9),
    }
    save("b4a_adaptive_interval", out)
    if verbose:
        print(f"\nB4A per-app adaptive intervals (3 apps, injected MTBF "
              f"{ADAPTIVE_MTBF_S:.0f}s, {total_work_s:.0f}s of work each):")
        for pol, res in (("fixed", fixed), ("adaptive", adaptive)):
            print(f"  {pol}:")
            for name, r in res["per_app"].items():
                print(f"    {name:7s} interval={r['final_interval_s']:6.2f}s "
                      f"commits={r['commits']:4d} "
                      f"wasted={r['wasted_work_s']:6.2f}s "
                      f"ckpt={r['ckpt_overhead_s']:5.2f}s "
                      f"overhead={r['total_overhead_s']:6.2f}s")
            print(f"    aggregate overhead {res['total_overhead_s']:.2f}s")
        print(f"  per-app Young/Daly cuts aggregate overhead by "
              f"{100 * out['overhead_reduction']:.0f}%")
    assert adaptive["total_overhead_s"] < fixed["total_overhead_s"], \
        "per-app adaptive intervals must beat the shared fixed interval"
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--adaptive", action="store_true",
                    help="run the adaptive-interval wasted-work comparison")
    args = ap.parse_args(argv)
    if args.adaptive:
        run_adaptive()
    else:
        run()


if __name__ == "__main__":
    main()
