"""B12 -- crash-consistent control plane: warm recovery vs cold scan.

Three experiments against the same commit workload:

  * **warm recovery**: commit + drain + trickle a run of checkpoints,
    hard-crash the controller, and time ``Controller.recover()`` (journal
    snapshot + WAL replay + tier reconciliation) in sim seconds.  The
    recovered catalog must restore the newest checkpoint bit-identically.

  * **cold L3 manifest scan**: the same workload on a journal-less
    cluster, then a crash *and* a recycled PFS (the durability-floor
    scenario): ``latest_restartable`` must fall through to the remote
    object store's manifests, paying a request-latency round trip per
    LIST/GET.  Warm recovery must beat this scan by >= 5x sim time —
    the whole point of journaling the metadata.

  * **journal append overhead**: the same commit path with the journal
    on vs off.  The WAL barrier writes must cost <= 3% extra sim time —
    crash consistency is supposed to be cheap.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import block_parts, fmt_bytes, save

PARTS = 4
PAYLOAD = 4 << 20
COMMITS = 6
SMOKE_PAYLOAD = 1 << 20
SMOKE_COMMITS = 4

MIN_WARM_SPEEDUP = 5.0         # cold L3 scan / warm recover, asserted below
MAX_JOURNAL_OVERHEAD_PCT = 3.0  # journal-on vs journal-off commit path


def _commit_run(cluster, payload: int, n_commits: int, drain: bool):
    """Commit ``n_commits`` checkpoints; returns (client, data, sim_s)."""
    data = np.arange(payload // 4, dtype=np.float32)
    client = ICheckClient("app", cluster.controller, ranks=PARTS).init(
        ckpt_bytes_estimate=payload)
    client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
    # whole-loop clock delta, not summed transfer spans: the journal's
    # barrier appends sleep the sim clock outside any transfer, and the
    # overhead leg exists to price exactly that
    t0 = cluster.clock.now()
    for step in range(n_commits):
        client.commit(step, {"x": block_parts(data + step, PARTS)},
                      blocking=True, drain=drain)
    return client, data, cluster.clock.now() - t0


def _warm_leg(payload: int, n_commits: int) -> dict:
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=8 * payload, l3=True,
                       adaptive_interval=False) as c:
        ctl = c.controller
        client, data, _ = _commit_run(c, payload, n_commits, drain=True)
        ctl.wait_for_drains(timeout=60)
        ctl.wait_for_uploads(timeout=60)
        ctl.crash()
        report = ctl.recover()
        warm_s = float(report["duration_s"])
        got = ctl.latest_restartable("app")
        assert got is not None and got[0].ckpt_id == n_commits - 1, \
            "warm recovery lost the newest checkpoint"
        meta, parts, level = client.restart()
        back = np.concatenate([parts["x"][i] for i in range(PARTS)])
        np.testing.assert_array_equal(back, data + meta.step)
        client.finalize()
        return {
            "warm_recover_sim_s": warm_s,
            "replay": report["replay"],
            "max_known": int(report["apps"]["app"]["max_known"]),
            "downgraded": len(report["downgraded"]),
            "restore_level": level,
        }


def _cold_leg(payload: int, n_commits: int) -> dict:
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=8 * payload, l3=True,
                       journal=False, adaptive_interval=False) as c:
        ctl = c.controller
        client, data, _ = _commit_run(c, payload, n_commits, drain=True)
        ctl.wait_for_drains(timeout=60)
        ctl.wait_for_uploads(timeout=60)
        in_l3 = c.l3.list_checkpoints("app")
        assert len(in_l3) == n_commits, \
            f"trickle left only {len(in_l3)}/{n_commits} checkpoints in L3"
        ctl.crash()
        # the PFS was recycled with the controller: manifests and shards
        # gone, so restartability knowledge must come from the L3 scan
        for ckpt_id in c.pfs.list_checkpoints("app"):
            c.pfs.drop_checkpoint("app", ckpt_id)
        t0 = c.clock.now()
        got = ctl.latest_restartable("app")
        cold_s = c.clock.now() - t0
        assert got is not None and got[0].ckpt_id == n_commits - 1, \
            "cold L3 scan failed to find the newest checkpoint"
        client.finalize()
        return {"cold_scan_sim_s": cold_s, "found_level": got[1]}


def _overhead_leg(payload: int, n_commits: int) -> dict:
    times = {}
    for label, journal in (("journal_on", True), ("journal_off", False)):
        with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                           node_memory=8 * payload, journal=journal,
                           adaptive_interval=False) as c:
            client, _, sim_s = _commit_run(c, payload, n_commits,
                                           drain=False)
            client.finalize()
            times[label] = sim_s
    pct = (times["journal_on"] / max(times["journal_off"], 1e-12)
           - 1.0) * 100.0
    return {
        "commit_sim_s_journal_on": times["journal_on"],
        "commit_sim_s_journal_off": times["journal_off"],
        "journal_overhead_pct": pct,
    }


def _run(payload: int, n_commits: int, verbose: bool, tag: str) -> dict:
    warm = _warm_leg(payload, n_commits)
    cold = _cold_leg(payload, n_commits)
    overhead = _overhead_leg(payload, n_commits)
    speedup = cold["cold_scan_sim_s"] / max(warm["warm_recover_sim_s"],
                                            1e-12)
    out = {
        "payload": payload,
        "commits": n_commits,
        "warm": warm,
        "cold": cold,
        "overhead": overhead,
        "warm_speedup": speedup,
    }
    save(f"b12_recovery{tag}", out)
    if verbose:
        print(f"\nB12 control-plane recovery ({fmt_bytes(payload)} "
              f"x{n_commits}):")
        print(f"  warm recover   {warm['warm_recover_sim_s']:.6f}s sim "
              f"(replay {warm['replay'].get('frames', 0)} frames, "
              f"snapshot={bool(warm['replay'].get('snapshot'))}, "
              f"restore level={warm['restore_level']})")
        print(f"  cold L3 scan   {cold['cold_scan_sim_s']:.6f}s sim "
              f"(found level={cold['found_level']})")
        print(f"  warm speedup   {speedup:.1f}x "
              f"(gate: >={MIN_WARM_SPEEDUP:.0f}x)")
        print(f"  journal cost   "
              f"{overhead['journal_overhead_pct']:+.3f}% commit sim time "
              f"(gate: <={MAX_JOURNAL_OVERHEAD_PCT:.0f}%)")
    # the claims this benchmark exists to demonstrate, enforced:
    assert cold["found_level"] == "l3", \
        f"cold scan answered from {cold['found_level']}, not the L3 floor"
    assert speedup >= MIN_WARM_SPEEDUP, \
        f"warm recovery only {speedup:.1f}x faster than the cold L3 scan"
    assert overhead["journal_overhead_pct"] <= MAX_JOURNAL_OVERHEAD_PCT, \
        (f"journal overhead {overhead['journal_overhead_pct']:.2f}% > "
         f"{MAX_JOURNAL_OVERHEAD_PCT}%")
    return out


def run(verbose: bool = True) -> dict:
    return _run(PAYLOAD, COMMITS, verbose, tag="")


def run_smoke(verbose: bool = True) -> dict:
    return _run(SMOKE_PAYLOAD, SMOKE_COMMITS, verbose, tag="_smoke")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run_smoke() if args.smoke else run()


if __name__ == "__main__":
    main()
