"""B8 -- serving: measured CPU decode throughput (tiny configs) next to
the dry-run-derived v5e decode latency bounds (full configs), including
the int8-KV (H8) variant where it changes the bound."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import ServeEngine, serve_max_len

from .common import save

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _bound(path):
    try:
        a = json.load(open(path))
        return a["roofline"]["bound_s"], \
            a["memory"]["peak_bytes_per_device"] / 2**30
    except OSError:
        return None, None


def run(verbose: bool = True) -> dict:
    rows = []
    rng = np.random.default_rng(0)
    for arch in ("yi-6b", "deepseek-7b", "rwkv6-7b", "recurrentgemma-9b"):
        cfg = get_config(arch, tiny=True)
        params, _ = init_params(cfg, jax.random.key(0))
        b, t, gen = 4, 16, 32
        batch = {"tokens": rng.integers(0, cfg.vocab_size, (b, t))
                 .astype(np.int32)}
        eng = ServeEngine(cfg, params, max_len=serve_max_len(cfg, t, gen))
        eng.generate(batch, gen_len=2)          # compile
        t0 = time.monotonic()
        out = eng.generate(batch, gen_len=gen)
        tps = b * gen / (time.monotonic() - t0)
        bound, mem = _bound(os.path.join(
            ART, "dryrun", f"{arch}__decode_32k__pod.json"))
        bound_q, mem_q = _bound(os.path.join(
            ART, "perf", f"{arch}__decode_32k__pod__H8_kvq.json"))
        rows.append({"arch": arch, "cpu_tiny_tok_s": tps,
                     "v5e_decode_bound_s": bound, "mem_GiB": mem,
                     "v5e_bound_int8kv_s": bound_q, "mem_int8kv_GiB": mem_q})
    out = {"rows": rows}
    save("b8_serving", out)
    if verbose:
        print("\nB8 serving (tiny-config CPU throughput; v5e decode_32k "
              "step bound from the dry-run):")
        for r in rows:
            extra = ""
            if r["v5e_bound_int8kv_s"]:
                extra = (f"  int8-KV: {r['v5e_bound_int8kv_s']*1e3:.1f}ms, "
                         f"{r['mem_int8kv_GiB']:.1f}GiB")
            bd = f"{r['v5e_decode_bound_s']*1e3:.1f}ms" \
                if r["v5e_decode_bound_s"] else "n/a"
            print(f"  {r['arch']:18s} cpu {r['cpu_tiny_tok_s']:7.1f} tok/s | "
                  f"v5e bound {bd}, {r['mem_GiB']:.1f}GiB{extra}")
    return out


if __name__ == "__main__":
    run()
