"""B10 -- incremental delta checkpointing on the commit hot path.

Runs the same seeded commit sequence through three codecs (``raw``, ``q8``,
``q8-delta``) on identical clusters and measures what actually crosses the
client→agent fabric:

  * **low churn** — each step perturbs ~1% of the parameter blocks (the
    steady state of a converging training run): q8-delta ships sparse
    XOR-delta frames, so steady-state bytes-on-wire collapse (≥3x vs raw is
    asserted; in practice far more) and commit sim-time shrinks with them;
  * **high churn** — every block changes each step: the delta packer falls
    back to keyframes, so q8-delta never does worse than plain q8
    (asserted).

The q8-delta leg's restart (keyframe + delta replay) is verified
**bit-identical** to the plain-q8 leg's restore of the same data inside the
benchmark.  ``run_smoke`` feeds the CI perf gate and appends the q8-delta
cluster's telemetry (codec compression-ratio / encode-time gauges) to
``BENCH_prometheus.txt``.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.core import ICheckClient, ICheckCluster
from repro.kernels.ckpt_codec.blocks import BLOCK

from .common import block_parts, fmt_bytes, save

PAYLOAD = 32 << 20          # full-run region bytes
SMOKE_PAYLOAD = 4 << 20
COMMITS = 12                # includes one interior keyframe (K=8)
PARTS = 4
KEYFRAME_EVERY = 8
LOW_CHURN_FRAC = 0.01       # fraction of blocks perturbed per step


def _churn(rng, data: np.ndarray, frac: float) -> None:
    """Perturb ``frac`` of the BLOCK-sized chunks of ``data`` in place."""
    nb = data.size // BLOCK
    picks = rng.choice(nb, size=max(1, int(frac * nb)), replace=False)
    for b in picks:
        data[b * BLOCK:(b + 1) * BLOCK] += \
            rng.standard_normal(BLOCK).astype(np.float32) * 0.1


def _leg(codec: str, payload: int, n_commits: int, high_churn: bool,
         seed: int = 0) -> dict:
    """One codec leg: identical seeded data sequence, bytes + sim time."""
    rng = np.random.default_rng(seed)
    data = rng.standard_normal(payload // 4).astype(np.float32)
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=max(4 * payload * n_commits // PARTS,
                                       64 << 20),
                       adaptive_interval=False,
                       delta_keyframe_every=KEYFRAME_EVERY) as c:
        client = ICheckClient("app", c.controller, ranks=PARTS,
                              codec=codec).init(ckpt_bytes_estimate=payload)
        client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
        wire = []
        sim = []
        frames = []
        for step in range(n_commits):
            if step:
                if high_churn:
                    data = rng.standard_normal(payload // 4) \
                        .astype(np.float32)
                else:
                    _churn(rng, data, LOW_CHURN_FRAC)
            h = client.commit(step, {"x": block_parts(data, PARTS)},
                              blocking=True, drain=False)
            wire.append(sum(len(p) for k, p, _ in h._puts if k.replica == 0))
            sim.append(h.sim_duration)
            frames.append(h.meta.regions["x"].frame)
        meta, out, _ = client.restart()
        assert meta.step == n_commits - 1
        restored = np.concatenate(
            [out["x"][i].ravel() for i in range(PARTS)])
        tel = c.telemetry.snapshot()["per_app"]["app"]
        client.finalize()
    # steady state = everything after the initial (keyframe) commit
    return {
        "codec": codec,
        "total_wire_bytes": int(sum(wire)),
        "steady_wire_bytes": int(sum(wire[1:])),
        "steady_raw_bytes": payload * (n_commits - 1),
        "steady_commit_sim_s": float(sum(sim[1:])),
        "commit_rate_Bps": payload * (n_commits - 1)
        / max(sum(sim[1:]), 1e-12),
        "key_frames": frames.count("key"),
        "delta_frames": frames.count("delta"),
        "codec_compression_ratio": tel["codec_compression_ratio"],
        "codec_encode_s": tel["codec_encode_s"],
        "restored": restored,
        "data": data,
    }


def _workload(payload: int, n_commits: int, high_churn: bool) -> dict:
    legs = {codec: _leg(codec, payload, n_commits, high_churn)
            for codec in ("raw", "q8", "q8-delta")}
    # keyframe+delta replay must reproduce exactly what a plain-q8 restore
    # of the same data yields (both legs saw identical seeded sequences)
    np.testing.assert_array_equal(legs["q8-delta"]["restored"],
                                  legs["q8"]["restored"])
    np.testing.assert_array_equal(legs["raw"]["restored"],
                                  legs["raw"]["data"])
    out = {}
    for codec, leg in legs.items():
        leg = dict(leg)
        leg.pop("restored"), leg.pop("data")
        leg["wire_reduction_vs_raw"] = (
            leg["steady_raw_bytes"] / max(leg["steady_wire_bytes"], 1))
        out[codec] = leg
    return out


def _run(payload: int, n_commits: int, verbose: bool, tag: str,
         prometheus_append: str = "") -> dict:
    low = _workload(payload, n_commits, high_churn=False)
    high = _workload(payload, n_commits, high_churn=True)
    out = {"payload": payload, "commits": n_commits,
           "keyframe_every": KEYFRAME_EVERY,
           "low_churn_frac": LOW_CHURN_FRAC,
           "low_churn": low, "high_churn": high}
    save(f"b10_delta{tag}", out)
    if verbose:
        for name, wl in (("low-churn", low), ("high-churn", high)):
            print(f"\nB10 {name} ({fmt_bytes(payload)} x{n_commits} commits,"
                  f" K={KEYFRAME_EVERY}):")
            for codec, leg in wl.items():
                print(f"  {codec:9s}: steady wire "
                      f"{fmt_bytes(leg['steady_wire_bytes']):>10s} "
                      f"({leg['wire_reduction_vs_raw']:7.1f}x vs raw)  "
                      f"commit {fmt_bytes(leg['commit_rate_Bps'])}/s  "
                      f"frames {leg['key_frames']}K/{leg['delta_frames']}D")
        print("  [keyframe+delta restart verified bit-identical to q8]")
    # the claims this benchmark exists to demonstrate, enforced:
    assert low["q8-delta"]["wire_reduction_vs_raw"] >= 3.0, \
        "q8-delta must cut steady-state bytes-on-wire >=3x on low churn"
    assert low["q8-delta"]["steady_wire_bytes"] < \
        low["q8"]["steady_wire_bytes"], \
        "q8-delta must beat plain q8 on low churn"
    assert high["q8-delta"]["steady_wire_bytes"] <= \
        high["q8"]["steady_wire_bytes"] * 1.001, \
        "q8-delta must never lose to plain q8 (keyframe fallback)"
    assert low["q8-delta"]["commit_rate_Bps"] > low["raw"]["commit_rate_Bps"]
    if prometheus_append:
        # the codec compression-ratio / encode-time gauges come from the
        # q8-delta leg's cluster; re-run a tiny one to export them
        with ICheckCluster(n_icheck_nodes=1, n_spare_nodes=0,
                           node_memory=64 << 20, adaptive_interval=False,
                           delta_keyframe_every=KEYFRAME_EVERY) as c:
            client = ICheckClient("app", c.controller, ranks=1,
                                  codec="q8-delta").init()
            rng = np.random.default_rng(0)
            data = rng.standard_normal((SMOKE_PAYLOAD // 16) // 4) \
                .astype(np.float32)
            client.add_adapt("x", data.shape, "float32", num_parts=1)
            for step in range(3):
                _churn(rng, data, LOW_CHURN_FRAC)
                client.commit(step, {"x": {0: data}}, blocking=True,
                              drain=False)
            prom = c.telemetry.prometheus()
            client.finalize()
        with open(prometheus_append, "a") as f:
            f.write("\n# ---- b10: q8-delta commit-path codec gauges ----\n")
            f.write(prom)
        if verbose:
            print(f"  [codec gauges appended to {prometheus_append}]")
    return out


def run(verbose: bool = True) -> dict:
    return _run(PAYLOAD, COMMITS, verbose, tag="")


def run_smoke(verbose: bool = True) -> dict:
    return _run(SMOKE_PAYLOAD, COMMITS, verbose, tag="_smoke",
                prometheus_append=os.path.join(os.getcwd(),
                                               "BENCH_prometheus.txt"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run_smoke() if args.smoke else run()


if __name__ == "__main__":
    main()
