"""B5 -- multilevel restart latency (paper SSII): restoring from L1 (agent
memory over the fabric) vs L2 (parallel file system), plus the L1-replica
failover path (kill the primary replica's agent; restart must still come
from a surviving L1 copy).
"""
from __future__ import annotations

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import block_parts, fmt_bytes, save

PAYLOAD = 128 << 20
PARTS = 16
PFS_BW = 10e9
NIC_BW = 25e9


def run(verbose: bool = True) -> dict:
    data = np.random.default_rng(0).standard_normal(
        PAYLOAD // 4).astype(np.float32)
    rows = {}
    with ICheckCluster(n_icheck_nodes=4, node_memory=8 << 30,
                       nic_bandwidth=NIC_BW, pfs_bandwidth=PFS_BW) as c:
        from .common import FixedCountPolicy

        c.controller.policy = FixedCountPolicy(4)  # spread the 2 replicas
        client = ICheckClient("app", c.controller, ranks=PARTS,
                              replication=2).init(
            ckpt_bytes_estimate=PAYLOAD)
        client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
        client.commit(0, {"x": block_parts(data, PARTS)}, blocking=True)
        c.controller.wait_for_drains(timeout=60)

        # -- L1 restart
        t0 = c.clock.now()
        meta, parts, level = client.restart()
        rows["l1"] = {"sim_s": c.clock.now() - t0, "level": level}
        assert level == "l1"

        # -- L1 with primary-replica failure (failover to replica 1)
        primary = c.controller.agents_for("app")[0]
        c.fault.kill_agent(primary.agent_id)
        t0 = c.clock.now()
        meta, parts, level = client.restart()
        rows["l1_failover"] = {"sim_s": c.clock.now() - t0, "level": level}

        # -- L2 restart (all agents dead -> PFS)
        for mgr in c.controller.managers():
            for agent in list(mgr.agents()):
                c.fault.kill_agent(agent.agent_id)
        t0 = c.clock.now()
        meta, parts, level = client.restart()
        rows["l2"] = {"sim_s": c.clock.now() - t0, "level": level}
        assert level == "l2"
        got = np.concatenate([parts["x"][i] for i in range(PARTS)])
        np.testing.assert_array_equal(got, data)
        client.finalize()

    out = {"payload": PAYLOAD, "rows": rows,
           "l2_over_l1": rows["l2"]["sim_s"] / max(rows["l1"]["sim_s"], 1e-9)}
    save("b5_restart", out)
    if verbose:
        print(f"\nB5 restart latency ({fmt_bytes(PAYLOAD)}, repl=2):")
        for k, r in rows.items():
            print(f"  {k:12s}: {r['sim_s']:.3f}s sim (from {r['level']})")
        print(f"  L1 is {out['l2_over_l1']:.1f}x faster than PFS restart")
    return out


if __name__ == "__main__":
    run()
