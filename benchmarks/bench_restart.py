"""B5 -- multilevel restart latency (paper SSII): restoring from L1 (agent
memory over the fabric) vs L2 (parallel file system), plus the L1-replica
failover path (kill the primary replica's agent; restart must still come
from a surviving L1 copy).

``--adaptive`` (also B5A in the driver) runs the closed-loop interval
benchmark instead: the same failure-injected workload under a fixed
checkpoint interval vs the Young/Daly IntervalController driven by live
TelemetryService estimates, comparing wasted work + checkpoint overhead.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import (block_parts, failure_schedule, fmt_bytes,
                     run_ckpt_workload, save)

OBS_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "obs")

PAYLOAD = 128 << 20
PARTS = 16
PFS_BW = 10e9
NIC_BW = 25e9


def run(verbose: bool = True) -> dict:
    data = np.random.default_rng(0).standard_normal(
        PAYLOAD // 4).astype(np.float32)
    rows = {}
    with ICheckCluster(n_icheck_nodes=4, node_memory=8 << 30,
                       nic_bandwidth=NIC_BW, pfs_bandwidth=PFS_BW) as c:
        from .common import FixedCountPolicy

        c.controller.policy = FixedCountPolicy(4)  # spread the 2 replicas
        client = ICheckClient("app", c.controller, ranks=PARTS,
                              replication=2).init(
            ckpt_bytes_estimate=PAYLOAD)
        client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
        client.commit(0, {"x": block_parts(data, PARTS)}, blocking=True)
        c.controller.wait_for_drains(timeout=60)

        # -- L1 restart
        t0 = c.clock.now()
        meta, parts, level = client.restart()
        rows["l1"] = {"sim_s": c.clock.now() - t0, "level": level}
        assert level == "l1"

        # -- L1 with primary-replica failure (failover to replica 1)
        primary = c.controller.agents_for("app")[0]
        c.fault.kill_agent(primary.agent_id)
        t0 = c.clock.now()
        meta, parts, level = client.restart()
        rows["l1_failover"] = {"sim_s": c.clock.now() - t0, "level": level}

        # -- L2 restart (all agents dead -> PFS)
        for mgr in c.controller.managers():
            for agent in list(mgr.agents()):
                c.fault.kill_agent(agent.agent_id)
        t0 = c.clock.now()
        meta, parts, level = client.restart()
        rows["l2"] = {"sim_s": c.clock.now() - t0, "level": level}
        assert level == "l2"
        got = np.concatenate([parts["x"][i] for i in range(PARTS)])
        np.testing.assert_array_equal(got, data)
        # the commit/drain numbers come straight from the TelemetryService
        # (the bus-fed metrics exporter) rather than ad-hoc audit scans
        telemetry = c.telemetry.snapshot()["per_app"]["app"]
        client.finalize()

    out = {"payload": PAYLOAD, "rows": rows,
           "l2_over_l1": rows["l2"]["sim_s"] / max(rows["l1"]["sim_s"], 1e-9),
           "telemetry": telemetry}
    save("b5_restart", out)
    if verbose:
        print(f"\nB5 restart latency ({fmt_bytes(PAYLOAD)}, repl=2):")
        for k, r in rows.items():
            print(f"  {k:12s}: {r['sim_s']:.3f}s sim (from {r['level']})")
        print(f"  L1 is {out['l2_over_l1']:.1f}x faster than PFS restart")
        print(f"  telemetry: commit {telemetry['commit_latency_s']:.3f}s sim "
              f"EWMA, drain {fmt_bytes(telemetry['drain_rate_Bps'])}/s EWMA")
    return out


# ---------------------------------------------------------------- adaptive
ADAPTIVE_PAYLOAD = 48 << 20
ADAPTIVE_PARTS = 8
ADAPTIVE_MTBF_S = 30.0
ADAPTIVE_WORK_S = 180.0
FIXED_INTERVAL_S = 12.0


def _interval_policy_run(adaptive: bool, data, failure_times,
                         total_work_s: float) -> dict:
    """One policy leg: identical cluster + failure schedule, only the
    interval source differs (static config vs IntervalController)."""
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=2 << 30, nic_bandwidth=1e9,
                       adaptive_interval=adaptive,
                       default_mtbf_s=300.0) as c:
        client = ICheckClient("app", c.controller, ranks=ADAPTIVE_PARTS,
                              ckpt_interval_s=FIXED_INTERVAL_S).init(
            ckpt_bytes_estimate=data.nbytes)
        client.add_adapt("x", data.shape, "float32",
                         num_parts=ADAPTIVE_PARTS)
        parts = {"x": block_parts(data, ADAPTIVE_PARTS)}
        # adaptive: the client's ckpt_interval_s tracks INTERVAL_CHANGED
        # events; fixed: it stays at the registered constant
        res = run_ckpt_workload(c, client, parts, total_work_s,
                                failure_times,
                                interval_fn=lambda: client.ckpt_interval_s)
        snap = c.telemetry.snapshot()
        res["telemetry"] = snap["per_app"]["app"]
        res["mtbf_estimate_s"] = snap["per_app"]["app"]["mtbf_s"]
        res["commit_cost_estimate_s"] = \
            snap["per_app"]["app"]["commit_latency_s"]
        client.finalize()
    return res


def run_adaptive(verbose: bool = True, total_work_s: float = ADAPTIVE_WORK_S,
                 mtbf_s: float = ADAPTIVE_MTBF_S, seed: int = 0) -> dict:
    data = np.random.default_rng(1).standard_normal(
        ADAPTIVE_PAYLOAD // 4).astype(np.float32)
    failures = failure_schedule(mtbf_s, 4.0 * total_work_s, seed=seed)
    fixed = _interval_policy_run(False, data, failures, total_work_s)
    adaptive = _interval_policy_run(True, data, failures, total_work_s)
    out = {
        "payload": ADAPTIVE_PAYLOAD,
        "injected_mtbf_s": mtbf_s,
        "fixed_interval_s": FIXED_INTERVAL_S,
        "fixed": fixed,
        "adaptive": adaptive,
        "overhead_reduction": 1.0 - adaptive["total_overhead_s"]
        / max(fixed["total_overhead_s"], 1e-9),
    }
    save("b5a_adaptive_interval", out)
    if verbose:
        print(f"\nB5A adaptive checkpoint interval "
              f"({fmt_bytes(ADAPTIVE_PAYLOAD)} ckpt, injected MTBF "
              f"{mtbf_s:.0f}s, {total_work_s:.0f}s of work):")
        for name, r in (("fixed", fixed), ("adaptive", adaptive)):
            print(f"  {name:9s} interval={r['final_interval_s']:7.2f}s "
                  f"commits={r['commits']:4d} failures={r['failures']:2d} "
                  f"wasted={r['wasted_work_s']:7.2f}s "
                  f"ckpt={r['ckpt_overhead_s']:6.2f}s "
                  f"total_overhead={r['total_overhead_s']:7.2f}s")
        print(f"  telemetry estimates (adaptive leg): "
              f"C={adaptive['commit_cost_estimate_s']:.3f}s "
              f"MTBF={adaptive['mtbf_estimate_s']:.1f}s")
        print(f"  adaptive cuts total overhead by "
              f"{100 * out['overhead_reduction']:.0f}%")
    assert adaptive["total_overhead_s"] < fixed["total_overhead_s"], \
        "adaptive interval must beat the mis-tuned fixed interval"
    return out


# ------------------------------------------------------------ trace smoke
TRACE_PAYLOAD = 16 << 20
TRACE_PARTS = 8
TRACE_WORK_S = 60.0
TRACE_INTERVAL_S = 6.0
TRACE_OVERHEAD_TOL = 0.03


def _trace_leg(data, trace: bool, trace_path=None) -> dict:
    """One tracing leg: identical cluster + checkpoint workload, only the
    tracer differs.  Spans read the sim clock but never advance it, so the
    traced leg's sim-time throughput must match the untraced one."""
    with ICheckCluster(n_icheck_nodes=2, n_spare_nodes=0,
                       node_memory=2 << 30, nic_bandwidth=1e9,
                       trace=trace, trace_path=trace_path) as c:
        client = ICheckClient("app", c.controller, ranks=TRACE_PARTS).init(
            ckpt_bytes_estimate=data.nbytes)
        client.add_adapt("x", data.shape, "float32", num_parts=TRACE_PARTS)
        parts = {"x": block_parts(data, TRACE_PARTS)}
        res = run_ckpt_workload(c, client, parts, TRACE_WORK_S, [],
                                interval_fn=lambda: TRACE_INTERVAL_S)
        res["spans"] = len(c.tracer.spans())
        client.finalize()
    return res


def run_trace_smoke(verbose: bool = True) -> dict:
    """B5T -- tracing overhead: sim-time throughput of a checkpointing
    workload with end-to-end tracing enabled must stay within
    ``TRACE_OVERHEAD_TOL`` of the untraced run, and the traced leg exports
    a Chrome ``trace_event`` artifact for Perfetto."""
    data = np.random.default_rng(2).standard_normal(
        TRACE_PAYLOAD // 4).astype(np.float32)
    os.makedirs(OBS_DIR, exist_ok=True)
    trace_path = os.path.abspath(os.path.join(OBS_DIR, "trace_smoke.json"))
    base = _trace_leg(data, trace=False)
    traced = _trace_leg(data, trace=True, trace_path=trace_path)
    # sim throughput = work_s / elapsed_sim_s over the same work, so the
    # traced/untraced throughput ratio is the inverse elapsed ratio
    ratio = base["elapsed_sim_s"] / max(traced["elapsed_sim_s"], 1e-12)
    out = {
        "payload": TRACE_PAYLOAD,
        "base": base,
        "traced": traced,
        "throughput_ratio": ratio,
        "trace_path": trace_path,
    }
    save("b5t_trace_overhead", out)
    if verbose:
        print(f"\nB5T tracing overhead ({fmt_bytes(TRACE_PAYLOAD)} ckpt, "
              f"{TRACE_WORK_S:.0f}s of work):")
        print(f"  untraced: {base['elapsed_sim_s']:.3f}s sim, "
              f"{base['commits']} commits")
        print(f"  traced:   {traced['elapsed_sim_s']:.3f}s sim, "
              f"{traced['commits']} commits, {traced['spans']} spans")
        print(f"  throughput ratio (traced/untraced): {ratio:.4f}")
        print(f"  chrome trace: {trace_path}")
    assert traced["spans"] > 0, "tracing was enabled but produced no spans"
    assert abs(1.0 - ratio) <= TRACE_OVERHEAD_TOL, \
        (f"tracing changed sim-time throughput by "
         f"{100 * abs(1.0 - ratio):.2f}% "
         f"(> {100 * TRACE_OVERHEAD_TOL:.0f}% tolerance)")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--adaptive", action="store_true",
                    help="run the adaptive-interval wasted-work comparison")
    ap.add_argument("--trace-smoke", action="store_true",
                    help="run the tracing-overhead comparison")
    args = ap.parse_args(argv)
    if args.adaptive:
        run_adaptive()
    elif args.trace_smoke:
        run_trace_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
