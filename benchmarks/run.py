"""Benchmark driver: one benchmark per paper claim (DESIGN.md SS6).

  PYTHONPATH=src python -m benchmarks.run [--only b1,b3] [--smoke]

``--smoke`` runs the seconds-scale perf canary (b1 + b2 at tiny payloads)
used by CI to catch control/data-plane throughput regressions.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (bench_async_overlap, bench_codec, bench_multiapp,
               bench_redistribution, bench_restart, bench_serving,
               bench_transfer, roofline)

ALL = {
    "b1": ("agent-count transfer knee", bench_transfer.run),
    "b2": ("async commit overlap", bench_async_overlap.run),
    "b3": ("redistribution", bench_redistribution.run),
    "b4": ("multi-app adaptivity", bench_multiapp.run),
    "b5": ("multilevel restart", bench_restart.run),
    "b6": ("checkpoint codec", bench_codec.run),
    "b7": ("roofline table", roofline.run),
    "b8": ("serving decode", bench_serving.run),
}

SMOKE = {
    "b1": ("agent-count transfer knee (smoke)", bench_transfer.run_smoke),
    "b2": ("async commit overlap (smoke)", bench_async_overlap.run_smoke),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. b1,b3")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale perf canary (CI)")
    args = ap.parse_args(argv)
    table = SMOKE if args.smoke else ALL
    names = list(table) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in table]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; have {sorted(table)}")
    failures = []
    t0 = time.monotonic()
    for name in names:
        desc, fn = table[name]
        print(f"\n===== {name.upper()}: {desc} =====")
        try:
            t = time.monotonic()
            fn(verbose=True)
            print(f"[{name} done in {time.monotonic() - t:.1f}s]")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n===== benchmarks finished in {time.monotonic() - t0:.1f}s =====")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL BENCHMARKS PASS")


if __name__ == "__main__":
    main()
