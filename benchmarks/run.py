"""Benchmark driver: one benchmark per paper claim (DESIGN.md SS6).

  PYTHONPATH=src python -m benchmarks.run [--only b1,b3] [--smoke]

``--smoke`` runs the seconds-scale perf canary (b1 + b2 at tiny payloads)
used by CI to catch control/data-plane throughput regressions.  It writes
``BENCH_smoke.json`` (deterministic sim-time metrics; compared against the
committed baseline by ``benchmarks/check_regression.py``) and exits
non-zero the moment any sub-benchmark raises — a crashed benchmark must
fail the CI perf job, not green-wash it.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from . import (bench_async_overlap, bench_codec, bench_delta, bench_erasure,
               bench_multiapp, bench_recovery, bench_redistribution,
               bench_restart, bench_serving, bench_tiering, bench_transfer,
               roofline)

ALL = {
    "b1": ("agent-count transfer knee", bench_transfer.run),
    "b2": ("async commit overlap", bench_async_overlap.run),
    "b3": ("redistribution", bench_redistribution.run),
    "b4": ("multi-app adaptivity", bench_multiapp.run),
    "b4a": ("adaptive per-app ckpt intervals", bench_multiapp.run_adaptive),
    "b5": ("multilevel restart", bench_restart.run),
    "b5a": ("adaptive ckpt interval vs fixed", bench_restart.run_adaptive),
    "b6": ("checkpoint codec", bench_codec.run),
    "b7": ("roofline table", roofline.run),
    "b8": ("serving decode", bench_serving.run),
    "b9": ("storage lifecycle tiering", bench_tiering.run),
    "b10": ("incremental delta checkpointing", bench_delta.run),
    "b11": ("erasure-coded durability", bench_erasure.run),
    "b12": ("crash-consistent control plane", bench_recovery.run),
}

SMOKE = {
    "b1": ("agent-count transfer knee (smoke)", bench_transfer.run_smoke),
    "b2": ("async commit overlap (smoke)", bench_async_overlap.run_smoke),
    # b9 runs before b3/b10: it *writes* BENCH_prometheus.txt, they append
    "b9": ("storage lifecycle tiering (smoke)", bench_tiering.run_smoke),
    "b3": ("peer redistribution (smoke)", bench_redistribution.run_smoke),
    "b10": ("incremental delta checkpointing (smoke)",
            bench_delta.run_smoke),
    "b5t": ("tracing overhead (smoke)", bench_restart.run_trace_smoke),
    "b11": ("erasure-coded durability (smoke)", bench_erasure.run_smoke),
    "b12": ("crash-consistent control plane (smoke)",
            bench_recovery.run_smoke),
}

SMOKE_JSON = "BENCH_smoke.json"


def smoke_metrics(results: dict) -> dict:
    """Flat, deterministic (sim-time-derived) metrics for the CI regression
    gate.  Higher-is-better throughput/overlap numbers, except the metrics
    listed in ``check_regression.LOWER_IS_BETTER`` (currently
    ``b3_stall_s``)."""
    metrics = {}
    b1 = results.get("b1")
    if b1:
        metrics["b1_max_rate_Bps"] = max(r["rate_Bps"] for r in b1["rows"])
        metrics["b1_single_agent_rate_Bps"] = b1["rows"][0]["rate_Bps"]
    b2 = results.get("b2")
    if b2:
        metrics["b2_hidden_fraction"] = b2["hidden_fraction"]
        metrics["b2_commit_rate_Bps"] = b2["payload"] / max(
            b2["async_transfer_sim_s_hidden"], 1e-12)
    b3 = results.get("b3")
    if b3:
        row = b3["rows"][-1]
        # higher-is-better: adapt-window speedup of the peer path over the
        # client funnel, and how many times fewer bytes the client sees
        metrics["b3_peer_speedup"] = row["peer_speedup"]
        metrics["b3_bytes_through_client_reduction"] = \
            row["bytes_through_client_reduction"]
        stall = b3.get("stall")
        if stall:
            # the bounded cutover stall of a zero-stall resize — the one
            # LOWER-is-better smoke metric (check_regression flips its
            # comparison) — and the work retained inside the window
            metrics["b3_stall_s"] = stall["stall_s"]
            metrics["b3_overlap_steps"] = stall["overlap_steps"]
    b9 = results.get("b9")
    if b9:
        metrics["b9_lifecycle_commit_rate_Bps"] = \
            b9["pressure"]["lifecycle"]["commit_rate_Bps"]
        metrics["b9_l2_restart_rate_Bps"] = \
            b9["l3_restart"]["l2"]["rate_Bps"]
        metrics["b9_l3_restart_rate_Bps"] = \
            b9["l3_restart"]["l3_cold"]["rate_Bps"]
    b10 = results.get("b10")
    if b10:
        low, high = b10["low_churn"], b10["high_churn"]
        metrics["b10_delta_lowchurn_wire_ratio"] = \
            low["q8-delta"]["wire_reduction_vs_raw"]
        metrics["b10_delta_commit_rate_Bps"] = \
            low["q8-delta"]["commit_rate_Bps"]
        # >=1 means q8-delta never ships more bytes than plain q8
        metrics["b10_delta_highchurn_vs_q8"] = (
            high["q8"]["steady_wire_bytes"]
            / max(high["q8-delta"]["steady_wire_bytes"], 1))
    b11 = results.get("b11")
    if b11:
        metrics["b11_ec_commit_rate_Bps"] = b11["ec"]["commit_rate_Bps"]
        metrics["b11_l1_ratio"] = b11["ec"]["l1_ratio"]
        metrics["b11_rebuild_s"] = b11["rebuild"]["rebuild_sim_s"]
    b12 = results.get("b12")
    if b12:
        # warm recovery must stay cheap in absolute sim terms and keep its
        # margin over the cold L3 manifest scan; the journal's commit-path
        # tax must stay ~zero (both *_s/_pct metrics are lower-is-better)
        metrics["b12_warm_recover_s"] = b12["warm"]["warm_recover_sim_s"]
        metrics["b12_warm_speedup"] = b12["warm_speedup"]
        metrics["b12_journal_overhead_pct"] = \
            b12["overhead"]["journal_overhead_pct"]
    b5t = results.get("b5t")
    if b5t:
        # ~1.0 by construction (spans observe the sim clock, never load
        # it); a drop means tracing started costing sim time
        metrics["b5t_trace_throughput_ratio"] = b5t["throughput_ratio"]
    return metrics


def _write_smoke_json(results: dict, failures: list) -> None:
    payload = {
        "metrics": smoke_metrics(results),
        "results": results,
        "failures": failures,
        "ok": not failures,
    }
    with open(SMOKE_JSON, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[smoke metrics written to {SMOKE_JSON}]")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. b1,b3")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale perf canary (CI)")
    args = ap.parse_args(argv)
    table = SMOKE if args.smoke else ALL
    names = list(table) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in table]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; have {sorted(table)}")
    failures = []
    results = {}
    t0 = time.monotonic()
    for name in names:
        desc, fn = table[name]
        print(f"\n===== {name.upper()}: {desc} =====")
        try:
            t = time.monotonic()
            results[name] = fn(verbose=True)
            print(f"[{name} done in {time.monotonic() - t:.1f}s]")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append({"bench": name, "error": repr(e)})
            if args.smoke:
                # CI perf canary: a crashed sub-benchmark must abort the
                # run with a non-zero exit, never print-and-continue
                _write_smoke_json(results, failures)
                print(f"SMOKE FAILED at {name}: {e!r}")
                sys.exit(1)
    if args.smoke:
        _write_smoke_json(results, failures)
    print(f"\n===== benchmarks finished in {time.monotonic() - t0:.1f}s =====")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL BENCHMARKS PASS")


if __name__ == "__main__":
    main()
