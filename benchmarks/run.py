"""Benchmark driver: one benchmark per paper claim (DESIGN.md SS6).

  PYTHONPATH=src python -m benchmarks.run [--only b1,b3]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (bench_async_overlap, bench_codec, bench_multiapp,
               bench_redistribution, bench_restart, bench_serving,
               bench_transfer, roofline)

ALL = {
    "b1": ("agent-count transfer knee", bench_transfer.run),
    "b2": ("async commit overlap", bench_async_overlap.run),
    "b3": ("redistribution", bench_redistribution.run),
    "b4": ("multi-app adaptivity", bench_multiapp.run),
    "b5": ("multilevel restart", bench_restart.run),
    "b6": ("checkpoint codec", bench_codec.run),
    "b7": ("roofline table", roofline.run),
    "b8": ("serving decode", bench_serving.run),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. b1,b3")
    args = ap.parse_args(argv)
    names = list(ALL) if not args.only else args.only.split(",")
    failures = []
    t0 = time.monotonic()
    for name in names:
        desc, fn = ALL[name]
        print(f"\n===== {name.upper()}: {desc} =====")
        try:
            t = time.monotonic()
            fn(verbose=True)
            print(f"[{name} done in {time.monotonic() - t:.1f}s]")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n===== benchmarks finished in {time.monotonic() - t0:.1f}s =====")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL BENCHMARKS PASS")


if __name__ == "__main__":
    main()
