"""Shared benchmark helpers: fixed-count placement policy, result I/O."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence, Tuple

from repro.core.policies import NodeView, SchedulingPolicy
from repro.core.types import AppRecord

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


class FixedCountPolicy(SchedulingPolicy):
    """Places exactly ``n`` agents, round-robin across nodes (one per node
    first) -- the control knob for the agent-count sweep (B1)."""

    name = "fixed"

    def __init__(self, n: int):
        self.n = n

    def place(self, nodes: Sequence[NodeView], app: AppRecord):
        placement: Dict[str, int] = {}
        i = 0
        for _ in range(self.n):
            nv = nodes[i % len(nodes)]
            placement[nv.node_id] = placement.get(nv.node_id, 0) + 1
            i += 1
        return list(placement.items())


def save(name: str, payload: dict) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PiB"


def block_parts(arr, ranks: int):
    from repro.core import split_array
    from repro.core.types import PartitionDesc, PartitionScheme

    desc = PartitionDesc(scheme=PartitionScheme.BLOCK, num_parts=ranks)
    return {i: p for i, p in enumerate(split_array(arr, desc))}
