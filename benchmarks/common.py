"""Shared benchmark helpers: fixed-count placement policy, result I/O, and
the failure-injected compute/checkpoint workload used by the adaptive
interval benchmarks (bench_restart / bench_multiapp ``--adaptive``)."""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core import events as icheck_events
from repro.core.policies import NodeView, SchedulingPolicy
from repro.core.types import AppRecord

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


class FixedCountPolicy(SchedulingPolicy):
    """Places exactly ``n`` agents, round-robin across nodes (one per node
    first) -- the control knob for the agent-count sweep (B1)."""

    name = "fixed"

    def __init__(self, n: int):
        self.n = n

    def place(self, nodes: Sequence[NodeView], app: AppRecord):
        placement: Dict[str, int] = {}
        i = 0
        for _ in range(self.n):
            nv = nodes[i % len(nodes)]
            placement[nv.node_id] = placement.get(nv.node_id, 0) + 1
            i += 1
        return list(placement.items())


def save(name: str, payload: dict) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PiB"


def block_parts(arr, ranks: int):
    from repro.core import split_array
    from repro.core.types import PartitionDesc, PartitionScheme

    desc = PartitionDesc(scheme=PartitionScheme.BLOCK, num_parts=ranks)
    return {i: p for i, p in enumerate(split_array(arr, desc))}


def failure_schedule(mtbf_s: float, horizon_s: float, seed: int = 0,
                     t0: float = 0.0) -> List[float]:
    """Absolute failure times: exponential inter-arrivals, mean ``mtbf_s``.

    The same schedule is replayed against every policy under comparison so
    fixed vs adaptive intervals see identical fault sequences.
    """
    rng = np.random.default_rng(seed)
    times, t = [], t0
    while t < t0 + 3.0 * horizon_s:
        t += float(rng.exponential(mtbf_s))
        times.append(t)
    return times


def run_ckpt_workload(cluster, client, parts: Dict[str, dict],
                      total_work_s: float, failure_times: Sequence[float],
                      interval_fn: Callable[[], float],
                      work_slice_s: float = 0.05, keep_l1: int = 2,
                      resize_probe: Callable[[], bool] = None,
                      on_tick: Callable[[float], None] = None,
                      on_restart: Callable[[object], None] = None) -> dict:
    """Drive a simulated compute loop with checkpoints on the cluster clock.

    The application "computes" by advancing the sim clock in slices; every
    ``interval_fn()`` sim-seconds it commits (blocking, so commit cost lands
    on the clock too).  Injected rank failures (absolute sim times from
    ``failure_times``) are published on the controller bus — exactly what
    feeds the TelemetryService's MTBF estimate — and roll the app back to
    its latest checkpoint: everything computed since is *wasted work*.

    Returns the wasted-work / checkpoint-overhead / restart-cost accounting
    that the adaptive-interval benchmarks compare across policies.

    ``resize_probe`` (optional) is sampled once per work slice: while it
    returns True the app is inside an adapt window that it *kept stepping
    through* (a zero-stall overlap resize), and the slice is counted into
    ``steps_during_resize`` / ``work_during_resize_s`` — the work a
    stop-the-world resize would have forfeited.

    ``on_tick`` (optional) is called with the current sim time once per
    loop iteration — the chaos campaign runner drives its injector (and
    data churn) through this hook so scheduled faults land at deterministic
    sim-time offsets relative to the workload.  ``on_restart`` (optional)
    receives the full ``client.restart()`` result — ``(meta, parts, level)``
    or None — after every injected rank failure, so an oracle can check the
    restored bytes (the workload itself only accounts the restart cost).
    """
    clock, bus = cluster.clock, cluster.controller.bus
    app_id = client.app_id
    step = 0
    start_t = clock.now()
    # priming commit: gives the telemetry its first commit-cost sample and
    # the workload a time-zero restart point
    t0 = clock.now()
    client.commit(step, parts, blocking=True, drain=False)
    step += 1
    ckpt_overhead_s = clock.now() - t0
    commits, failures = 1, 0
    wasted_s = restart_s = 0.0
    steps_during_resize = 0
    work_during_resize_s = 0.0
    work_done = 0.0
    work_at_ckpt = 0.0
    last_ckpt_t = clock.now()
    ckpt_ids = [0]
    fail_iter = iter(sorted(failure_times))
    next_fail = next(fail_iter, float("inf"))

    while work_done < total_work_s:
        now = clock.now()
        if on_tick is not None:
            on_tick(now)
        if now >= next_fail:
            # the rank dies: lose all work since the last checkpoint
            bus.publish(icheck_events.APP_RANK_FAILED, app=app_id, rank=0)
            failures += 1
            wasted_s += work_done - work_at_ckpt
            work_done = work_at_ckpt
            t0 = clock.now()
            restored = client.restart()
            restart_s += clock.now() - t0
            if on_restart is not None:
                on_restart(restored)
            next_fail = next(fail_iter, float("inf"))
            last_ckpt_t = clock.now()
            continue
        if now - last_ckpt_t >= interval_fn():
            t0 = clock.now()
            client.commit(step, parts, blocking=True, drain=False)
            ckpt_overhead_s += clock.now() - t0
            ckpt_ids.append(step)
            step += 1
            commits += 1
            work_at_ckpt = work_done
            last_ckpt_t = clock.now()
            # keep L1 bounded without involving the drain path (drain=False
            # keeps the PFS out of the timeline): drop all but the newest
            # keep_l1 checkpoints from every node's tier pipeline
            for old in ckpt_ids[:-keep_l1]:
                for mgr in cluster.controller.managers():
                    mgr.store.drop_checkpoint(app_id, old)
            del ckpt_ids[:-keep_l1]
            continue
        dt = min(work_slice_s, total_work_s - work_done,
                 max(next_fail - now, 1e-9))
        clock.sleep(dt)
        work_done += dt
        if resize_probe is not None and resize_probe():
            steps_during_resize += 1
            work_during_resize_s += dt

    elapsed = clock.now() - start_t
    return {
        "total_work_s": total_work_s,
        "elapsed_sim_s": elapsed,
        "commits": commits,
        "failures": failures,
        "wasted_work_s": wasted_s,
        "ckpt_overhead_s": ckpt_overhead_s,
        "restart_s": restart_s,
        "total_overhead_s": wasted_s + ckpt_overhead_s + restart_s,
        "final_interval_s": interval_fn(),
        "steps_during_resize": steps_during_resize,
        "work_during_resize_s": work_during_resize_s,
    }
