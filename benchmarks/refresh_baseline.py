"""Deliberate perf-baseline refresh — one command instead of hand-edited
JSON.

Runs the smoke suite (unless ``--from-current`` points at an existing
``BENCH_smoke.json``), then rewrites ``benchmarks/baseline_smoke.json`` with
the fresh metrics and prints the metric-by-metric delta against the old
baseline so the refresh is an informed decision, not a blind overwrite.

  python -m benchmarks.refresh_baseline                # run smoke + refresh
  python -m benchmarks.refresh_baseline --from-current BENCH_smoke.json

A refresh is the right move when a change *legitimately* shifts throughput
(new hardware model, new benchmark, a deliberate trade-off) — never to
silence a regression the gate just caught.  The regression gate
(``check_regression.py``) only starts tracking a new metric once it appears
in the committed baseline, which is exactly what this helper does.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baseline_smoke.json")
DEFAULT_CURRENT = "BENCH_smoke.json"


def _load(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"ERROR: cannot read {path}: {e}")
        sys.exit(2)
    if not isinstance(data.get("metrics"), dict):
        print(f"ERROR: {path} has no 'metrics' block")
        sys.exit(2)
    return data


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="refresh the committed smoke-benchmark baseline")
    ap.add_argument("--from-current", metavar="JSON", default=None,
                    help="use an existing BENCH_smoke.json instead of "
                         "running the smoke suite")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file to rewrite")
    args = ap.parse_args(argv)

    if args.from_current is None:
        print("running the smoke suite (python -m benchmarks.run --smoke)…")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke"],
            cwd=os.path.dirname(HERE))
        if proc.returncode != 0:
            print("ERROR: smoke run failed — refusing to refresh the "
                  "baseline from a broken run")
            sys.exit(1)
        current_path = os.path.join(os.path.dirname(HERE), DEFAULT_CURRENT)
    else:
        current_path = args.from_current

    current = _load(current_path)
    if not current.get("ok", True) or current.get("failures"):
        print(f"ERROR: {current_path} reports failures: "
              f"{current.get('failures')} — refusing to refresh")
        sys.exit(1)

    old_metrics = {}
    if os.path.exists(args.baseline):
        old_metrics = _load(args.baseline).get("metrics", {})

    print(f"\n{'metric':35s} {'old':>14s} {'new':>14s}")
    for name in sorted(set(old_metrics) | set(current["metrics"])):
        old = old_metrics.get(name)
        new = current["metrics"].get(name)
        old_s = f"{old:14.4g}" if old is not None else f"{'(none)':>14s}"
        new_s = f"{new:14.4g}" if new is not None else f"{'REMOVED':>14s}"
        delta = ""
        if old and new:
            delta = f"  {100 * (new - old) / old:+.1f}%"
        print(f"{name:35s} {old_s} {new_s}{delta}")

    with open(args.baseline, "w") as f:
        json.dump(current, f, indent=1, default=float)
        f.write("\n")
    print(f"\nbaseline refreshed: {args.baseline}")
    print("commit it together with the change that justified the refresh.")


if __name__ == "__main__":
    main()
