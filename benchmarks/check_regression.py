"""CI perf regression gate.

Compares the metrics block of a fresh ``BENCH_smoke.json`` (written by
``python -m benchmarks.run --smoke``) against the committed baseline and
fails (exit 1) when any throughput metric regresses by more than the
threshold (default 15%).  All smoke metrics are simulated-time derived and
therefore deterministic across machines — a regression means the code got
slower in sim terms (extra copies, broken overlap, serialized transfers),
not that the runner was noisy.

  python benchmarks/check_regression.py \
      --baseline benchmarks/baseline_smoke.json --current BENCH_smoke.json

Exit codes: 0 ok, 1 regression/crashed run, 2 usage or malformed input.
"""
from __future__ import annotations

import argparse
import json
import sys

# smoke metrics are higher-is-better unless listed in LOWER_IS_BETTER; a new
# metric added to the current file without a baseline entry is reported but
# does not fail the gate (the baseline must be refreshed deliberately to
# start tracking it)
DEFAULT_THRESHOLD = 0.15

# metrics where a *rise* is the regression (latencies/stalls): the delta
# comparison is flipped for these
LOWER_IS_BETTER = {"b3_stall_s", "b11_l1_ratio", "b11_rebuild_s",
                   "b12_warm_recover_s", "b12_journal_overhead_pct"}


def load(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"ERROR: cannot read {path}: {e}")
        sys.exit(2)
    if not isinstance(data.get("metrics"), dict):
        print(f"ERROR: {path} has no 'metrics' block")
        sys.exit(2)
    return data


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max tolerated fractional regression (0.15 = 15%%)")
    args = ap.parse_args(argv)

    base = load(args.baseline)
    cur = load(args.current)
    # a crashed/failed run still gets the full metric comparison below —
    # the report must show *everything* that regressed, not bail at the
    # first bad signal and hide the rest from the CI log
    regressions = []
    if not cur.get("ok", True) or cur.get("failures"):
        for failure in cur.get("failures") or ("run reports ok=false",):
            regressions.append(f"current run failure: {failure}")
    print(f"{'metric':35s} {'baseline':>14s} {'current':>14s} {'delta':>8s}")
    for name, base_val in sorted(base["metrics"].items()):
        cur_val = cur["metrics"].get(name)
        if cur_val is None:
            regressions.append(f"{name}: missing from current run")
            print(f"{name:35s} {base_val:14.4g} {'MISSING':>14s}")
            continue
        if base_val <= 0:
            print(f"{name:35s} {base_val:14.4g} {cur_val:14.4g}   (skip)")
            continue
        delta = (cur_val - base_val) / base_val
        flag = ""
        if name in LOWER_IS_BETTER:
            if delta > args.threshold:
                regressions.append(
                    f"{name}: {base_val:.4g} -> {cur_val:.4g} "
                    f"({100 * delta:+.1f}% > +{100 * args.threshold:.0f}%, "
                    f"lower-is-better)")
                flag = "  << REGRESSION"
        elif delta < -args.threshold:
            regressions.append(
                f"{name}: {base_val:.4g} -> {cur_val:.4g} "
                f"({100 * delta:+.1f}% < -{100 * args.threshold:.0f}%)")
            flag = "  << REGRESSION"
        print(f"{name:35s} {base_val:14.4g} {cur_val:14.4g} "
              f"{100 * delta:+7.1f}%{flag}")
    for name in sorted(set(cur["metrics"]) - set(base["metrics"])):
        print(f"{name:35s} {'(new)':>14s} {cur['metrics'][name]:14.4g}")

    if regressions:
        print("\nPERF GATE FAILED (threshold "
              f"{100 * args.threshold:.0f}%) — all findings:")
        for r in regressions:
            print(f"  - {r}")
        sys.exit(1)
    print("\nperf gate OK: no metric regressed beyond "
          f"{100 * args.threshold:.0f}%")


if __name__ == "__main__":
    main()
