"""B11 -- erasure-coded L1 durability: m-failure survival at 1.25x memory.

Three experiments against the same logical payload:

  * **commit-rate overhead**: the steady-state commit path under
    ``durability="ec"`` (k=4, m=1 -> 1.25x bytes on the wire) vs the 2x
    replication baseline it replaces.  The erasure commit must cost at
    most 15% more sim time than replication (in practice it is *faster*:
    it ships 1.25x bytes instead of 2x).

  * **rebuild after m simultaneous deaths** (k=4, m=2): kill m agents
    spanning two nodes after a committed stripe -- the restore must stay
    bit-identical to the numpy oracle -- then kill a whole node (losing
    exactly m fragments of every stripe) and time the health monitor's
    peer rebuild: surviving agents GF-decode any k fragments and re-host
    the lost ones, no whole-shard re-replication, no PFS involved.

  * **L1 occupancy**: bytes resident in L1 per durable shard must stay
    <= 1.35x the raw payload for (k=4, m=1) -- the (k+m)/k = 1.25 stripe
    plus per-fragment framing.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import numpy as np

from repro.core import ICheckClient, ICheckCluster
from repro.core import events as E
from repro.kernels.ckpt_codec.rs import rs_decode_np, split_rows

from .common import block_parts, fmt_bytes, save

EC_K = 4
EC_M = 1               # commit/occupancy legs: the 1.25x configuration
REBUILD_M = 2          # rebuild leg: survive a whole-node loss on 3 nodes
PARTS = 4

PAYLOAD = 8 << 20
COMMITS = 6
SMOKE_PAYLOAD = 1 << 20
SMOKE_COMMITS = 3

MAX_COMMIT_OVERHEAD = 0.15     # vs 2x replication, asserted below
MAX_L1_RATIO = 1.35            # (k+m)/k = 1.25 plus framing, asserted below
REBUILD_WALL_S = 30.0


def _restart_when_ready(client, wall_s: float = REBUILD_WALL_S):
    """Restart, waiting out the health monitor's replacement launches --
    right after a kill the surviving fragment set may be temporarily
    unreachable until replacement agents re-attach the node stores."""
    deadline = time.monotonic() + wall_s
    while True:
        got = client.restart()
        if got is not None:
            return got
        if time.monotonic() >= deadline:
            raise AssertionError("no restartable checkpoint after kill")
        time.sleep(0.05)


def _commit_leg(durability: str, payload: int, n_commits: int) -> dict:
    """One steady-state commit leg; only the durability scheme differs."""
    data = np.arange(payload // 4, dtype=np.float32)
    kwargs = dict(durability="ec", ec_k=EC_K, ec_m=EC_M) \
        if durability == "ec" else dict(replication=2)
    with ICheckCluster(n_icheck_nodes=EC_K + EC_M, n_spare_nodes=0,
                       node_memory=8 * payload,
                       adaptive_interval=False) as c:
        client = ICheckClient("app", c.controller, ranks=PARTS,
                              **kwargs).init(ckpt_bytes_estimate=payload)
        client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
        commit_sim_s = 0.0
        for step in range(n_commits):
            h = client.commit(step, {"x": block_parts(data + step, PARTS)},
                              blocking=True, drain=False)
            commit_sim_s += h.sim_duration
        # L1 bytes actually resident for the newest checkpoint vs its raw
        # payload -- the memory price of the durability scheme
        last = h.meta.ckpt_id
        resident = 0
        for mgr in c.controller.managers():
            for key in mgr.store.keys():
                if key.app_id == "app" and key.ckpt_id == last:
                    resident += len(mgr.store.get(key, verify=False))
        meta, parts, level = client.restart()
        got = np.concatenate([parts["x"][i] for i in range(PARTS)])
        np.testing.assert_array_equal(got, data + meta.step)
        client.finalize()
        return {
            "durability": durability,
            "commit_sim_s": commit_sim_s,
            "commit_rate_Bps": n_commits * payload / max(commit_sim_s,
                                                         1e-12),
            "l1_resident_bytes": resident,
            "l1_ratio": resident / payload,
        }


def _rebuild_leg(payload: int) -> dict:
    """m simultaneous agent deaths (spanning two nodes), then a whole-node
    loss; every stripe must come back via peer rebuild, bit-identical."""
    k, m = EC_K, REBUILD_M
    data = np.arange(payload // 4, dtype=np.float32)
    with ICheckCluster(n_icheck_nodes=3, n_spare_nodes=0,
                       node_memory=8 * payload,
                       adaptive_interval=False) as c:
        ctl = c.controller
        client = ICheckClient("app", ctl, ranks=PARTS, durability="ec",
                              ec_k=k, ec_m=m).init(
            ckpt_bytes_estimate=payload)
        client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
        client.commit(0, {"x": block_parts(data, PARTS)}, blocking=True,
                      drain=False)

        # numpy oracle for one stripe: decoding any k surviving fragments
        # of part 0 must reproduce the exact committed bytes
        part0 = np.ascontiguousarray(block_parts(data, PARTS)[0]).tobytes()
        frags: Dict[int, bytes] = {}
        from repro.core.tiers import ec_parse_fragment
        for mgr in ctl.managers():
            for key in mgr.store.keys():
                if key.app_id == "app" and key.region == "x" \
                        and key.part == 0:
                    _, _, idx, orig_len, _, row = ec_parse_fragment(
                        mgr.store.get(key, verify=False))
                    frags[idx] = row
        survivors = {i: np.frombuffer(frags[i], dtype=np.uint8)
                     for i in sorted(frags)[1:k + 1]}   # drop a data row
        oracle_rows = rs_decode_np(survivors, k, m)
        want_rows = split_rows(part0, k)
        assert all(np.array_equal(a, b)
                   for a, b in zip(oracle_rows, want_rows)), \
            "surviving fragments do not decode to the numpy oracle"

        # -- m agent deaths spanning two nodes -------------------------
        agents = ctl.agents_for("app")
        victims, nodes = [], set()
        for a in agents:
            if a.node_id not in nodes:
                victims.append(a)
                nodes.add(a.node_id)
            if len(victims) == m:
                break
        assert len({a.node_id for a in victims}) == 2, \
            "m deaths must span two nodes"
        for a in victims:
            c.fault.kill_agent(a.agent_id)
        meta, parts, level = _restart_when_ready(client)
        got = np.concatenate([parts["x"][i] for i in range(PARTS)])
        np.testing.assert_array_equal(got, data)

        # -- whole-node loss: exactly m fragments of every stripe ------
        victim_node = next(mg.node_id for mg in ctl.managers()
                           if any(key.app_id == "app"
                                  for key in mg.store.keys()))
        stripes = len({key.base() for mg in ctl.managers()
                       if mg.node_id == victim_node
                       for key in mg.store.keys()
                       if key.app_id == "app"})
        c.fault.kill_node(victim_node)
        deadline = time.monotonic() + REBUILD_WALL_S
        while time.monotonic() < deadline:
            ec = c.telemetry.snapshot()["ec"]
            if ec["rebuilds_done"] + ec["rebuilds_failed"] >= stripes:
                break
            time.sleep(0.02)
        ec = c.telemetry.snapshot()["ec"]
        assert ec["rebuilds_failed"] == 0, \
            f"{ec['rebuilds_failed']} stripe rebuilds failed"
        assert ec["rebuilds_done"] >= stripes
        rebuild_sim_s = sum(
            float(r.get("sim_s", 0.0)) for r in ctl.events
            if r["event"] == E.EC_REBUILD_DONE)

        meta, parts, level = _restart_when_ready(client)
        got = np.concatenate([parts["x"][i] for i in range(PARTS)])
        np.testing.assert_array_equal(got, data)
        client.finalize()
        return {
            "k": k,
            "m": m,
            "stripes_rebuilt": int(ec["rebuilds_done"]),
            "rebuild_sim_s": rebuild_sim_s,
            "rebuild_rate_Bps": ec["rebuild_bytes"] / max(rebuild_sim_s,
                                                          1e-12),
            "degraded_rebuilds": int(ec["rebuilds_degraded"]),
            "restore_level": level,
        }


def _run(payload: int, n_commits: int, verbose: bool, tag: str) -> dict:
    repl = _commit_leg("replicate", payload, n_commits)
    ec = _commit_leg("ec", payload, n_commits)
    rebuild = _rebuild_leg(payload)
    overhead = ec["commit_sim_s"] / max(repl["commit_sim_s"], 1e-12) - 1.0
    out = {
        "payload": payload,
        "commits": n_commits,
        "k": EC_K,
        "m": EC_M,
        "replicate": repl,
        "ec": ec,
        "commit_overhead_vs_replication": overhead,
        "rebuild": rebuild,
    }
    save(f"b11_erasure{tag}", out)
    if verbose:
        print(f"\nB11 commit path ({fmt_bytes(payload)} x{n_commits}, "
              f"k={EC_K} m={EC_M} vs 2x replication):")
        for leg in (repl, ec):
            print(f"  {leg['durability']:10s} "
                  f"commit={fmt_bytes(leg['commit_rate_Bps'])}/s "
                  f"L1={fmt_bytes(leg['l1_resident_bytes'])} "
                  f"({leg['l1_ratio']:.3f}x raw)")
        print(f"  overhead vs replication: {overhead * 100:+.1f}% "
              f"(gate: <{MAX_COMMIT_OVERHEAD * 100:.0f}%)")
        print(f"B11 rebuild (k={rebuild['k']} m={rebuild['m']}, "
              f"node loss = m fragments/stripe):")
        print(f"  {rebuild['stripes_rebuilt']} stripes in "
              f"{rebuild['rebuild_sim_s']:.6f}s sim "
              f"({fmt_bytes(rebuild['rebuild_rate_Bps'])}/s, "
              f"{rebuild['degraded_rebuilds']} degraded)")
    # the claims this benchmark exists to demonstrate, enforced:
    assert overhead < MAX_COMMIT_OVERHEAD, \
        f"EC commit overhead {overhead:.2%} >= {MAX_COMMIT_OVERHEAD:.0%}"
    assert ec["l1_ratio"] <= MAX_L1_RATIO, \
        f"EC L1 ratio {ec['l1_ratio']:.3f} > {MAX_L1_RATIO}"
    assert repl["l1_ratio"] >= 1.9, \
        "the replication baseline must actually pay ~2x memory"
    assert rebuild["stripes_rebuilt"] >= PARTS
    return out


def run(verbose: bool = True) -> dict:
    return _run(PAYLOAD, COMMITS, verbose, tag="")


def run_smoke(verbose: bool = True) -> dict:
    return _run(SMOKE_PAYLOAD, SMOKE_COMMITS, verbose, tag="_smoke")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run_smoke() if args.smoke else run()


if __name__ == "__main__":
    main()
