"""B1 -- aggregate checkpoint transfer rate vs. #agents (paper SSII:
"iCheck can dynamically change the agent count to obtain an optimum
checkpoint transfer rate").

Agents on distinct iCheck nodes add NIC capacity; agents sharing a node
share its NIC -- the rate curve therefore has a knee at #agents == #nodes,
which is exactly what ``icheck_probe_agents`` adapts toward.
"""
from __future__ import annotations

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import FixedCountPolicy, block_parts, fmt_bytes, save

NODES = 8
NIC_BW = 25e9
PAYLOAD = 256 << 20      # 256 MiB checkpoint
PARTS = 32


def run(verbose: bool = True) -> dict:
    rows = []
    data = np.random.default_rng(0).standard_normal(
        PAYLOAD // 4).astype(np.float32)
    for n_agents in (1, 2, 4, 6, 8, 12, 16):
        with ICheckCluster(n_icheck_nodes=NODES, n_spare_nodes=0,
                           node_memory=4 << 30, nic_bandwidth=NIC_BW) as c:
            c.controller.policy = FixedCountPolicy(n_agents)
            client = ICheckClient("app", c.controller, ranks=PARTS).init(
                ckpt_bytes_estimate=PAYLOAD)
            client.add_adapt("x", data.shape, "float32", num_parts=PARTS)
            h = client.commit(0, {"x": block_parts(data, PARTS)},
                              blocking=True, drain=False)
            rate = PAYLOAD / max(h.sim_duration, 1e-9)
            rows.append({"agents": n_agents, "sim_s": h.sim_duration,
                         "rate_Bps": rate})
            client.finalize()
    # knee: first agent count reaching ~the saturated (max) rate
    max_rate = max(r["rate_Bps"] for r in rows)
    knee = next(r["agents"] for r in rows
                if r["rate_Bps"] >= 0.95 * max_rate)
    out = {"nodes": NODES, "payload": PAYLOAD, "rows": rows, "knee": knee}
    save("b1_transfer", out)
    if verbose:
        print(f"\nB1 transfer rate vs agents ({NODES} nodes, "
              f"{fmt_bytes(PAYLOAD)} ckpt, NIC {fmt_bytes(NIC_BW)}/s):")
        for r in rows:
            bar = "#" * int(r["rate_Bps"] / (NIC_BW / 4))
            print(f"  agents={r['agents']:3d}  rate={fmt_bytes(r['rate_Bps'])}/s "
                  f"({r['sim_s']:.3f}s sim)  {bar}")
        print(f"  knee at ~{knee} agents (= node count: NIC-bound beyond)")
    return out


if __name__ == "__main__":
    run()
