"""B1 -- aggregate checkpoint transfer rate vs. #agents (paper SSII:
"iCheck can dynamically change the agent count to obtain an optimum
checkpoint transfer rate").

Agents on distinct iCheck nodes add NIC capacity; agents sharing a node
share its NIC -- the rate curve therefore has a knee at #agents == #nodes,
which is exactly what ``icheck_probe_agents`` adapts toward.
"""
from __future__ import annotations

import numpy as np

from repro.core import ICheckClient, ICheckCluster

from .common import FixedCountPolicy, block_parts, fmt_bytes, save

NODES = 8
NIC_BW = 25e9
PAYLOAD = 256 << 20      # 256 MiB checkpoint
PARTS = 32
AGENT_SWEEP = (1, 2, 4, 6, 8, 12, 16)


def run(verbose: bool = True, payload: int = PAYLOAD, parts: int = PARTS,
        nodes: int = NODES, agent_sweep=AGENT_SWEEP) -> dict:
    rows = []
    data = np.random.default_rng(0).standard_normal(
        payload // 4).astype(np.float32)
    for n_agents in agent_sweep:
        with ICheckCluster(n_icheck_nodes=nodes, n_spare_nodes=0,
                           node_memory=4 << 30, nic_bandwidth=NIC_BW) as c:
            c.controller.policy = FixedCountPolicy(n_agents)
            client = ICheckClient("app", c.controller, ranks=parts).init(
                ckpt_bytes_estimate=payload)
            client.add_adapt("x", data.shape, "float32", num_parts=parts)
            h = client.commit(0, {"x": block_parts(data, parts)},
                              blocking=True, drain=False)
            rate = payload / max(h.sim_duration, 1e-9)
            rows.append({"agents": n_agents, "sim_s": h.sim_duration,
                         "rate_Bps": rate})
            client.finalize()
    # knee: first agent count reaching ~the saturated (max) rate
    max_rate = max(r["rate_Bps"] for r in rows)
    knee = next(r["agents"] for r in rows
                if r["rate_Bps"] >= 0.95 * max_rate)
    out = {"nodes": nodes, "payload": payload, "rows": rows, "knee": knee}
    save("b1_transfer", out)
    if verbose:
        print(f"\nB1 transfer rate vs agents ({nodes} nodes, "
              f"{fmt_bytes(payload)} ckpt, NIC {fmt_bytes(NIC_BW)}/s):")
        for r in rows:
            bar = "#" * int(r["rate_Bps"] / (NIC_BW / 4))
            print(f"  agents={r['agents']:3d}  rate={fmt_bytes(r['rate_Bps'])}/s "
                  f"({r['sim_s']:.3f}s sim)  {bar}")
        print(f"  knee at ~{knee} agents (= node count: NIC-bound beyond)")
    return out


def run_smoke(verbose: bool = True) -> dict:
    """Seconds-scale perf canary for CI: tiny payload, short sweep."""
    return run(verbose=verbose, payload=4 << 20, parts=4, nodes=2,
               agent_sweep=(1, 2, 4))


if __name__ == "__main__":
    run()
