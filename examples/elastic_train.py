"""Elastic training (paper SSIII): the resource manager grows the job
mid-run; iCheck redistributes the TrainState through its agents and training
continues -- out-of-the-box malleability, no app-side re-initialization.

  PYTHONPATH=src python examples/elastic_train.py
"""
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import ICheckCluster
from repro.optim import AdamWConfig
from repro.train import ElasticTrainer


def main():
    cfg = get_config("qwen2.5-3b", tiny=True)
    shape = ShapeConfig("elastic", "train", seq_len=64, global_batch=8)

    with ICheckCluster(n_icheck_nodes=2) as cluster:
        trainer = ElasticTrainer(cfg, shape, cluster, app_id="elastic",
                                 ranks=2, seed=0,
                                 opt_cfg=AdamWConfig(lr=2e-3),
                                 commit_every=10, total_steps=60)
        print("phase 1: 2 ranks")
        trainer.run(20)
        l1 = trainer.metrics_log[-1]["loss"]

        print("RM grants 2 more ranks -> expand to 4 "
              "(adapt_begin / icheck_redistribute / adapt_commit)")
        cluster.rm.schedule_resize("elastic", 4)
        trainer.run(20)
        l2 = trainer.metrics_log[-1]["loss"]
        assert trainer.app.ranks == 4 and trainer.resizes == 1

        print("RM retakes 3 ranks -> shrink to 1")
        cluster.rm.schedule_resize("elastic", 1)
        trainer.run(20)
        l3 = trainer.metrics_log[-1]["loss"]
        assert trainer.app.ranks == 1 and trainer.resizes == 2

        trainer.finalize()
        print(f"loss: {trainer.metrics_log[0]['loss']:.3f} -> {l1:.3f} "
              f"-> {l2:.3f} -> {l3:.3f} across 2 resizes "
              f"(continuous trajectory)")


if __name__ == "__main__":
    main()
