"""Multi-application checkpointing (paper SSII/SSIV): one iCheck instance
serves a training job and a serving job simultaneously, scaling its own
nodes through the RM when memory runs out -- system-level malleability.

  PYTHONPATH=src python examples/multi_app.py
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import ICheckClient, ICheckCluster
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.serve import ServeEngine
from repro.train import ElasticTrainer


def main():
    with ICheckCluster(n_icheck_nodes=1, n_spare_nodes=3,
                       node_memory=2 << 20) as cluster:
        n0 = len(cluster.controller.managers())

        # app 1: a training job with periodic commits
        cfg_t = get_config("yi-6b", tiny=True)
        trainer = ElasticTrainer(cfg_t, ShapeConfig("t", "train", 32, 4),
                                 cluster, app_id="trainer", seed=0,
                                 opt_cfg=AdamWConfig(lr=1e-3),
                                 commit_every=5, total_steps=20)

        # app 2: a serving job checkpointing its KV cache after prefill
        cfg_s = get_config("qwen2.5-3b", tiny=True)
        params, _ = init_params(cfg_s, jax.random.key(1))
        engine = ServeEngine(cfg_s, params, max_len=64)
        serve_client = ICheckClient("server", cluster.controller).init()

        trainer.run(10)
        out = engine.generate(
            {"tokens": np.arange(16, dtype=np.int32)[None, :].repeat(2, 0)},
            gen_len=8, checkpoint_client=serve_client)
        trainer.run(10)

        # serve's commit is async: give its transfer a moment to land
        import time
        for _ in range(50):
            if cluster.controller.latest_restartable("server"):
                break
            time.sleep(0.1)

        n1 = len(cluster.controller.managers())
        apps = ["trainer", "server"]
        for app in apps:
            found = cluster.controller.latest_restartable(app)
            assert found is not None, app
            print(f"app {app!r}: newest checkpoint step={found[0].step} "
                  f"({found[1]}), agents="
                  f"{len(cluster.controller.agents_for(app))}")
        print(f"iCheck nodes: {n0} -> {n1} "
              f"(controller grew via the RM when memory ran short)")
        trainer.finalize()
        serve_client.finalize()


if __name__ == "__main__":
    main()
