"""Serving demo: batched greedy generation across four model families
(dense / SSM / hybrid / enc-dec), with KV-cache vs recurrent-state size
printed -- the O(1)-state property that makes long_500k decodable.

  PYTHONPATH=src python examples/serve_demo.py
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.models import init_cache, init_params
from repro.serve import ServeEngine, serve_max_len


def cache_bytes(cfg, batch, max_len):
    cache = init_cache(cfg, batch, max_len)
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(cache))


def main():
    rng = np.random.default_rng(0)
    for arch in ("yi-6b", "rwkv6-7b", "recurrentgemma-9b",
                 "seamless-m4t-medium"):
        cfg = get_config(arch, tiny=True)
        params, _ = init_params(cfg, jax.random.key(0))
        b, t, gen = 2, 16, 12
        batch = {"tokens": rng.integers(0, cfg.vocab_size, (b, t))
                 .astype(np.int32)}
        if cfg.frontend == "frames":
            batch["frames"] = rng.standard_normal(
                (b, cfg.num_frames, cfg.d_model)).astype(np.float32)
        engine = ServeEngine(cfg, params,
                             max_len=serve_max_len(cfg, t, gen))
        out = engine.generate(batch, gen_len=gen)
        short = cache_bytes(cfg, b, 32)
        long = cache_bytes(cfg, b, 4096)
        growth = long / short
        kind = "O(1) state" if growth < 2 else "KV cache grows with T"
        print(f"{arch:22s} generated {out.shape}; state @T=32: "
              f"{short / 2**10:7.1f}KiB  @T=4096: {long / 2**10:9.1f}KiB  "
              f"({kind})")


if __name__ == "__main__":
    main()
