"""Quickstart: the iCheck workflow from paper Listing 1, step by step,
against a tiny JAX model -- register, add_adapt, commit (async), restart.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.core import ICheckClient, ICheckCluster, snapshot_pytree
from repro.core.snapshot import restore_pytree
from repro.models import forward, init_params


def main():
    cfg = get_config("yi-6b", tiny=True)
    params, _ = init_params(cfg, jax.random.key(0))
    batch = {"tokens": np.arange(32, dtype=np.int32)[None, :] % cfg.vocab_size}

    # an iCheck deployment: RM + controller + 2 iCheck nodes + PFS
    with ICheckCluster(n_icheck_nodes=2) as cluster:
        # 1. icheck_init: register with the controller, get agents
        client = ICheckClient("quickstart", cluster.controller).init()
        print(f"connected to {len(client.agents)} agent(s)")

        # 2. icheck_add_adapt: register every model param as a region
        snap = snapshot_pytree(params, step=0)
        client.add_adapt_snapshot(snap)
        print(f"registered {len(snap.regions)} regions, "
              f"{snap.total_bytes() / 2**20:.1f} MiB")

        # 3. icheck_commit: async transfer to agent memory (L1), then PFS
        handle = client.commit(
            step=0, parts_by_region={n: r.parts
                                     for n, r in snap.regions.items()})
        print("commit returned immediately; app keeps computing...")
        logits, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
        handle.wait(timeout=60)
        print(f"checkpoint {handle.ckpt_id} in L1 "
              f"(simulated transfer {handle.sim_duration * 1e3:.2f} ms)")

        # 4. icheck_restart: fetch the newest checkpoint back
        meta, regions, level = client.restart()
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        restored = restore_pytree(template, regions, meta.regions)
        logits2, _ = jax.jit(lambda p, b: forward(cfg, p, b))(restored, batch)
        np.testing.assert_array_equal(np.asarray(logits),
                                      np.asarray(logits2))
        print(f"restored from {level}: forward pass is bit-identical")
        client.finalize()


if __name__ == "__main__":
    main()
