"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on CPU with iCheck commits + a mid-run simulated failure and
restart (the full fault-tolerance loop).

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--small]
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import ICheckCluster
from repro.optim import AdamWConfig
from repro.train import ElasticTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true",
                    help="~2M params instead of ~100M (fast CI)")
    args = ap.parse_args()

    base = get_config("yi-6b", tiny=True)
    if args.small:
        cfg = dataclasses.replace(base, name="llama-2m")
        shape = ShapeConfig("e2e", "train", seq_len=64, global_batch=8)
    else:
        # ~100M params: 12L, d_model=512, 8 heads, d_ff=2048, 32k vocab
        cfg = dataclasses.replace(
            base, name="llama-100m", num_layers=12, d_model=512,
            num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32768,
            dtype="float32")
        shape = ShapeConfig("e2e", "train", seq_len=128, global_batch=4)

    with ICheckCluster(n_icheck_nodes=2) as cluster:
        trainer = ElasticTrainer(cfg, shape, cluster, app_id="e2e", seed=0,
                                 opt_cfg=AdamWConfig(lr=1e-3),
                                 commit_every=25, probe_every=100,
                                 total_steps=args.steps)
        n_params = sum(x.size for x in
                       __import__("jax").tree.leaves(trainer.state.params))
        print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
              f"batch {shape.global_batch} x {shape.seq_len}")

        half = args.steps // 2
        t0 = time.monotonic()
        trainer.run(half)
        print(f"[{time.monotonic() - t0:6.1f}s] step {half}: "
              f"loss {trainer.metrics_log[-1]['loss']:.4f}")
        trainer.commit(blocking=True)

        # simulate a crash: abandon the trainer, start a new one (restart)
        print("simulating node failure -> restart from iCheck")
        trainer2 = ElasticTrainer(cfg, shape, cluster, app_id="e2e", seed=0,
                                  opt_cfg=AdamWConfig(lr=1e-3),
                                  commit_every=25, probe_every=100,
                                  total_steps=args.steps)
        assert trainer2.restarted and int(trainer2.state.step) == half
        trainer2.run(args.steps - half)
        print(f"[{time.monotonic() - t0:6.1f}s] step {args.steps}: "
              f"loss {trainer2.metrics_log[-1]['loss']:.4f}")
        first = trainer.metrics_log[0]["loss"]
        last = trainer2.metrics_log[-1]["loss"]
        print(f"loss {first:.3f} -> {last:.3f} "
              f"({'LEARNED' if last < first * 0.7 else 'check config'}); "
              f"restart was transparent")
        trainer2.finalize()


if __name__ == "__main__":
    main()
