"""Core building blocks: RMSNorm, dense projections, RoPE, gated FFNs,
embeddings.  Pure-functional: every ``*_init`` returns ``(params, axes)``
where ``axes`` mirrors ``params`` with tuples of *logical* axis names
(resolved to PartitionSpecs by ``repro.sharding.rules``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def _dense_init(key, shape, axes, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    if scale is None:
        scale = fan_in ** -0.5
    w = jax.random.normal(key, shape, dtype) * scale
    return w, axes


# --------------------------------------------------------------------------
# RMSNorm
#
# custom_vjp with *compute-dtype cotangent boundaries* (hillclimb H2): the
# statistics run in f32 registers, but the saved residual is the bf16 x and
# dx leaves in bf16 -- without this, XLA's excess-precision pass promotes
# the loop-carried residual-stream cotangents (and the TP all-reduces that
# move them) to f32, doubling HBM + ICI traffic.
# --------------------------------------------------------------------------
def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


@jax.custom_vjp
def _rmsnorm_core(x, scale):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6) * scale
    return y.astype(x.dtype)


def _rmsnorm_fwd(x, scale):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    rsig = jax.lax.rsqrt(var + 1e-6)
    y = (xf * rsig * scale).astype(x.dtype)
    return y, (x, rsig, scale)


def _rmsnorm_bwd(res, dy):
    x, rsig, scale = res
    d = x.shape[-1]
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32) * scale
    inner = jnp.sum(dyf * xf, axis=-1, keepdims=True) / d
    dx = rsig * (dyf - xf * (rsig * rsig) * inner)
    dscale = jnp.sum((dy.astype(jnp.float32)
                      * xf * rsig).reshape(-1, d), axis=0)
    return dx.astype(x.dtype), dscale


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(params, x, eps: float = 1e-6):
    return _rmsnorm_core(x, params["scale"])


def groupnorm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head group norm used by RWKV-6 on the wkv output.

    x: (B, T, H, D) normalized over D per head; scale/bias: (H, D).
    """
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, H, T, D); positions: (B, T) absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freq  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Gated FFN (SwiGLU / GeGLU)
#
# gate and up projections are STACKED on a leading axis and applied with one
# contraction: the backward dx then sums the two branches *locally inside
# the dot* before GSPMD's partial-sum all-reduce -- one (B,T,D) all-reduce
# per layer instead of two (perf hillclimb H1, EXPERIMENTS.md SSPerf).
# --------------------------------------------------------------------------
def ffn_init(key, d_model, d_ff):
    k1, k3 = jax.random.split(key, 2)
    p, a = {}, {}
    w = jax.random.normal(k1, (2, d_model, d_ff), jnp.float32) \
        * d_model ** -0.5
    p["w_gu"], a["w_gu"] = w, ("stack", "embed", "ff")
    p["w_down"], a["w_down"] = _dense_init(k3, (d_ff, d_model), ("ff", "embed"))
    return p, a


def ffn_apply(params, x, kind: str = "swiglu"):
    act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
    wgu = params["w_gu"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    gu = jnp.einsum("btd,kdf->kbtf", x, wgu)
    h = act(gu[0]) * gu[1]
    h = constrain(h, "batch", "seq", "act_ff")
    return h @ wd


# --------------------------------------------------------------------------
# Embedding / LM head
# --------------------------------------------------------------------------
def embed_init(key, vocab, d_model):
    w = jax.random.normal(key, (vocab, d_model), jnp.float32)
    return {"table": w}, {"table": ("vocab", "embed")}


def embed_apply(params, tokens):
    out = jnp.take(params["table"], tokens, axis=0)
    return constrain(out, "batch", "seq", "act_embed")


def lm_head_init(key, d_model, vocab):
    p, a = {}, {}
    p["w"], a["w"] = _dense_init(key, (d_model, vocab), ("embed", "vocab"))
    return p, a


def lm_head_apply(params, x, valid_vocab: int = 0):
    """valid_vocab > 0: the head is padded; mask the tail to -inf so the
    padded logits are inert in softmax/argmax (no slice -> no resharding)."""
    logits = x @ params["w"].astype(x.dtype)
    vp = logits.shape[-1]
    if valid_vocab and valid_vocab < vp:
        ok = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0) < valid_vocab
        logits = jnp.where(ok, logits, jnp.asarray(-1e30, logits.dtype))
    return constrain(logits, "batch", "seq", "act_vocab")


# --------------------------------------------------------------------------
# Frontend stubs (assignment: audio frames / vision patches arrive as
# precomputed embeddings via input_specs; the frontend is a projection)
# --------------------------------------------------------------------------
def frontend_init(key, d_in, d_model):
    p, a = {}, {}
    p["proj"], a["proj"] = _dense_init(key, (d_in, d_model), (None, "embed"))
    return p, a


def frontend_apply(params, embeds):
    out = embeds @ params["proj"].astype(embeds.dtype)
    return constrain(out, "batch", "seq", "act_embed")
