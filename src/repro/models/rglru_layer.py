"""Griffin / RecurrentGemma recurrent block: causal depthwise conv1d +
RG-LRU over the blocked Pallas scan, gated by a GeLU branch.

State carried for decode: ``conv``: (B, conv_width-1, rnn_width) past
inputs; ``h``: (B, rnn_width) f32 recurrent state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import rglru as rglru_core
from repro.sharding import constrain

from .layers import _dense_init

RGLRU_C = 8.0  # Griffin's fixed recurrence-sharpness constant


class RGLRUState(NamedTuple):
    conv: jax.Array     # (B, W-1, rnn_width)
    h: jax.Array        # (B, rnn_width) f32


def recurrent_init(key, d_model, rnn_width, conv_width):
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    # in/gate projections stacked (hillclimb H1: one bwd dx all-reduce)
    p["w_ig"] = jax.random.normal(ks[0], (2, d_model, rnn_width),
                                  jnp.float32) * d_model ** -0.5
    a["w_ig"] = ("stack", "embed", "rnn")
    p["w_out"], a["w_out"] = _dense_init(ks[2], (rnn_width, d_model),
                                         ("rnn", "embed"))
    p["conv_w"] = jax.random.normal(ks[3], (conv_width, rnn_width),
                                    jnp.float32) * conv_width ** -0.5
    a["conv_w"] = ("conv", "rnn")
    p["conv_b"] = jnp.zeros((rnn_width,), jnp.float32)
    a["conv_b"] = ("rnn",)
    # recurrence/input gates stacked likewise
    p["w_ai"] = jax.random.normal(ks[4], (2, rnn_width, rnn_width),
                                  jnp.float32) * rnn_width ** -0.5
    a["w_ai"] = ("stack", "rnn", None)
    # Lambda init so that a^c = sigmoid(lam)^c lands in [0.9, 0.999]
    u = jnp.linspace(0.9 ** (1 / RGLRU_C), 0.999 ** (1 / RGLRU_C), rnn_width)
    p["lam"] = jnp.log(u / (1 - u)).astype(jnp.float32)
    a["lam"] = ("rnn",)
    return p, a


def _causal_conv(y, conv_w, conv_b, state):
    """Depthwise causal conv. y: (B, T, N); state: (B, W-1, N) history."""
    w = conv_w.shape[0]
    hist = jnp.concatenate([state.astype(y.dtype), y], axis=1)
    out = jnp.zeros_like(y)
    for i in range(w):
        out = out + hist[:, w - 1 - i: hist.shape[1] - i, :] \
            * conv_w[w - 1 - i].astype(y.dtype)
    new_state = hist[:, -(w - 1):, :] if w > 1 else state
    return out + conv_b.astype(y.dtype), new_state


def recurrent_apply(params, x, state: RGLRUState, impl=None):
    """x: (B, T, d_model) -> (out, new_state)."""
    ig = jnp.einsum("btd,kdn->kbtn", x, params["w_ig"].astype(x.dtype))
    y, gate = ig[0], jax.nn.gelu(ig[1])
    y = constrain(y, "batch", "seq", "act_rnn")
    y, conv_state = _causal_conv(y, params["conv_w"], params["conv_b"],
                                 state.conv)
    yf = y.astype(jnp.float32)
    ai = jnp.einsum("btn,knm->kbtm", yf, params["w_ai"].astype(jnp.float32))
    r = jax.nn.sigmoid(ai[0])
    i = jax.nn.sigmoid(ai[1])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r     # (B, T, N) <= 0
    a2 = jnp.exp(2.0 * log_a)
    g = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * yf
    h, h_last = rglru_core(log_a, g.astype(x.dtype), state.h, impl=impl)
    h = constrain(h, "batch", "seq", "act_rnn")
    out = (gate * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    out = constrain(out, "batch", "seq", "act_embed")
    return out, RGLRUState(conv=conv_state.astype(state.conv.dtype), h=h_last)


def init_state(batch, rnn_width, conv_width, dtype):
    return RGLRUState(conv=jnp.zeros((batch, conv_width - 1, rnn_width), dtype),
                      h=jnp.zeros((batch, rnn_width), jnp.float32))


def state_axes():
    return RGLRUState(conv=("batch", None, "act_rnn"),
                      h=("batch", "act_rnn"))
