"""Top-k routed Mixture-of-Experts FFN (dbrx-style fine-grained / qwen3-style
many-expert), expert-parallel over the "model" mesh axis.

Dispatch is the sort-free mesh-tensorflow scheme, vmapped over the batch row
so every cumsum/scatter is *local to a data shard* (no cross-shard sort):

  1. router top-k -> (T, k) expert ids + renormalized weights,
  2. position-in-expert via a cumulative count over the T*k assignments,
     drop beyond per-row capacity C = ceil(k * T / E * capacity_factor),
  3. scatter tokens into an (E, C, d_model) dispatch buffer,
  4. per-expert SwiGLU via batched einsum with weights sharded on the
     expert axis -- GSPMD turns the (data-sharded tokens) -> (expert-sharded
     buffer) handoff into the canonical MoE all-to-all,
  5. gather back, weight, and sum the k contributions.

Also returns the switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain

from .layers import _dense_init


def moe_init(key, d_model, num_experts, d_ff):
    kr, k1, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["router"], a["router"] = _dense_init(kr, (d_model, num_experts),
                                           ("embed", None))
    # gate/up expert weights stacked: one dispatch contraction, one bwd
    # dx all-reduce (hillclimb H1)
    p["w_gu"] = jax.random.normal(k1, (2, num_experts, d_model, d_ff),
                                  jnp.float32) * d_model ** -0.5
    a["w_gu"] = ("stack", "experts", "embed", "expert_ff")
    p["w_down"] = jax.random.normal(k3, (num_experts, d_ff, d_model),
                                    jnp.float32) * d_ff ** -0.5
    a["w_down"] = ("experts", "expert_ff", "embed")
    return p, a


def _dispatch_row(x, ids, weights, capacity, num_experts):
    """Per-batch-row dispatch. x: (T, D); ids/weights: (T, k).

    Returns (xe: (E, C, D), slot: (T*k,), keep: (T*k,), token_of: (T*k,)).
    """
    t, k = ids.shape
    flat_ids = ids.reshape(t * k)                        # token-major order
    oh = jax.nn.one_hot(flat_ids, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh                     # (T*k, E)
    pos = jnp.sum(pos * oh, axis=1)                       # position in expert
    keep = pos < capacity
    slot = jnp.where(keep, flat_ids * capacity + pos, num_experts * capacity)
    token_of = jnp.arange(t * k) // k
    d = x.shape[-1]
    buf = jnp.zeros((num_experts * capacity + 1, d), x.dtype)
    xe = buf.at[slot].add(x[token_of] * keep[:, None].astype(x.dtype))
    return xe[:-1].reshape(num_experts, capacity, d), slot, keep, token_of


def moe_apply(params, x, *, num_experts, experts_per_token,
              capacity_factor=1.25, aux_coef=0.01, act=jax.nn.silu):
    """x: (B, T, d_model) -> (y, aux_loss)."""
    b, t, d = x.shape
    k = experts_per_token
    e = num_experts
    capacity = max(int(k * t / e * capacity_factor), 1)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # (B, T, E)
    top_p, top_ids = jax.lax.top_k(probs, k)              # (B, T, k)
    top_w = (top_p / jnp.sum(top_p, axis=-1, keepdims=True)).astype(x.dtype)

    # load-balancing aux loss (switch): E * mean_e(frac_routed * mean_prob)
    frac = jnp.mean(jax.nn.one_hot(top_ids, e, dtype=jnp.float32),
                    axis=(1, 2))                          # (B, E)
    mean_p = jnp.mean(probs, axis=1)                      # (B, E)
    aux = aux_coef * e * jnp.mean(jnp.sum(frac * mean_p, axis=-1))

    xe, slot, keep, token_of = jax.vmap(
        lambda xr, ir, wr: _dispatch_row(xr, ir, wr, capacity, e)
    )(x, top_ids, top_w)
    xe = constrain(xe, "batch", "act_experts", None, None)

    gu = jnp.einsum("becd,kedf->kbecf", xe, params["w_gu"].astype(x.dtype))
    h = act(gu[0]) * gu[1]
    h = constrain(h, "batch", "act_experts", None, None)
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(x.dtype))
    # combine side (hillclimb H6): gather expert outputs from a *replicated*
    # buffer -- one all-gather of (E, C, D) -- instead of gathering from the
    # expert-sharded buffer, whose backward scatter-add forces a full
    # (T*k, D) all-reduce (~4x the bytes, measured on qwen3)
    ye = constrain(ye, "batch", None, None, None)

    def _combine_row(ye_r, slot_r, keep_r, token_of_r, w_r):
        flat = jnp.concatenate(
            [ye_r.reshape(e * capacity, d), jnp.zeros((1, d), ye_r.dtype)], 0)
        contrib = flat[slot_r] * (keep_r[:, None] * w_r.reshape(-1)[:, None]
                                  ).astype(ye_r.dtype)
        return jnp.zeros((t, d), ye_r.dtype).at[token_of_r].add(contrib)

    y = jax.vmap(_combine_row)(ye, slot, keep, token_of, top_w)
    return constrain(y, "batch", "seq", "act_embed"), aux
