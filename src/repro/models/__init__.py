from .transformer import (abstract_params, cache_axes, decode_step, forward,
                          init_cache, init_params, loss_fn, prefill,
                          stack_plan)
from .params import count_params, param_shardings, param_specs

__all__ = ["init_params", "abstract_params", "forward", "loss_fn",
           "init_cache", "cache_axes", "prefill", "decode_step", "stack_plan",
           "count_params", "param_specs", "param_shardings"]
