"""RWKV-6 (Finch) block: data-dependent token-shift time-mix over the
chunked Pallas recurrence kernel + squared-ReLU channel-mix.

State carried for decode: per block,
  ``shift_tm`` / ``shift_cm``: (B, d_model) -- previous token's activations
  ``wkv``: (B, H, Dh, Dh) f32 -- the linear-attention state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import rwkv6 as rwkv6_core
from repro.sharding import constrain

from .layers import _dense_init, groupnorm_heads

LORA_RANK = 32


class RWKVState(NamedTuple):
    shift_tm: jax.Array        # (B, D)
    shift_cm: jax.Array        # (B, D)
    wkv: jax.Array             # (B, H, Dh, Dh) f32


def timemix_init(key, d_model, head_dim):
    h = d_model // head_dim
    ks = jax.random.split(key, 12)
    p, a = {}, {}
    # r/k/v/g projections stacked: one contraction, one bwd dx all-reduce
    p["w_rkvg"] = jax.random.normal(ks[0], (4, d_model, d_model),
                                    jnp.float32) * d_model ** -0.5
    a["w_rkvg"] = ("stack", "embed", "rnn")
    p["wo"], a["wo"] = _dense_init(ks[4], (d_model, d_model), ("rnn", "embed"))
    # data-dependent decay: w = exp(-exp(w0 + (x @ A) @ B))
    p["w0"] = jnp.zeros((d_model,), jnp.float32) - 4.0
    a["w0"] = ("rnn",)
    p["wA"], a["wA"] = _dense_init(ks[5], (d_model, LORA_RANK), ("embed", None))
    p["wB"], a["wB"] = _dense_init(ks[6], (LORA_RANK, d_model), (None, "rnn"),
                                   scale=0.01)
    # token-shift interpolation factors (static mu + data-dependent lora)
    p["mu"] = jnp.full((5, d_model), 0.5, jnp.float32)   # r,k,v,w,g
    a["mu"] = ("stack", "embed")
    p["muA"], a["muA"] = _dense_init(ks[7], (d_model, LORA_RANK), ("embed", None))
    p["muB"], a["muB"] = _dense_init(ks[8], (LORA_RANK, 5 * d_model),
                                     (None, None), scale=0.01)
    p["u"] = jnp.zeros((h, head_dim), jnp.float32)       # bonus
    a["u"] = (None, "rnn")
    p["gn_scale"] = jnp.ones((h, head_dim), jnp.float32)
    p["gn_bias"] = jnp.zeros((h, head_dim), jnp.float32)
    a["gn_scale"] = a["gn_bias"] = (None, "rnn")
    return p, a


def _token_shift(x, last):
    """x: (B, T, D); last: (B, D) previous token (zeros at sequence start)."""
    prev = jnp.concatenate([last[:, None, :].astype(x.dtype), x[:, :-1, :]], 1)
    return prev


def timemix_apply(params, x, state_tm, wkv_state, head_dim, impl=None):
    b, t, d = x.shape
    h = d // head_dim
    prev = _token_shift(x, state_tm)
    delta = prev - x
    # data-dependent interpolation (RWKV-6 "ddlerp")
    lora = jnp.tanh(x @ params["muA"].astype(x.dtype))
    lora = (lora @ params["muB"].astype(x.dtype)).reshape(b, t, 5, d)
    mix = params["mu"].astype(x.dtype)[None, None] + lora
    xr, xk, xv, xw, xg = [x + delta * mix[:, :, i] for i in range(5)]

    xs4 = jnp.stack([xr, xk, xv, xg])                    # (4, B, T, D)
    rkvg = jnp.einsum("nbtd,ndh->nbth", xs4,
                      params["w_rkvg"].astype(x.dtype))
    r, k, v, g = rkvg[0], rkvg[1], rkvg[2], rkvg[3]
    wlog = params["w0"] + jnp.tanh(xw @ params["wA"].astype(x.dtype)) \
        @ params["wB"].astype(x.dtype)
    log_w = -jnp.exp(wlog.astype(jnp.float32))           # (B, T, D) <= 0

    def heads(z):
        return z.reshape(b, t, h, head_dim).transpose(0, 2, 1, 3)

    r_, k_, v_, lw_ = heads(r), heads(k), heads(v), heads(log_w)
    r_ = constrain(r_, "batch", "act_rnn", "seq", None)
    o, wkv_new = rwkv6_core(r_, k_, v_, lw_, params["u"], wkv_state,
                            impl=impl)
    o = o.transpose(0, 2, 1, 3)                          # (B, T, H, Dh)
    o = groupnorm_heads(o, params["gn_scale"], params["gn_bias"])
    o = o.reshape(b, t, d) * jax.nn.silu(g)
    out = o @ params["wo"].astype(x.dtype)
    return constrain(out, "batch", "seq", "act_embed"), x[:, -1, :], wkv_new


def chanmix_init(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["wk"], a["wk"] = _dense_init(k1, (d_model, d_ff), ("embed", "ff"))
    p["wv"], a["wv"] = _dense_init(k2, (d_ff, d_model), ("ff", "embed"))
    p["wr"], a["wr"] = _dense_init(k3, (d_model, d_model), ("embed", "rnn"))
    p["mu"] = jnp.full((2, d_model), 0.5, jnp.float32)   # k, r
    a["mu"] = ("stack", "embed")
    return p, a


def chanmix_apply(params, x, state_cm):
    prev = _token_shift(x, state_cm)
    delta = prev - x
    mu = params["mu"].astype(x.dtype)
    xk = x + delta * mu[0]
    xr = x + delta * mu[1]
    k = jnp.square(jax.nn.relu(xk @ params["wk"].astype(x.dtype)))
    k = constrain(k, "batch", "seq", "act_ff")
    kv = k @ params["wv"].astype(x.dtype)
    out = jax.nn.sigmoid(xr @ params["wr"].astype(x.dtype)) * kv
    return constrain(out, "batch", "seq", "act_embed"), x[:, -1, :]


def init_state(batch, d_model, head_dim, dtype):
    h = d_model // head_dim
    return RWKVState(
        shift_tm=jnp.zeros((batch, d_model), dtype),
        shift_cm=jnp.zeros((batch, d_model), dtype),
        wkv=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32))


def state_axes():
    return RWKVState(shift_tm=("batch", "act_embed"),
                     shift_cm=("batch", "act_embed"),
                     wkv=("batch", "act_rnn", None, None))
