"""The shared LM backbone: one parameterized definition covering all ten
assigned architectures (dense / MoE / enc-dec audio / VLM / RWKV-6 SSM /
RG-LRU hybrid).

Structure
---------
* ``init_params(cfg, key)`` -> ``(params, axes)``: params is a pytree of f32
  arrays; ``axes`` mirrors it with logical-axis tuples for the sharding
  rules.  Layer stacks are *stacked* along a leading "layers" axis and run
  with ``lax.scan`` (+ ``jax.checkpoint`` remat) so HLO size and compile
  time stay bounded at 94 layers x 512 devices.  ``abstract_params`` gives
  (ShapeDtypeStructs, axes) without allocating -- the dry-run path.
* ``forward`` / ``loss_fn``: train & scoring path.
* ``init_cache`` / ``prefill`` / ``decode_step``: serving path with KV
  caches (attention), ring buffers (sliding-window), recurrent states
  (RWKV-6 / RG-LRU) -- O(1)-in-T state for the sub-quadratic archs.

Hybrid archs scan over *super-layers* (one pattern period, e.g.
(rec, rec, attn) for recurrentgemma) plus explicit tail layers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.sharding import constrain

from . import attention as attn
from . import moe as moe_lib
from . import rglru_layer as rglru
from . import rwkv6_layer as rwkv
from .layers import (embed_apply, embed_init, ffn_apply, ffn_init,
                     frontend_apply, frontend_init, lm_head_apply,
                     lm_head_init, rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


def _is_axes(x) -> bool:
    return (isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x))


# ==========================================================================
# layer-stack layout per architecture
# ==========================================================================
def stack_plan(cfg: ModelConfig) -> Dict[str, Any]:
    """How layers are grouped for scan: one scanned *super-layer* holds one
    pattern period; leftovers become explicit tail layers."""
    if cfg.mixer == "rwkv6":
        return dict(scan_kinds=("rwkv",), scan_len=cfg.num_layers,
                    tail_kinds=(), enc_layers=0)
    if cfg.mixer == "rglru_hybrid":
        period = cfg.pattern or ("rec", "rec", "attn")
        n_scan = cfg.num_layers // len(period)
        n_tail = cfg.num_layers - n_scan * len(period)
        tail = (cfg.tail_layers or ("rec",) * n_tail)[:n_tail]
        return dict(scan_kinds=tuple(period), scan_len=n_scan,
                    tail_kinds=tuple(tail), enc_layers=0)
    if cfg.is_encdec:
        return dict(scan_kinds=("dec",), scan_len=cfg.num_layers,
                    tail_kinds=(), enc_layers=cfg.encoder_layers)
    return dict(scan_kinds=("attn",), scan_len=cfg.num_layers,
                tail_kinds=(), enc_layers=0)


def _layer_window(cfg: ModelConfig, kind: str) -> Optional[int]:
    if kind == "attn" and cfg.mixer == "rglru_hybrid":
        return cfg.window or 2048
    return cfg.window


# ==========================================================================
# per-layer blocks
# ==========================================================================
def _block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["norm1"], a["norm1"] = rmsnorm_init(cfg.d_model)
    if kind in ("attn", "dec"):
        p["attn"], a["attn"] = attn.attn_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias)
        if kind == "dec":
            p["norm_x"], a["norm_x"] = rmsnorm_init(cfg.d_model)
            p["xattn"], a["xattn"] = attn.attn_init(
                ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias, cross=True)
        p["norm2"], a["norm2"] = rmsnorm_init(cfg.d_model)
        if cfg.ffn == "moe":
            p["moe"], a["moe"] = moe_lib.moe_init(
                ks[2], cfg.d_model, cfg.num_experts, cfg.resolved_moe_d_ff)
        else:
            p["ffn"], a["ffn"] = ffn_init(ks[2], cfg.d_model, cfg.d_ff)
    elif kind == "rwkv":
        p["tm"], a["tm"] = rwkv.timemix_init(ks[0], cfg.d_model,
                                             cfg.rwkv_head_dim)
        p["norm2"], a["norm2"] = rmsnorm_init(cfg.d_model)
        p["cm"], a["cm"] = rwkv.chanmix_init(ks[1], cfg.d_model, cfg.d_ff)
    elif kind == "rec":
        p["rec"], a["rec"] = rglru.recurrent_init(
            ks[0], cfg.d_model, cfg.resolved_rnn_width, cfg.conv1d_width)
        p["norm2"], a["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"], a["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff)
    else:
        raise ValueError(kind)
    return p, a


def _ffn_or_moe(cfg, p, h):
    if cfg.ffn == "moe":
        return moe_lib.moe_apply(
            p["moe"], h, num_experts=cfg.num_experts,
            experts_per_token=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            aux_coef=cfg.router_aux_coef)
    return ffn_apply(p["ffn"], h, kind=cfg.ffn), jnp.zeros((), jnp.float32)


def _block_apply(cfg: ModelConfig, p: Params, x, *, kind: str, positions,
                 state, enc_out=None, impl=None, causal=True):
    """Full-sequence (train / prefill / encoder) application.

    ``state`` is None for pure training; for prefill it is this layer's
    cache slot and the updated cache is returned.
    Returns (x, aux, new_state)."""
    window = _layer_window(cfg, kind)
    aux = jnp.zeros((), jnp.float32)
    new_state = state
    h = rmsnorm(p["norm1"], x)
    if kind in ("attn", "dec"):
        kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                  head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                  impl=impl)
        if state is not None:
            y, kvc = attn.attn_apply(p["attn"], h, positions=positions,
                                     causal=causal, window=window,
                                     return_cache=True, **kw)
            new_state = dict(state,
                             self=_write_prefill_cache(state["self"], kvc,
                                                       window))
        else:
            y = attn.attn_apply(p["attn"], h, positions=positions,
                                causal=causal, window=window, **kw)
        x = x + checkpoint_name(y, "psum_out")
        if kind == "dec":
            hx = rmsnorm(p["norm_x"], x)
            y = attn.attn_apply(p["xattn"], hx, xkv=enc_out, causal=False,
                                use_rope=False, **kw)
            x = x + checkpoint_name(y, "psum_out")
            if state is not None:
                cross = attn.cross_kv(p["xattn"], enc_out, cfg.num_kv_heads,
                                      cfg.resolved_head_dim, enc_out.dtype)
                if new_state["cross"].ks is not None:   # int8 KV mode
                    kq, ksc = attn._q8(cross.k)
                    vq, vsc = attn._q8(cross.v)
                    cross = attn.KVCache(k=kq, v=vq, ks=ksc, vs=vsc)
                new_state = dict(new_state, cross=cross)
        h2 = rmsnorm(p["norm2"], x)
        y, aux = _ffn_or_moe(cfg, p, h2)
        x = x + checkpoint_name(y, "psum_out")
    elif kind == "rwkv":
        st = state if state is not None else rwkv.init_state(
            x.shape[0], cfg.d_model, cfg.rwkv_head_dim, x.dtype)
        y, shift_tm, wkv_new = rwkv.timemix_apply(
            p["tm"], h, st.shift_tm, st.wkv, cfg.rwkv_head_dim, impl=impl)
        x = x + checkpoint_name(y, "psum_out")
        h2 = rmsnorm(p["norm2"], x)
        y, shift_cm = rwkv.chanmix_apply(p["cm"], h2, st.shift_cm)
        x = x + checkpoint_name(y, "psum_out")
        new_state = rwkv.RWKVState(shift_tm=shift_tm.astype(st.shift_tm.dtype),
                                   shift_cm=shift_cm.astype(st.shift_cm.dtype),
                                   wkv=wkv_new)
        if state is None:
            new_state = None
    elif kind == "rec":
        st = state if state is not None else rglru.init_state(
            x.shape[0], cfg.resolved_rnn_width, cfg.conv1d_width, x.dtype)
        y, new_state = rglru.recurrent_apply(p["rec"], h, st, impl=impl)
        x = x + checkpoint_name(y, "psum_out")
        h2 = rmsnorm(p["norm2"], x)
        x = x + checkpoint_name(ffn_apply(p["ffn"], h2, kind=cfg.ffn),
                                "psum_out")
        if state is None:
            new_state = None
    return constrain(x, "batch", "seq", "act_embed"), aux, new_state


def _write_prefill_cache(cache: attn.KVCache, kvc: attn.KVCache, window):
    """Store prefill K/V into the (possibly ring, possibly int8) buffer."""
    s_max = cache.k.shape[2]
    t = kvc.k.shape[2]
    k_in, v_in = kvc.k, kvc.v
    ks = vs = None
    if cache.ks is not None:                     # int8 KV mode
        k_in, ks = attn._q8(k_in)
        v_in, vs = attn._q8(v_in)
    if t > s_max:
        # ring buffer: keep the last `s_max` positions, rotated so absolute
        # position p lives in slot p % s_max (matching decode's ring writes)
        shift = (t - s_max) % s_max

        def roll(x):
            return jnp.roll(x[:, :, t - s_max:, :], shift, axis=2)

        return attn.KVCache(
            k=roll(k_in).astype(cache.k.dtype),
            v=roll(v_in).astype(cache.v.dtype),
            ks=None if ks is None else roll(ks),
            vs=None if vs is None else roll(vs))
    def dus(buf, val):
        return jax.lax.dynamic_update_slice(
            buf, val.astype(buf.dtype), (0, 0, 0, 0))

    return attn.KVCache(
        k=dus(cache.k, k_in), v=dus(cache.v, v_in),
        ks=None if ks is None else dus(cache.ks, ks),
        vs=None if vs is None else dus(cache.vs, vs))


def _block_decode(cfg: ModelConfig, p: Params, x, idx, *, kind: str,
                  state, impl=None):
    """One-token decode. x: (B, 1, D). Returns (x, new_state)."""
    window = _layer_window(cfg, kind)
    h = rmsnorm(p["norm1"], x)
    if kind in ("attn", "dec"):
        kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                  head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
        y, kvc = attn.attn_decode(p["attn"], h, state["self"], idx,
                                  window=window, **kw)
        state = dict(state, self=kvc)
        x = x + y
        if kind == "dec":
            hx = rmsnorm(p["norm_x"], x)
            y, _ = attn.attn_decode(p["xattn"], hx, state["cross"], idx,
                                    cross=True, use_rope=False, **kw)
            x = x + y
        h2 = rmsnorm(p["norm2"], x)
        y, _ = _ffn_or_moe(cfg, p, h2)
        x = x + y
    elif kind == "rwkv":
        y, shift_tm, wkv_new = rwkv.timemix_apply(
            p["tm"], h, state.shift_tm, state.wkv, cfg.rwkv_head_dim,
            impl=impl)
        x = x + y
        h2 = rmsnorm(p["norm2"], x)
        y, shift_cm = rwkv.chanmix_apply(p["cm"], h2, state.shift_cm)
        x = x + y
        state = rwkv.RWKVState(shift_tm=shift_tm.astype(state.shift_tm.dtype),
                               shift_cm=shift_cm.astype(state.shift_cm.dtype),
                               wkv=wkv_new)
    elif kind == "rec":
        y, state = rglru.recurrent_apply(p["rec"], h, state, impl=impl)
        x = x + y
        h2 = rmsnorm(p["norm2"], x)
        x = x + ffn_apply(p["ffn"], h2, kind=cfg.ffn)
    return x, state


# ==========================================================================
# parameter init
# ==========================================================================
def _super_init(key, cfg, kinds):
    p, a = {}, {}
    ks = jax.random.split(key, len(kinds))
    for i, kind in enumerate(kinds):
        p[f"b{i}"], a[f"b{i}"] = _block_init(ks[i], cfg, kind)
    return p, a


def _stacked_init(key, cfg, kinds, n):
    keys = jax.random.split(key, n)
    holder = {}

    def init_only(k):
        p, a = _super_init(k, cfg, kinds)
        holder["axes"] = a                 # static, captured during trace
        return p

    stacked = jax.vmap(init_only)(keys)
    axes = jax.tree.map(lambda ax: ("layers",) + tuple(ax), holder["axes"],
                        is_leaf=_is_axes)
    return stacked, axes


def init_params(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    plan = stack_plan(cfg)
    ks = jax.random.split(key, 6 + len(plan["tail_kinds"]))
    p, a = {}, {}
    p["embed"], a["embed"] = embed_init(ks[0], cfg.padded_vocab, cfg.d_model)
    if cfg.frontend in ("frames", "patches"):
        p["frontend"], a["frontend"] = frontend_init(ks[1], cfg.d_model,
                                                     cfg.d_model)
    if plan["enc_layers"]:
        p["enc"], a["enc"] = _stacked_init(ks[2], cfg, ("attn",),
                                           plan["enc_layers"])
        p["enc_norm"], a["enc_norm"] = rmsnorm_init(cfg.d_model)
    p["stack"], a["stack"] = _stacked_init(ks[3], cfg, plan["scan_kinds"],
                                           plan["scan_len"])
    for i, kind in enumerate(plan["tail_kinds"]):
        p[f"tail{i}"], a[f"tail{i}"] = _block_init(ks[6 + i], cfg, kind)
    p["final_norm"], a["final_norm"] = rmsnorm_init(cfg.d_model)
    p["lm_head"], a["lm_head"] = lm_head_init(ks[5], cfg.d_model,
                                              cfg.padded_vocab)
    return p, a


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct pytree, axes pytree) without touching devices."""
    holder = {}

    def f(k):
        p, a = init_params(cfg, k)
        holder["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.key(0))
    return shapes, holder["axes"]


# ==========================================================================
# forward (train / score)
# ==========================================================================
def _remat(f, policy: str):
    if policy == "none":
        return f
    if policy == "dots":
        return jax.checkpoint(
            f,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if policy == "psum":
        # hillclimb H3: save exactly the post-all-reduce block outputs --
        # the backward then never re-runs the forward's TP collectives
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.save_only_these_names(
                "psum_out"))
    return jax.checkpoint(f)


def _embed_inputs(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = embed_apply(params["embed"], tokens).astype(cfg.dtype)
    prefix = 0
    if cfg.frontend == "patches":
        pe = frontend_apply(params["frontend"],
                            batch["patches"].astype(cfg.dtype))
        x = jnp.concatenate([pe, x], axis=1)
        prefix = pe.shape[1]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
    return x, positions, prefix


def _run_encoder(cfg, params, batch, impl):
    enc_in = frontend_apply(params["frontend"],
                            batch["frames"].astype(cfg.dtype))
    b, s, _ = enc_in.shape
    epos = jnp.broadcast_to(jnp.arange(s), (b, s))

    def ebody(e, lp):
        e, _, _ = _block_apply(cfg, lp["b0"], e, kind="attn", positions=epos,
                               state=None, impl=impl, causal=False)
        return e, None

    e, _ = jax.lax.scan(_remat(ebody, cfg.remat_policy), enc_in,
                        params["enc"])
    return rmsnorm(params["enc_norm"], e)


def _run_stack(cfg, params, x, positions, *, plan, impl, enc_out=None,
               caches=None):
    """Scan the super-layer stack (+ tail layers).

    ``caches`` is None (training) or {"stack": stacked-cache, "tails": [...]}
    (prefill).  Returns (x, aux, new_caches)."""
    kinds = plan["scan_kinds"]

    def body(carry, xs):
        x, aux = carry
        lp, cache_in = xs
        new_cache = cache_in
        for i, kind in enumerate(kinds):
            st = None if cache_in is None else cache_in[f"b{i}"]
            x, aux_i, st = _block_apply(cfg, lp[f"b{i}"], x, kind=kind,
                                        positions=positions, state=st,
                                        enc_out=enc_out, impl=impl)
            aux = aux + aux_i
            if cache_in is not None:
                new_cache = dict(new_cache, **{f"b{i}": st})
        return (x, aux), new_cache

    (x, aux), new_stack = jax.lax.scan(
        _remat(body, cfg.remat_policy),
        (x, jnp.zeros((), jnp.float32)),
        (params["stack"], caches["stack"] if caches else None))
    new_tails = []
    for i, kind in enumerate(plan["tail_kinds"]):
        st = None if caches is None else caches["tails"][i]
        x, aux_i, st = _block_apply(cfg, params[f"tail{i}"], x, kind=kind,
                                    positions=positions, state=st,
                                    enc_out=enc_out, impl=impl)
        aux = aux + aux_i
        new_tails.append(st)
    new_caches = None if caches is None else dict(caches, stack=new_stack,
                                                  tails=new_tails)
    return x, aux, new_caches


def forward(cfg: ModelConfig, params, batch, *, impl: Optional[str] = None):
    """Training / scoring forward pass. Returns (logits, aux_loss)."""
    plan = stack_plan(cfg)
    x, positions, prefix = _embed_inputs(cfg, params, batch)
    enc_out = _run_encoder(cfg, params, batch, impl) if plan["enc_layers"] \
        else None
    x, aux, _ = _run_stack(cfg, params, x, positions, plan=plan, impl=impl,
                           enc_out=enc_out)
    x = rmsnorm(params["final_norm"], x)
    if prefix:
        x = x[:, prefix:, :]
    logits = lm_head_apply(params["lm_head"], x,
                           valid_vocab=cfg.vocab_size)
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch, *, impl: Optional[str] = None):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux = forward(cfg, params, batch, impl=impl)
    labels = batch["labels"]
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = labels[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(targets, 0)[..., None],
                               axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    xent = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = xent + aux
    return loss, {"xent": xent, "aux": aux}


# ==========================================================================
# serving: cache init / prefill / decode
# ==========================================================================
def _kind_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype):
    window = _layer_window(cfg, kind)
    if kind == "attn":
        s = min(max_len, window) if window else max_len
        return {"self": attn.init_kv_cache(batch, cfg.num_kv_heads, s,
                                           cfg.resolved_head_dim, dtype,
                                           quant=cfg.kv_quant)}
    if kind == "dec":
        return {"self": attn.init_kv_cache(batch, cfg.num_kv_heads, max_len,
                                           cfg.resolved_head_dim, dtype,
                                           quant=cfg.kv_quant),
                "cross": attn.init_kv_cache(batch, cfg.num_kv_heads,
                                            cfg.num_frames,
                                            cfg.resolved_head_dim, dtype,
                                            quant=cfg.kv_quant)}
    if kind == "rwkv":
        return rwkv.init_state(batch, cfg.d_model, cfg.rwkv_head_dim, dtype)
    if kind == "rec":
        return rglru.init_state(batch, cfg.resolved_rnn_width,
                                cfg.conv1d_width, dtype)
    raise ValueError(kind)


def _kind_cache_axes(kind: str, quant: bool = False):
    if kind == "attn":
        return {"self": attn.cache_axes(quant)}
    if kind == "dec":
        return {"self": attn.cache_axes(quant),
                "cross": attn.cache_axes(quant)}
    if kind == "rwkv":
        return rwkv.state_axes()
    if kind == "rec":
        return rglru.state_axes()
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, Any]:
    """Decode cache pytree for a batch of ``batch`` sequences."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    plan = stack_plan(cfg)
    single = {f"b{i}": _kind_cache_init(cfg, kind, batch, max_len, dtype)
              for i, kind in enumerate(plan["scan_kinds"])}
    n = plan["scan_len"]
    stacked = jax.tree.map(
        lambda leaf: jnp.zeros((n,) + leaf.shape, leaf.dtype), single)
    tails = [_kind_cache_init(cfg, kind, batch, max_len, dtype)
             for kind in plan["tail_kinds"]]
    return {"stack": stacked, "tails": tails,
            "idx": jnp.zeros((), jnp.int32)}


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Logical-axis pytree matching ``init_cache`` output."""
    plan = stack_plan(cfg)
    single = {f"b{i}": _kind_cache_axes(kind, cfg.kv_quant)
              for i, kind in enumerate(plan["scan_kinds"])}
    stacked = jax.tree.map(lambda ax: ("layers",) + tuple(ax), single,
                           is_leaf=_is_axes)
    tails = [_kind_cache_axes(kind, cfg.kv_quant)
         for kind in plan["tail_kinds"]]
    return {"stack": stacked, "tails": tails, "idx": ()}


def prefill(cfg: ModelConfig, params, batch, cache, *,
            impl: Optional[str] = None):
    """Run the prompt through the model, filling ``cache``.

    Returns (logits_last: (B, vocab), new_cache)."""
    plan = stack_plan(cfg)
    x, positions, prefix = _embed_inputs(cfg, params, batch)
    enc_out = _run_encoder(cfg, params, batch, impl) if plan["enc_layers"] \
        else None
    x, _, caches = _run_stack(cfg, params, x, positions, plan=plan,
                              impl=impl, enc_out=enc_out, caches=cache)
    x = rmsnorm(params["final_norm"], x)
    logits = lm_head_apply(params["lm_head"], x[:, -1:, :],
                           valid_vocab=cfg.vocab_size)[:, 0, :]
    caches = dict(caches, idx=jnp.asarray(x.shape[1], jnp.int32))
    return logits, caches


def decode_step(cfg: ModelConfig, params, cache, tokens, *,
                impl: Optional[str] = None):
    """One decoding step. tokens: (B, 1) -> (logits (B, vocab), new_cache)."""
    plan = stack_plan(cfg)
    kinds = plan["scan_kinds"]
    x = embed_apply(params["embed"], tokens).astype(cfg.dtype)
    idx = cache["idx"]

    def body(x, xs):
        lp, lc = xs
        new_lc = lc
        for i, kind in enumerate(kinds):
            x, st = _block_decode(cfg, lp[f"b{i}"], x, idx, kind=kind,
                                  state=lc[f"b{i}"], impl=impl)
            new_lc = dict(new_lc, **{f"b{i}": st})
        return x, new_lc

    x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
    new_tails = []
    for i, kind in enumerate(plan["tail_kinds"]):
        x, st = _block_decode(cfg, params[f"tail{i}"], x, idx, kind=kind,
                              state=cache["tails"][i], impl=impl)
        new_tails.append(st)
    x = rmsnorm(params["final_norm"], x)
    logits = lm_head_apply(params["lm_head"], x,
                           valid_vocab=cfg.vocab_size)[:, 0, :]
    new_cache = dict(cache, stack=new_stack, tails=new_tails, idx=idx + 1)
    return logits, new_cache
