"""GQA attention block: train/prefill (flash kernel) + decode (KV cache).

Decode deliberately uses a plain einsum over the cache instead of the flash
kernel: with T=1 the step is HBM-bound on reading the cache, and the einsum
form propagates GSPMD shardings cleanly whether the cache is sharded over
kv-heads (divisible case) or over the sequence axis (kv_seq fallback, used
when kv_heads do not divide the model axis -- softmax statistics and the
PV contraction then reduce over the sharded axis with an all-reduce).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import attention as flash_attention
from repro.sharding import constrain

from .layers import _dense_init, apply_rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array                      # (B, Hkv, S, D) -- bf16, or int8 codes
    v: jax.Array
    ks: Optional[jax.Array] = None    # int8 mode: (B, Hkv, S, D/blk) f16
    vs: Optional[jax.Array] = None    # scales (see _q8)


# Scale granularity of the int8 KV cache: one f16 scale per head, per
# position, per `_Q8_SCALE_BLOCK` contiguous head dims.  A single
# per-position scale (the old scheme) lets one outlier dim set the step for
# the whole vector; on the seamless (frames/cross-attention) arch the
# resulting ~1.4e-2 logit noise exceeded near-tie argmax gaps and decode
# diverged.  Sub-head blocks cut the error ~2-3x; f16 scales keep the
# quantized cache well under half the f32 cache (scale error ~2^-11 is
# negligible next to int8 rounding at 1/254).
_Q8_SCALE_BLOCK = 4


def _q8_block(head_dim: int) -> int:
    """Scale-block size for a head dim (whole head when not divisible)."""
    return _Q8_SCALE_BLOCK if head_dim % _Q8_SCALE_BLOCK == 0 else head_dim


def _q8(x):
    """Blockwise int8 quantization along the head dim.

    x: (..., D) -> (codes int8 (..., D), scales f16 (..., D/blk)),
    symmetric absmax scaling per block."""
    d = x.shape[-1]
    blk = _q8_block(d)
    xf = x.astype(jnp.float32).reshape(x.shape[:-1] + (d // blk, blk))
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(x.shape), scale[..., 0].astype(jnp.float16)


def _dq(codes, scales):
    """Dequantize _q8 output to f32 (codes (..., D), scales (..., D/blk))."""
    d = codes.shape[-1]
    nb = scales.shape[-1]
    xf = codes.astype(jnp.float32).reshape(codes.shape[:-1] + (nb, d // nb))
    return (xf * scales.astype(jnp.float32)[..., None]).reshape(codes.shape)


def attn_init(key, d_model, num_heads, num_kv_heads, head_dim,
              qkv_bias: bool = False, cross: bool = False):
    """K and V projections are STACKED on a leading axis (one contraction,
    one backward dx all-reduce -- hillclimb H1)."""
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = _dense_init(ks[0], (d_model, num_heads * head_dim),
                                   ("embed", "heads"))
    wkv = jax.random.normal(ks[1], (2, d_model, num_kv_heads * head_dim),
                            jnp.float32) * d_model ** -0.5
    p["wkv"], a["wkv"] = wkv, ("stack", "embed", "kv_heads")
    p["wo"], a["wo"] = _dense_init(ks[3], (num_heads * head_dim, d_model),
                                   ("heads", "embed"))
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), jnp.float32)
        p["bkv"] = jnp.zeros((2, num_kv_heads * head_dim,), jnp.float32)
        a["bq"], a["bkv"] = ("heads",), ("stack", "kv_heads")
    return p, a


def _project_qkv(params, x, xkv, num_heads, num_kv_heads, head_dim):
    b, t, _ = x.shape
    s = xkv.shape[1]
    q = x @ params["wq"].astype(x.dtype)
    kv = jnp.einsum("bsd,kdh->kbsh", xkv, params["wkv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        kv = kv + params["bkv"].astype(x.dtype)[:, None, None, :]
    k, v = kv[0], kv[1]
    q = q.reshape(b, t, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attn_apply(params, x, *, num_heads, num_kv_heads, head_dim,
               positions=None, causal: bool = True,
               window: Optional[int] = None, rope_theta: float = 10000.0,
               use_rope: bool = True, xkv=None, impl: Optional[str] = None,
               return_cache: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``xkv`` (for cross-attention) defaults to ``x`` (self-attention).
    Returns ``out`` or ``(out, KVCache)`` when ``return_cache``.
    """
    b, t, _ = x.shape
    self_attn = xkv is None
    xkv = x if xkv is None else xkv
    q, k, v = _project_qkv(params, x, xkv, num_heads, num_kv_heads, head_dim)
    if use_rope and self_attn:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = constrain(q, "batch", "act_heads", "seq", None)
    k = constrain(k, "batch", "act_kv_heads", "kv_seq", None)
    v = constrain(v, "batch", "act_kv_heads", "kv_seq", None)
    o = flash_attention(q, k, v, causal=causal and self_attn, window=window,
                        impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, num_heads * head_dim)
    out = o @ params["wo"].astype(x.dtype)
    out = constrain(out, "batch", "seq", "act_embed")
    if return_cache:
        return out, KVCache(k=k, v=v)
    return out


def cross_kv(params, enc_out, num_kv_heads, head_dim, dtype):
    """Project encoder outputs into a static cross-attention KV cache."""
    b, s, _ = enc_out.shape
    kv = jnp.einsum("bsd,kdh->kbsh", enc_out,
                    params["wkv"].astype(enc_out.dtype))
    if "bkv" in params:
        kv = kv + params["bkv"].astype(enc_out.dtype)[:, None, None, :]
    k = kv[0].reshape(b, s, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = kv[1].reshape(b, s, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    return KVCache(k=k.astype(dtype), v=v.astype(dtype))


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------
def init_kv_cache(batch, num_kv_heads, max_len, head_dim, dtype,
                  quant: bool = False):
    if quant:
        nb = head_dim // _q8_block(head_dim)
        z = jnp.zeros((batch, num_kv_heads, max_len, head_dim), jnp.int8)
        s = jnp.ones((batch, num_kv_heads, max_len, nb), jnp.float16)
        return KVCache(k=z, v=z, ks=s, vs=s)
    z = jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype)
    return KVCache(k=z, v=z)


def cache_axes(quant: bool = False):
    ax = ("batch", "act_kv_heads", "kv_seq", None)
    if quant:
        return KVCache(k=ax, v=ax, ks=ax, vs=ax)
    return KVCache(k=ax, v=ax)


def attn_decode(params, x, cache: KVCache, idx, *, num_heads, num_kv_heads,
                head_dim, rope_theta: float = 10000.0, use_rope: bool = True,
                window: Optional[int] = None, cross: bool = False,
                scale: Optional[float] = None):
    """One-token decode. x: (B, 1, d_model); idx: scalar current position.

    For sliding-window layers the cache is a ring buffer of size
    ``window`` -- keys are RoPE'd with absolute positions at insert time, so
    overwriting old slots needs no re-rotation.  ``cross=True`` attends over
    a static (prefilled) cache without inserting.
    """
    b = x.shape[0]
    s = cache.k.shape[2]
    if scale is None:
        scale = head_dim ** -0.5
    q = x @ params["wq"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    q = q.reshape(b, 1, num_heads, head_dim).transpose(0, 2, 1, 3)
    pos = jnp.broadcast_to(idx[None], (b, 1)).astype(jnp.int32)
    if use_rope:
        q = apply_rope(q, pos, rope_theta)

    if not cross:
        kv_new = jnp.einsum("bsd,kdh->kbsh", x,
                            params["wkv"].astype(x.dtype))
        if "bkv" in params:
            kv_new = kv_new + params["bkv"].astype(x.dtype)[:, None, None, :]
        k_new = kv_new[0].reshape(b, 1, num_kv_heads, head_dim) \
            .transpose(0, 2, 1, 3)
        v_new = kv_new[1].reshape(b, 1, num_kv_heads, head_dim) \
            .transpose(0, 2, 1, 3)
        if use_rope:
            k_new = apply_rope(k_new, pos, rope_theta)
        slot = idx % s if window is not None else idx
        if cache.ks is not None:                 # int8 KV mode
            kq, ksc = _q8(k_new)
            vq, vsc = _q8(v_new)
            cache = KVCache(
                k=jax.lax.dynamic_update_slice(cache.k, kq, (0, 0, slot, 0)),
                v=jax.lax.dynamic_update_slice(cache.v, vq, (0, 0, slot, 0)),
                ks=jax.lax.dynamic_update_slice(cache.ks, ksc,
                                                (0, 0, slot, 0)),
                vs=jax.lax.dynamic_update_slice(cache.vs, vsc,
                                                (0, 0, slot, 0)))
        else:
            k_buf = jax.lax.dynamic_update_slice(
                cache.k, k_new.astype(cache.k.dtype), (0, 0, slot, 0))
            v_buf = jax.lax.dynamic_update_slice(
                cache.v, v_new.astype(cache.v.dtype), (0, 0, slot, 0))
            cache = KVCache(k=k_buf, v=v_buf, ks=cache.ks, vs=cache.vs)

    # einsum attention over the cache (GQA via head grouping).  int8 caches
    # dequantize blockwise first -- the cache was being materialized to f32
    # for the contraction anyway, and per-sub-block scales cannot be
    # factored out of the dot product the way a whole-vector scale could
    g = num_heads // num_kv_heads
    qg = q.reshape(b, num_kv_heads, g, head_dim)
    kf = _dq(cache.k, cache.ks) if cache.ks is not None \
        else cache.k.astype(jnp.float32)
    vf = _dq(cache.v, cache.vs) if cache.vs is not None \
        else cache.v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32) * scale, kf)
    kpos = jnp.arange(s)
    if cross:
        valid = kpos[None, None, None, :] >= 0   # whole prefilled cache
    elif window is not None:
        written = jnp.minimum(idx + 1, s)
        valid = kpos[None, None, None, :] < written
    else:
        valid = kpos[None, None, None, :] <= idx
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
    o = o.reshape(b, 1, num_heads * head_dim).astype(x.dtype)
    out = o @ params["wo"].astype(x.dtype)
    return constrain(out, "batch", None, "act_embed"), cache
