"""Parameter accounting + sharding-spec resolution for whole param trees."""
from __future__ import annotations


import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding import Rules, spec as axes_spec

_EXPERT_KEYS = ("w_gu", "w_down")


def _is_axes(x) -> bool:
    return (isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from the abstract init (no allocation).

    ``active_only``: MoE expert weights scaled by k/E (per-token activation).
    """
    from .transformer import abstract_params

    shapes, _ = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0.0
    frac = (cfg.experts_per_token / cfg.num_experts) if cfg.num_experts else 1.0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if active_only and cfg.num_experts and any(
                k in _EXPERT_KEYS for k in keys) and "moe" in keys:
            n = int(n * frac)
        total += n
    return int(total)


def param_specs(axes_tree, rules: Rules, mesh=None, shapes=None):
    """axes pytree (+ optional matching ShapeDtypeStruct pytree) ->
    PartitionSpec pytree."""
    if shapes is None:
        return jax.tree.map(lambda ax: axes_spec(ax, rules), axes_tree,
                            is_leaf=_is_axes)
    return jax.tree.map(
        lambda ax, sh: axes_spec(ax, rules, mesh, sh.shape),
        axes_tree, shapes, is_leaf=_is_axes)


def param_shardings(axes_tree, rules: Rules, mesh, shapes=None):
    from jax.sharding import NamedSharding

    specs = param_specs(axes_tree, rules, mesh, shapes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))
