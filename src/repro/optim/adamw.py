"""AdamW with ZeRO-1-style sharded moments + optional int8 gradient
compression with error feedback (pure-JAX, no optax dependency).

Moments inherit the parameter's logical axes, but are resolved against the
*FSDP* rule set regardless of the model's own rules: optimizer state is
always sharded over ("pod", "data") on the param's embed axis (ZeRO-1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array
    err: Any                  # error-feedback residual (None if no compress)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False    # int8 block-quantized grads + EF


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return schedule


def adamw_init(params, compress: bool = False) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
        err=jax.tree.map(zeros, params) if compress else None)


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def _compress_decompress(g, err):
    """int8 block-quantize + dequantize with error feedback.

    Models the bytes that would cross the data-parallel reduction fabric
    under gradient compression; the residual keeps the update unbiased over
    time (error feedback).
    """
    from repro.kernels.ckpt_codec import quantize, dequantize

    g_comp = g + err
    q, scale = quantize(g_comp)
    g_hat = dequantize(q, scale, g.shape, jnp.float32)
    return g_hat, g_comp - g_hat


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 schedule: Optional[Callable] = None):
    """Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    new_err = state.err
    if cfg.compress_grads and state.err is not None:
        pairs = jax.tree.map(_compress_decompress, grads, state.err)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    lr = schedule(count) if schedule is not None else cfg.lr
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, n):
        mhat = m / b1c
        nhat = n / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = AdamWState(mu=mu, nu=nu, count=count, err=new_err)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_axes(param_axes, compress: bool = False) -> AdamWState:
    """Logical axes for the optimizer state (mirror of params + scalars)."""
    return AdamWState(mu=param_axes, nu=param_axes, count=(),
                      err=param_axes if compress else None)
