from .adamw import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                    opt_state_axes, warmup_cosine)

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "opt_state_axes", "warmup_cosine"]
