"""Crash-dump flight recorder: the last N events + spans, always on.

A bounded ring per controller keeps the most recent bus events (audit
record shape, plus the trace context they were published under) and the
most recent completed spans — cheap enough to run unconditionally.  When
something goes red (an invariant at WARN/CRIT, a ``redistribution_fallback``,
a red chaos seed), :meth:`FlightRecorder.dump` writes the ring to
``artifacts/obs/`` so every failure ships its own timeline, the way a red
chaos seed already ships its schedule.

Dumps are deduplicated by ``reason`` key: one red invariant triggers
exactly one dump no matter how many layers notice the same failure.
"""

from __future__ import annotations

import collections
import json
import os
import threading
from typing import Any, Dict, List, Optional

DEFAULT_DIR = os.path.join("artifacts", "obs")


class FlightRecorder:
    """Bounded event+span ring with deduplicated crash dumps."""

    def __init__(self, clock=None, out_dir: Optional[str] = None,
                 max_events: int = 2048, max_spans: int = 2048):
        self.clock = clock
        self.out_dir = out_dir or DEFAULT_DIR
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(
            maxlen=int(max_events))
        self._spans: collections.deque = collections.deque(
            maxlen=int(max_spans))
        self.events_seen = 0
        self.spans_seen = 0
        self.dumps: Dict[str, str] = {}        # reason key -> dump path

    # ------------------------------------------------------------ feeding
    def on_event(self, ev) -> None:
        """Bus subscriber: ring-append the audit record + trace ids."""
        rec = ev.as_record()
        ctx = getattr(ev, "trace", None)
        if ctx is not None:
            rec["trace_id"] = ctx.trace_id
            rec["span_id"] = ctx.span_id
        with self._lock:
            self._events.append(rec)
            self.events_seen += 1

    def on_span(self, span) -> None:
        """Trace-collector listener: ring-append the completed span."""
        with self._lock:
            self._spans.append(span.as_dict())
            self.spans_seen += 1

    # ------------------------------------------------------------ dumping
    def _safe_key(self, reason: str) -> str:
        return "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in reason)[:120]

    def dump(self, reason: str,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Write the ring to ``<out_dir>/flight_<reason>.json``.

        Idempotent per ``reason``: a repeat trigger returns the existing
        dump path without rewriting (exactly one dump per red cause).
        """
        key = self._safe_key(reason)
        with self._lock:
            if key in self.dumps:
                return self.dumps[key]
            events = list(self._events)
            spans = list(self._spans)
            payload = {
                "reason": reason,
                "sim_t": self.clock.now() if self.clock is not None else 0.0,
                "events_seen": self.events_seen,
                "spans_seen": self.spans_seen,
                "events": events,
                "spans": spans,
            }
            if extra:
                payload["extra"] = extra
            os.makedirs(self.out_dir, exist_ok=True)
            path = os.path.abspath(
                os.path.join(self.out_dir, f"flight_{key}.json"))
            with open(path, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True, default=str)
            self.dumps[key] = path
            return path

    # ------------------------------------------------------------ reading
    def recent_events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def recent_spans(self) -> List[dict]:
        with self._lock:
            return list(self._spans)
