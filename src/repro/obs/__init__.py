"""Observability substrate: sim-time tracing, histograms, flight recorder.

See ARCHITECTURE.md "Observability" for the span taxonomy and the
``trace_id`` convention.
"""

from .flight import FlightRecorder
from .hist import LogHistogram
from .trace import Span, TraceCollector, TraceContext, trace_id_for

__all__ = [
    "FlightRecorder",
    "LogHistogram",
    "Span",
    "TraceCollector",
    "TraceContext",
    "trace_id_for",
]
