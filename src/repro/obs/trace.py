"""Sim-time distributed tracing for the checkpoint service core.

One checkpoint's life — client commit → device/host encode → L1 puts →
L2 drain → L3 trickle → restore or redistribution — crosses the client
thread, every agent inbox worker, the drain pool and its background lane,
and (for a zero-stall resize) an overlap window.  This module stitches
those hops into a single causal span tree per ``trace_id``:

* ``TraceContext`` — an immutable ``(trace_id, span_id, parent_id)``
  triple.  The *current* context is thread-local; queue hand-offs
  (agent ``_Op``s, drain submissions, background-lane closures) carry it
  explicitly and reinstate it with :meth:`TraceCollector.use` on the
  consuming thread.
* ``trace_id`` convention: ``"{app}/c{ckpt_id}"`` — derivable from any
  event payload that names the app and checkpoint, so late phases
  (drain retries, the L3 trickle, a restore hours later) re-join the
  tree without having had the context threaded to them: a span started
  with a ``trace_id`` but no parent attaches to that trace's root span.
* Spans live in **sim time** (the :class:`~repro.core.simnet.SimClock`);
  durations are the analytic sim seconds the operation accounted for.
* Export is Chrome/Perfetto ``trace_event`` JSON
  (:meth:`TraceCollector.to_chrome_trace`): one *process* per track
  prefix (node, client, service), one *thread* per full track name, so
  ``chrome://tracing`` / https://ui.perfetto.dev render one lane per
  node/agent/service.

Disabled collectors (the default) are no-ops on the hot path: ``record``
returns ``None`` immediately and ``span``/``use`` yield without touching
the thread-local, so tracing costs nothing unless asked for.
"""

from __future__ import annotations

import itertools
import json
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class TraceContext:
    """Propagated identity of one span: where new child spans attach."""

    trace_id: str
    span_id: int
    parent_id: Optional[int] = None


@dataclass
class Span:
    """One completed operation on one track, in sim time."""

    name: str
    trace_id: str
    span_id: int
    parent_id: Optional[int]
    track: str
    t0: float
    dur_s: float
    args: Dict[str, Any] = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "track": self.track,
            "t0": self.t0,
            "dur_s": self.dur_s,
            "args": dict(self.args),
        }


def trace_id_for(app_id: str, ckpt_id) -> str:
    """The canonical trace id of one checkpoint's life."""
    return f"{app_id}/c{ckpt_id}"


class TraceCollector:
    """Bounded, thread-safe span sink with Chrome trace export.

    ``enabled=False`` (default) keeps every entry point a near-free no-op
    so the tracer can be wired unconditionally through the core.
    """

    def __init__(self, clock=None, enabled: bool = False,
                 max_spans: int = 200_000):
        self.clock = clock
        self.enabled = bool(enabled)
        self.max_spans = int(max_spans)
        self.dropped = 0
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._ids = itertools.count(1)
        # trace_id -> root span_id: parentless spans of a known trace
        # attach here, which is what keeps cross-thread phases (drain,
        # trickle, restore) connected without explicit context plumbing
        self._roots: Dict[str, int] = {}
        self._tls = threading.local()
        # listeners observe completed spans (the flight recorder's ring)
        self._listeners: List[Any] = []

    # ------------------------------------------------------------ context
    def current(self) -> Optional[TraceContext]:
        if not self.enabled:
            return None
        return getattr(self._tls, "ctx", None)

    @contextmanager
    def use(self, ctx: Optional[TraceContext]):
        """Reinstate a handed-off context on the consuming thread."""
        if not self.enabled or ctx is None:
            yield
            return
        prev = getattr(self._tls, "ctx", None)
        self._tls.ctx = ctx
        try:
            yield
        finally:
            self._tls.ctx = prev

    # ------------------------------------------------------------ recording
    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else 0.0

    def _resolve_parent(self, trace_id: str,
                        parent: Optional[TraceContext]) -> Optional[int]:
        if parent is not None and parent.trace_id == trace_id:
            return parent.span_id
        cur = self.current()
        if cur is not None and cur.trace_id == trace_id:
            return cur.span_id
        return self._roots.get(trace_id)

    def record(self, name: str, trace_id: str, track: str,
               t0: Optional[float] = None, dur_s: float = 0.0,
               parent: Optional[TraceContext] = None,
               root: bool = False, **args) -> Optional[TraceContext]:
        """Append one completed span with an analytic sim duration.

        Returns the span's :class:`TraceContext` (for hand-off to child
        operations), or ``None`` when the collector is disabled.
        """
        if not self.enabled:
            return None
        span_id = next(self._ids)
        with self._lock:
            parent_id = None if root else self._resolve_parent(trace_id,
                                                               parent)
            if root and trace_id not in self._roots:
                self._roots[trace_id] = span_id
            span = Span(name=name, trace_id=trace_id, span_id=span_id,
                        parent_id=parent_id, track=track,
                        t0=self._now() if t0 is None else float(t0),
                        dur_s=max(0.0, float(dur_s)), args=dict(args))
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
            else:
                self._spans.append(span)
            listeners = list(self._listeners)
        for listener in listeners:
            try:
                listener(span)
            except Exception:  # noqa: BLE001 - observers must not break us
                pass
        return TraceContext(trace_id=trace_id, span_id=span_id,
                            parent_id=parent_id)

    @contextmanager
    def span(self, name: str, trace_id: str, track: str,
             parent: Optional[TraceContext] = None, root: bool = False,
             **args):
        """Context-managed span: duration is the sim-clock delta across the
        body, and the body runs with the new span as the current context
        (children started inside attach to it)."""
        if not self.enabled:
            yield None
            return
        t0 = self._now()
        span_id = next(self._ids)
        with self._lock:
            parent_id = None if root else self._resolve_parent(trace_id,
                                                               parent)
            if root and trace_id not in self._roots:
                self._roots[trace_id] = span_id
        ctx = TraceContext(trace_id=trace_id, span_id=span_id,
                           parent_id=parent_id)
        prev = getattr(self._tls, "ctx", None)
        self._tls.ctx = ctx
        try:
            yield ctx
        finally:
            self._tls.ctx = prev
            span = Span(name=name, trace_id=trace_id, span_id=span_id,
                        parent_id=parent_id, track=track, t0=t0,
                        dur_s=max(0.0, self._now() - t0), args=dict(args))
            with self._lock:
                if len(self._spans) >= self.max_spans:
                    self.dropped += 1
                else:
                    self._spans.append(span)
                listeners = list(self._listeners)
            for listener in listeners:
                try:
                    listener(span)
                except Exception:  # noqa: BLE001
                    pass

    def add_listener(self, listener) -> None:
        with self._lock:
            self._listeners.append(listener)

    # ------------------------------------------------------------ inspection
    def spans(self, trace_id: Optional[str] = None) -> List[Span]:
        with self._lock:
            if trace_id is None:
                return list(self._spans)
            return [s for s in self._spans if s.trace_id == trace_id]

    def trace_ids(self) -> List[str]:
        with self._lock:
            seen: Dict[str, None] = {}
            for s in self._spans:
                seen.setdefault(s.trace_id, None)
            return list(seen)

    def root_of(self, trace_id: str) -> Optional[int]:
        with self._lock:
            return self._roots.get(trace_id)

    # ------------------------------------------------------------ export
    def _track_ids(self, spans: List[Span]) -> Dict[str, Tuple[int, int]]:
        """Stable (pid, tid) per track: pid per prefix before the first
        '/', tid per full track name — one Perfetto lane per agent/lane."""
        pids: Dict[str, int] = {}
        tids: Dict[str, int] = {}
        out: Dict[str, Tuple[int, int]] = {}
        for s in spans:
            if s.track in out:
                continue
            proc = s.track.split("/", 1)[0]
            pid = pids.setdefault(proc, len(pids) + 1)
            tid = tids.setdefault(s.track, len(tids) + 1)
            out[s.track] = (pid, tid)
        return out

    def to_chrome_trace(self) -> dict:
        """Render every collected span as Chrome ``trace_event`` JSON
        (the dict form: load the file in chrome://tracing or Perfetto)."""
        spans = self.spans()
        tracks = self._track_ids(spans)
        events: List[dict] = []
        procs_done = set()
        for track, (pid, tid) in tracks.items():
            proc = track.split("/", 1)[0]
            if pid not in procs_done:
                procs_done.add(pid)
                events.append({"ph": "M", "name": "process_name",
                               "pid": pid, "tid": 0,
                               "args": {"name": proc}})
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pid, "tid": tid,
                           "args": {"name": track}})
        for s in spans:
            pid, tid = tracks[s.track]
            events.append({
                "ph": "X",
                "name": s.name,
                "cat": "ckpt",
                "ts": s.t0 * 1e6,          # trace_event wants microseconds
                "dur": s.dur_s * 1e6,
                "pid": pid,
                "tid": tid,
                "args": {**s.args, "trace_id": s.trace_id,
                         "span_id": s.span_id, "parent_id": s.parent_id},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"clock": "sim", "dropped_spans": self.dropped}}

    def write_chrome_trace(self, path: str) -> str:
        import os

        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1, sort_keys=True)
        return os.path.abspath(path)
