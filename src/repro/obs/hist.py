"""Fixed-bucket log2 histograms for latency/size distributions.

The telemetry EWMAs answer "what is the current estimate"; these answer
"where did the mass go" — the p50/p95/p99 the ROADMAP's QoS scheduler
needs.  Buckets are *fixed* powers of two (no dynamic rebucketing), so
the Prometheus ``le`` labels are stable across scrapes and across runs,
and two histograms of the same family are always mergeable.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Sequence, Tuple


class LogHistogram:
    """Counts per power-of-two bucket, with interpolated quantiles.

    ``lo_exp``/``hi_exp`` bound the bucket upper edges ``2**e`` for
    ``e in [lo_exp, hi_exp]``; values above ``2**hi_exp`` land in the
    overflow (``+Inf``) bucket.  Defaults cover ~1 µs .. ~1 h of sim
    seconds; use ``LogHistogram.for_bytes()`` for size distributions.
    """

    def __init__(self, lo_exp: int = -20, hi_exp: int = 12):
        self.bounds: Tuple[float, ...] = tuple(
            float(2.0 ** e) for e in range(int(lo_exp), int(hi_exp) + 1))
        self._counts: List[int] = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    @classmethod
    def for_bytes(cls) -> "LogHistogram":
        return cls(lo_exp=6, hi_exp=44)      # 64 B .. 16 TiB

    # ------------------------------------------------------------ recording
    def _bucket_index(self, value: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:                       # first bound >= value
            mid = (lo + hi) // 2
            if self.bounds[mid] < value:
                lo = mid + 1
            else:
                hi = mid
        return lo

    def observe(self, value: float) -> None:
        v = max(0.0, float(value))
        idx = self._bucket_index(v)
        with self._lock:
            self._counts[idx] += 1
            self.sum += v
            self.count += 1

    # ------------------------------------------------------------ reading
    def counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)

    def quantile(self, q: float) -> float:
        """Linear interpolation inside the target bucket (0 when empty)."""
        with self._lock:
            total = self.count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        rank = max(0.0, min(1.0, float(q))) * total
        seen = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if seen + c >= rank:
                frac = (rank - seen) / c
                hi = self.bounds[i] if i < len(self.bounds) \
                    else self.bounds[-1] * 2.0
                lo = self.bounds[i - 1] if i > 0 else 0.0
                return lo + frac * (hi - lo)
            seen += c
        return self.bounds[-1] * 2.0

    def quantiles(self, qs: Sequence[float] = (0.5, 0.95, 0.99)
                  ) -> Dict[str, float]:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    def as_dict(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s = self.count, self.sum
        out = {"count": total, "sum": s}
        if total:
            out.update(self.quantiles())
        return out

    def prometheus_rows(self) -> List[Tuple[str, float]]:
        """Cumulative ``(le, count)`` rows ending in ``+Inf`` — the
        Prometheus histogram bucket contract."""
        with self._lock:
            counts = list(self._counts)
        rows: List[Tuple[str, float]] = []
        cum = 0
        for bound, c in zip(self.bounds, counts):
            cum += c
            rows.append((f"{bound:.9g}", float(cum)))
        rows.append(("+Inf", float(cum + counts[-1])))
        return rows
