"""Invariants-as-code: the judged properties of a chaos campaign.

Checks are plain decorated functions (``@invariant("name")``) returning a
Checkmk-style :class:`Status` — OK(0)/WARN(1)/CRIT(2) — plus a detail
string.  They run *after* the campaign workload finishes, against the
evidence the campaign collected (:class:`~repro.chaos.campaign
.CampaignEvidence`): the cluster's audit log, the per-app numpy oracles,
the harness's own ground-truth counters, and the live cluster objects for
end-state scans.

A CRIT means the campaign *observed a correctness violation* — not that a
fault happened (faults are the input).  WARN flags suspicious-but-legal
outcomes (e.g. nothing to compare) so a silently vacuous campaign can't
read as green coverage.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Tuple

from repro.core import events as E

# the catalog's mandatory-reset vocabulary, plus the control-plane reasons
# emitted outside the bus subscriber (resize commit, app teardown, a commit
# whose encode failed mid-flight)
ALLOWED_RESET_REASONS = frozenset({
    E.APP_RANK_FAILED,
    E.NODE_FAILED,
    E.AGENT_FAILED,
    E.NODE_RETAKEN,
    E.MIGRATION_LOST_SHARD,
    E.CKPT_FAILED,
    E.CKPT_EXPIRED,
    E.SHARD_DEMOTED,
    E.CONTROLLER_RECOVERED,
    "resize",
    "app_finished",
    "commit_encode_failed",
})

# events after which ``latest_restartable`` may legitimately move backwards
# (something that could destroy, orphan or hide the newest checkpoint)
_DESTRUCTIVE_EVENTS = frozenset({
    E.CKPT_FAILED,
    E.CKPT_EXPIRED,
    E.NODE_FAILED,
    E.AGENT_FAILED,
    E.NODE_RETAKEN,
    E.MIGRATION_LOST_SHARD,
    E.SHARD_DEMOTED,
    E.CHAOS_INJECTED,
    E.CHAOS_CLEARED,
    # a warm recovery conservatively fails PENDING checkpoints and may
    # downgrade between durable tiers, so latest_restartable may step back
    E.CONTROLLER_RECOVERED,
})

# triggers whose firing *requires* a reset of any live chain of the
# affected app(s): app-scoped (payload names the app) vs cluster-wide
_APP_TRIGGERS = (E.APP_RANK_FAILED, E.CKPT_FAILED)
_CLUSTER_TRIGGERS = (E.NODE_FAILED, E.AGENT_FAILED)
# how far (in audit records) a reset may sit from its trigger: the catalog
# resets inside the trigger's publish fan-out, so the reset usually lands
# *before* the trigger in the log
_TRIGGER_SLACK = 50


class Status(enum.IntEnum):
    OK = 0
    WARN = 1
    CRIT = 2


@dataclasses.dataclass(frozen=True)
class CheckResult:
    name: str
    status: Status
    detail: str

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "status": self.status.name,
            "detail": self.detail,
        }


Check = Callable[[object], Tuple[Status, str]]
REGISTRY: Dict[str, Check] = {}


def invariant(name: str) -> Callable[[Check], Check]:
    """Register a check function under ``name`` (watchpost style: the
    decorated function *is* the invariant's definition and its doc)."""

    def deco(fn: Check) -> Check:
        REGISTRY[name] = fn
        return fn

    return deco


def run_checks(ev) -> List[CheckResult]:
    """Run every registered invariant against campaign evidence; a check
    that itself crashes is a CRIT (a broken check must not read as green)."""
    results: List[CheckResult] = []
    for name in sorted(REGISTRY):
        try:
            status, detail = REGISTRY[name](ev)
        except Exception as exc:  # noqa: BLE001 - surface, never mask
            status, detail = Status.CRIT, f"check raised: {exc!r}"
        results.append(CheckResult(name=name, status=status, detail=detail))
    return results


# ==========================================================================
# the checks
# ==========================================================================
@invariant("restore_bit_identity")
def check_restore_bit_identity(ev) -> Tuple[Status, str]:
    """Every restore (mid-campaign rank failures + the final sweep) must be
    bit-identical to the numpy oracle of the restored checkpoint: raw bytes
    for lossless codecs, the blockwise-q8 roundtrip for q8/q8-delta (delta
    replay reconstructs the head's exact codes, so chain shape is
    irrelevant to the oracle)."""
    bad = [c for c in ev.restore_checks if not c["ok"]]
    if bad:
        worst = bad[0]
        return Status.CRIT, (
            f"{len(bad)}/{len(ev.restore_checks)} restores corrupt; first: "
            f"app={worst['app']} ckpt={worst['ckpt']} {worst['detail']}")
    if not ev.restore_checks:
        return Status.WARN, "no restore was ever compared (vacuous campaign)"
    return Status.OK, "all compared restores bit-identical to the oracle"


@invariant("latest_restartable_monotonic")
def check_latest_restartable_monotonic(ev) -> Tuple[Status, str]:
    """``latest_restartable`` never regresses past an intact checkpoint:
    between two observations it may only move backwards if something
    destructive (failure, expiry, demotion, chaos action) happened in
    between — never spontaneously."""
    names = [r["event"] for r in ev.records]
    for app, obs in ev.restartable_obs.items():
        prev_idx, prev_ckpt = 0, None
        for idx, ckpt in obs:
            if (prev_ckpt is not None and (ckpt is None or ckpt < prev_ckpt)):
                lo = max(0, prev_idx - _TRIGGER_SLACK)
                hi = min(len(names), idx + _TRIGGER_SLACK)
                window = names[lo:hi]
                if not any(n in _DESTRUCTIVE_EVENTS for n in window):
                    return Status.CRIT, (
                        f"app={app}: latest_restartable regressed "
                        f"{prev_ckpt} -> {ckpt} with no destructive event "
                        f"in between")
            prev_idx, prev_ckpt = idx, ckpt
    return Status.OK, "latest_restartable only regressed under destruction"


@invariant("delta_chain_reset_policy")
def check_delta_chain_reset_policy(ev) -> Tuple[Status, str]:
    """Delta chains reset exactly when they must, and only then: every
    mandatory trigger that fires while a chain is live is followed by a
    matching ``DELTA_CHAIN_RESET`` (the catalog resets inside the trigger's
    fan-out, so the reset may precede the trigger in the log), and every
    reset names an allowed reason with a corroborating trigger nearby."""
    records = ev.records
    # -- "only then": every reset justified -------------------------------
    for i, rec in enumerate(records):
        if rec["event"] != E.DELTA_CHAIN_RESET:
            continue
        reason = rec.get("reason", "")
        if reason not in ALLOWED_RESET_REASONS:
            return Status.CRIT, (
                f"reset of {rec.get('app')}/{rec.get('region')} with "
                f"unknown reason {reason!r}")
        lo = max(0, i - _TRIGGER_SLACK)
        hi = min(len(records), i + _TRIGGER_SLACK)
        if reason == "resize":
            corroborated = ev.resizes > 0 or any(
                r["event"] in (E.REDISTRIBUTION_STARTED, E.RESIZE_FOREWARNED)
                for r in records[lo:hi])
        elif reason in ("app_finished", "commit_encode_failed"):
            corroborated = True  # harness-side teardown/commit paths
        else:
            corroborated = any(r["event"] == reason
                               for r in records[lo:hi])
        if not corroborated:
            return Status.CRIT, (
                f"reset of {rec.get('app')}/{rec.get('region')} claims "
                f"reason {reason!r} but no such trigger fired nearby")
    # -- "exactly when they must": no suppressed mandatory reset ----------
    alive: Dict[str, bool] = {}
    for i, rec in enumerate(records):
        name = rec["event"]
        if name == E.CKPT_DELTA_COMMITTED:
            if int(rec.get("key_frames", 0)) + \
                    int(rec.get("delta_frames", 0)) > 0:
                alive[rec["app"]] = True
        elif name == E.DELTA_CHAIN_RESET:
            alive[rec["app"]] = False
        elif name == E.CONTROLLER_RECOVERED:
            # no chain survives a warm recovery: journal-open chains get
            # explicit resets, and any chain the lazy-buffered journal
            # never saw died with the process (next commit keyframes)
            for app in alive:
                alive[app] = False
        elif name in _APP_TRIGGERS or name in _CLUSTER_TRIGGERS:
            affected = [rec["app"]] if name in _APP_TRIGGERS \
                else [a for a, live in alive.items() if live]
            for app in affected:
                if not alive.get(app):
                    continue
                hi = min(len(records), i + _TRIGGER_SLACK)
                # a trigger that lands inside a controller-crash window
                # can't be fan-out-handled — the recovery's conservative
                # chain invalidation discharges it instead
                if not any(
                        (r["event"] == E.DELTA_CHAIN_RESET
                         and r.get("app") == app
                         and r.get("reason") in (name,
                                                 E.CONTROLLER_RECOVERED))
                        or r["event"] == E.CONTROLLER_RECOVERED
                        for r in records[i:hi]):
                    return Status.CRIT, (
                        f"app={app}: {name} fired with a live delta chain "
                        f"but no matching reset followed")
                alive[app] = False
    return Status.OK, "every mandatory trigger reset, every reset justified"


@invariant("no_event_bus_stall")
def check_no_event_bus_stall(ev) -> Tuple[Status, str]:
    """No deadlock or unbounded stall: every bounded wait in the campaign
    resolved, every driver thread finished inside its wall budget, and sim
    time stayed under the campaign's bound."""
    if ev.stalls:
        return Status.CRIT, f"stalled: {'; '.join(ev.stalls[:3])}"
    if ev.driver_errors:
        return Status.CRIT, (
            f"driver raised: {'; '.join(ev.driver_errors[:3])}")
    if ev.final_sim_t > ev.sim_bound_s:
        return Status.WARN, (
            f"sim time {ev.final_sim_t:.2f}s exceeded bound "
            f"{ev.sim_bound_s:.2f}s")
    return Status.OK, "all waits bounded, drivers joined, sim time in bound"


@invariant("telemetry_matches_ground_truth")
def check_telemetry_matches_ground_truth(ev) -> Tuple[Status, str]:
    """The bus-fed telemetry gauges must agree with counters derived
    independently from the audit log and from the harness's own commit
    accounting — a dropped or double-counted event is an observability
    corruption even when the data plane is intact."""
    snap = ev.telemetry_snapshot.get("per_app", {})
    names_payloads = [(r["event"], r) for r in ev.records]

    def count(event: str, app: str = None, **match) -> int:
        n = 0
        for name, rec in names_payloads:
            if name != event:
                continue
            if app is not None and rec.get("app") != app:
                continue
            if any(rec.get(k) != v for k, v in match.items()):
                continue
            n += 1
        return n

    cluster_failures = sum(count(e) for e in _CLUSTER_TRIGGERS)
    mismatches: List[str] = []
    for app in ev.apps:
        tel = snap.get(app)
        if tel is None:
            mismatches.append(f"{app}: missing from telemetry")
            continue
        expected = {
            "commits": count(E.COMMIT_DONE, app),
            "failures": count(E.APP_RANK_FAILED, app) + cluster_failures,
            "delta_chain_resets": count(E.DELTA_CHAIN_RESET, app),
            "redistributions_peer": count(E.REDISTRIBUTION_DONE, app,
                                          via="peer"),
            "redistributions_client": count(E.REDISTRIBUTION_DONE, app,
                                            via="client"),
            "overlap_windows": count(E.RESIZE_OVERLAP_STARTED, app),
            "overlap_cutovers": count(E.CUTOVER_DONE, app),
            "redist_fallbacks": count(E.REDISTRIBUTION_FALLBACK, app),
            "ckpt_failures": count(E.CKPT_FAILED, app),
        }
        for key, want in expected.items():
            got = tel.get(key)
            if got != want:
                mismatches.append(f"{app}.{key}: telemetry={got} "
                                  f"audit-log={want}")
        harness_commits = ev.commit_counts.get(app, 0)
        if tel.get("commits", 0) < harness_commits:
            mismatches.append(
                f"{app}.commits: telemetry={tel.get('commits')} < "
                f"{harness_commits} acked blocking commits")
    if mismatches:
        return Status.CRIT, "; ".join(mismatches[:4])
    return Status.OK, "telemetry agrees with audit log and harness counts"


@invariant("ec_multi_death_durability")
def check_ec_multi_death_durability(ev) -> Tuple[Status, str]:
    """m simultaneous agent deaths never cost the erasure-coded app a
    restorable checkpoint: whenever a ``multi_agent_death`` action fired,
    every compared restore of the EC app stayed bit-identical to the numpy
    oracle, and the campaign actually committed erasure stripes (a campaign
    that never struck the EC path must not read as green coverage)."""
    deaths = [r for r in ev.records
              if r["event"] == E.CHAOS_INJECTED
              and r.get("kind") == "multi_agent_death"
              and r.get("detail") != "skipped (target gone)"]
    if not deaths:
        return Status.OK, "no multi_agent_death action this seed"
    ec = ev.telemetry_snapshot.get("ec", {})
    if not ec.get("stripes_committed"):
        return Status.WARN, (f"{len(deaths)} multi-death action(s) fired "
                             f"but no erasure stripe was ever committed "
                             f"(vacuous)")
    alpha = [c for c in ev.restore_checks if c["app"] == "alpha"]
    bad = [c for c in alpha if not c["ok"]]
    if bad:
        return Status.CRIT, (
            f"{len(bad)} corrupt EC-app restore(s) after {len(deaths)} "
            f"multi-death action(s); first: ckpt={bad[0]['ckpt']} "
            f"{bad[0]['detail']}")
    compared = [c for c in alpha if c["ok"] and not c.get("skipped")]
    if not compared:
        return Status.WARN, ("multi_agent_death fired but no EC-app "
                             "restore was ever compared")
    return Status.OK, (f"{len(deaths)} multi-death action(s) survived; "
                       f"{len(compared)} EC-app restore(s) bit-identical")


@invariant("recovery_fidelity")
def check_recovery_fidelity(ev) -> Tuple[Status, str]:
    """After every controller crash + warm recovery: ``latest_restartable``
    is bit-identically restorable (judged by the numpy oracles) and never
    *newer* than journaled truth (no phantom checkpoints invented by the
    rebuild); recovery knows at least as much as the PFS durably holds
    (a lost or suppressed journal write is exactly this clause going red);
    and an op stamped with the pre-crash epoch is provably rejected."""
    reports = getattr(ev, "recovery_reports", None) or []
    crashes = [r for r in ev.records
               if r["event"] == E.CHAOS_INJECTED
               and r.get("kind") == "controller_crash"
               and not str(r.get("detail", "")).startswith("skipped")]
    if not reports:
        if crashes:
            return Status.CRIT, (
                f"{len(crashes)} controller crash(es) fired but no "
                f"recovery report was collected")
        return Status.OK, "no controller crash this seed"
    problems: List[str] = []
    for i, rep in enumerate(reports):
        # bound against journal truth as of *after* the post-recovery
        # measurement: live drivers keep journaling commits throughout the
        # recovery sequence, and truth only ever grows
        truth = rep.get("truth_after") or rep["truth_before"]
        for app, latest in rep["post_latest"].items():
            bound = truth.get(app, -1)
            if latest is not None and latest > bound:
                problems.append(
                    f"#{i} {app}: latest_restartable={latest} newer than "
                    f"journaled truth {bound} (phantom checkpoint)")
        for app, known in rep["max_known"].items():
            pfs_hi = rep["pfs_before"].get(app, -1)
            if known < pfs_hi:
                problems.append(
                    f"#{i} {app}: recovery knows up to ckpt {known} but "
                    f"PFS durably holds up to {pfs_hi} (journal write "
                    f"lost or suppressed)")
            # the catalog bound is the deterministic form of the same
            # clause: journal-before-state means every id the pre-crash
            # catalog issued was journaled first, independent of whether
            # its drain reached a PFS manifest before the crash landed
            cat_hi = (rep.get("known_before") or {}).get(app, -1)
            if known < cat_hi:
                problems.append(
                    f"#{i} {app}: recovery knows up to ckpt {known} but "
                    f"the pre-crash catalog had issued up to {cat_hi} "
                    f"(journal write lost or suppressed)")
        if rep["stale_probe"] == "accepted":
            problems.append(f"#{i}: op stamped with the pre-crash epoch "
                            f"was accepted after recovery (fence broken)")
        bad = [c for c in rep["post_restores"] if not c["ok"]]
        if bad:
            problems.append(
                f"#{i}: {len(bad)} corrupt post-recovery restore(s); "
                f"first: app={bad[0]['app']} ckpt={bad[0]['ckpt']} "
                f"{bad[0]['detail']}")
    if problems:
        return Status.CRIT, "; ".join(problems[:4])
    if all(r["stale_probe"] == "skipped" for r in reports):
        return Status.WARN, (f"{len(reports)} recovery(ies) clean, but no "
                             f"stale-epoch probe ever landed (vacuous "
                             f"fencing coverage)")
    return Status.OK, (
        f"{len(reports)} crash(es) recovered: latest_restartable within "
        f"journaled truth, PFS fully accounted, stale ops fenced, "
        f"post-recovery restores bit-identical")


@invariant("no_leaked_window_state")
def check_no_leaked_window_state(ev) -> Tuple[Status, str]:
    """After every overlap window has closed: no ``.redist`` scratch
    generation survives in any tier, no chain hold remains open, and no
    agent retains assembly state or decoded-payload memo."""
    leaks: List[str] = []
    ctl = ev.cluster.controller
    for mgr in ctl.managers():
        scratch = [k for k in mgr.store.keys() if ".redist" in k.region]
        if scratch:
            leaks.append(f"{mgr.node_id}: {len(scratch)} scratch shards")
        for agent in mgr.agents():
            st = agent.stats()
            if st["assembly_states"]:
                leaks.append(f"{agent.agent_id}: "
                             f"{st['assembly_states']} assembly states")
            if st["decoded_memo"]:
                leaks.append(f"{agent.agent_id}: "
                             f"{st['decoded_memo']} decoded memo entries")
    holds = ctl.catalog.chain_holds()
    if holds:
        leaks.append(f"open chain holds: {sorted(holds)}")
    if leaks:
        return Status.CRIT, "; ".join(leaks[:4])
    return Status.OK, "no scratch, no holds, no retained window state"
