"""One chaos campaign: a seeded schedule fired into a live two-app cluster.

The workload is fixed so a seed's outcome is a function of its schedule
alone: app ``alpha`` (raw codec, 4 ranks) runs the benchmark harness's
failure-injected compute/checkpoint loop (``benchmarks.common
.run_ckpt_workload``) on a worker thread; app ``beta`` (q8-delta codec,
6 ranks, churning data) is stepped by the campaign's main loop and — when
the schedule says so — opens a zero-stall overlap resize window and cuts
over mid-chaos.  The :class:`ChaosInjector` polls sim time from both
drivers and fires each :class:`~repro.chaos.schedule.ChaosAction` the
first tick at or past its offset, clearing transient faults when their
``duration_s`` elapses.

Everything the invariants judge is collected into
:class:`CampaignEvidence` *while the cluster is still alive* (the leak
check scans live tiers/agents), then ``run_checks`` renders the verdict
and :func:`run_campaign` returns a deterministic report dict.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import threading
import time
import traceback
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import events as E
from repro.core.cluster import ICheckCluster
from repro.core.client import ICheckClient
from repro.core.services.journal import StaleEpochError
from repro.core.types import ICheckError, ShardKey
from repro.kernels.ckpt_codec.blocks import (dequantize_np, quantize_np,
                                             to_blocks_np)

from .invariants import run_checks
from .schedule import (CRASH_MODES, MID_WINDOW_FAULTS, ChaosAction,
                       ChaosSchedule, generate_schedule)

# the benchmark harness lives at the repo root, outside ``src`` — the
# campaign reuses its workload loop rather than forking a copy
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
if _REPO_ROOT not in sys.path:  # pragma: no cover - import plumbing
    sys.path.insert(0, _REPO_ROOT)
from benchmarks.common import block_parts, run_ckpt_workload  # noqa: E402

# errors a fault is *allowed* to surface to a driver (the campaign records
# and tolerates them; anything else is a bug in the system under test).
# concurrent.futures.TimeoutError is a distinct type until Python 3.11.
TOLERATED_ERRORS = (
    ICheckError,
    ConnectionError,
    TimeoutError,
    _FutureTimeout,
    KeyError,
)

WALL_BUDGET_S = 120.0       # whole-campaign wall budget (stall backstop)
CUTOVER_WAIT_S = 30.0       # bounded wait on the overlap cutover handle
ALPHA_JOIN_S = 60.0         # bounded join on the workload thread
SIM_BOUND_FACTOR = 8.0      # sim-time bound = factor * horizon + 10s
CRASH_GRACE_S = 0.6         # sim grace for drain/window crash deferral
STALE_PROBE_WAIT_S = 5.0    # wall bound on one stale-epoch probe op


@dataclasses.dataclass
class CampaignEvidence:
    """Everything the invariant registry consumes, collected live."""

    cluster: ICheckCluster
    apps: Tuple[str, ...]
    records: List[dict]
    telemetry_snapshot: dict
    restore_checks: List[dict]
    restartable_obs: Dict[str, List[Tuple[int, Optional[int]]]]
    commit_counts: Dict[str, int]
    stalls: List[str]
    driver_errors: List[str]
    notes: List[str]
    resizes: int
    final_sim_t: float
    sim_bound_s: float
    # one entry per fired controller_crash action (see the crash hook in
    # run_campaign): journaled truth / PFS high-water captured just before
    # the crash, what recovery rebuilt, and the stale-epoch probe verdict
    recovery_reports: List[dict] = dataclasses.field(default_factory=list)


def _q8_roundtrip(x: np.ndarray) -> np.ndarray:
    """The numpy oracle for q8/q8-delta restores: a restore of commit *t*
    must equal this independent blockwise-q8 roundtrip of x_t — delta
    replay reconstructs the head's exact codes, so chain shape never
    enters the oracle."""
    flat = np.ascontiguousarray(x).reshape(-1)
    blocks, n = to_blocks_np(flat)
    codes, scales = quantize_np(blocks)
    return dequantize_np(codes, scales, n, x.dtype).reshape(x.shape)


class ChaosInjector:
    """Resolves a schedule's symbolic targets against the live cluster and
    fires/clears actions as sim time passes the offsets."""

    def __init__(self, cluster: ICheckCluster, schedule: ChaosSchedule,
                 apps: Tuple[str, ...], t0: float):
        self.cluster = cluster
        self.ctl = cluster.controller
        self.fault = cluster.fault
        self.apps = apps
        self.t0 = t0
        # topology snapshot at campaign start: symbolic node index i always
        # means the i-th *initial* node, dead or alive
        self.node_ids = [m.node_id for m in self.ctl.managers()]
        self._pending = sorted(schedule.actions, key=lambda a: a.at_s)
        self._clears: List[Tuple[float, str, object]] = []
        self._lock = threading.Lock()
        self.fired: List[str] = []
        # wired by run_campaign after the drivers exist: crash_hook(mode)
        # performs crash+recover+probes and returns a detail string;
        # crash_ready(mode) gates the "drain"/"window" timing modes
        self.crash_hook = None
        self.crash_ready = None

    # ------------------------------------------------------------- polling
    def poll(self, now: float) -> None:
        rel = now - self.t0
        with self._lock:
            due = [a for a in self._pending if a.at_s <= rel]
            self._pending = [a for a in self._pending if a.at_s > rel]
            clears = [c for c in self._clears if c[0] <= rel]
            self._clears = [c for c in self._clears if c[0] > rel]
        for action in due:
            self._fire(action, rel)
        for _, desc, fn in clears:
            try:
                fn()
            except TOLERATED_ERRORS:
                pass
            self.ctl.bus.publish(E.CHAOS_CLEARED, kind=desc)

    def quiesce(self) -> None:
        """Clear every outstanding transient; drop unfired actions."""
        with self._lock:
            clears, self._clears = self._clears, []
            self._pending = []
        for _, desc, fn in clears:
            try:
                fn()
            except TOLERATED_ERRORS:
                pass
            self.ctl.bus.publish(E.CHAOS_CLEARED, kind=desc)

    # ------------------------------------------------------------ dispatch
    def _mgr(self, node_id: str):
        for m in self.ctl.managers():
            if m.node_id == node_id:
                return m
        return None

    def _agent_for(self, target: Dict[str, int]):
        app = self.apps[int(target.get("app", 0)) % len(self.apps)]
        agents = self.ctl.agents_for(app)
        if not agents:
            return None
        return agents[int(target.get("agent_slot", 0)) % len(agents)]

    def _fire(self, action, rel: float) -> None:
        kind = action.kind
        params = dict(action.params)
        if kind == "mid_window_fault":
            kind = MID_WINDOW_FAULTS[int(params.pop("sub", 0))]
        duration = float(params.get("duration_s", 0.0))
        detail = "skipped (target gone)"
        if kind == "agent_death":
            agent = self._agent_for(action.target)
            if agent is not None:
                self.fault.kill_agent(agent.agent_id)
                detail = agent.agent_id
        elif kind == "multi_agent_death":
            # kill `count` agents of one app in the same tick — spanning
            # distinct nodes first, so several failure domains lose their
            # fragment of the same erasure stripe *simultaneously*
            app = self.apps[int(action.target.get("app", 0)) % len(self.apps)]
            agents = self.ctl.agents_for(app)
            count = max(2, int(params.get("count", 2)))
            victims, seen_nodes = [], set()
            for a in agents:                       # one per node first
                if a.node_id not in seen_nodes:
                    victims.append(a)
                    seen_nodes.add(a.node_id)
            for a in agents:                       # then fill up
                if a not in victims:
                    victims.append(a)
            victims = victims[:count]
            for a in victims:
                self.fault.kill_agent(a.agent_id)
            if victims:
                detail = ",".join(a.agent_id for a in victims)
        elif kind == "node_loss":
            node_id = self.node_ids[int(action.target.get("node", 0))
                                    % len(self.node_ids)]
            if not self.fault.node_dead(node_id):
                self.fault.kill_node(node_id)
                detail = node_id
        elif kind in ("nic_degrade", "nic_down"):
            node_id = self.node_ids[int(action.target.get("node", 0))
                                    % len(self.node_ids)]
            mgr = self._mgr(node_id)
            if mgr is not None and not self.fault.node_dead(node_id):
                nic = mgr.nic
                if kind == "nic_degrade":
                    nic.set_slowdown(float(params.get("slowdown", 8.0)))
                    undo = lambda: nic.set_slowdown(1.0)  # noqa: E731
                else:
                    nic.set_down(True)
                    # a node that died while its NIC was down stays severed
                    undo = lambda: (not self.fault.node_dead(node_id)  # noqa: E731
                                    and nic.set_down(False))
                self._push_clear(rel + duration, kind, undo)
                detail = node_id
        elif kind == "straggler":
            agent = self._agent_for(action.target)
            if agent is not None:
                aid = agent.agent_id
                self.fault.make_straggler(
                    aid, float(params.get("slowdown", 4.0)))
                self._push_clear(rel + duration, kind,
                                 lambda: self.fault.clear_straggler(aid))
                detail = aid
        elif kind == "partition":
            a = self.node_ids[int(action.target.get("node", 0))
                              % len(self.node_ids)]
            b = self.node_ids[int(action.target.get("peer", 1))
                              % len(self.node_ids)]
            if a != b:
                self.fault.partition_nodes(a, b)
                self._push_clear(rel + duration, kind,
                                 lambda: self.fault.heal_partition(a, b))
                detail = f"{a}|{b}"
        elif kind == "l3_outage":
            l3 = self.cluster.l3
            if l3 is not None:
                l3.set_outage(True)
                self._push_clear(rel + duration, kind,
                                 lambda: l3.set_outage(False))
                detail = "l3"
        elif kind == "controller_crash":
            mode = CRASH_MODES[int(params.pop("mode", 0)) % len(CRASH_MODES)]
            if self.crash_hook is None:
                detail = "skipped (no crash hook)"
            else:
                ready = self.crash_ready is None or self.crash_ready(mode)
                deadline = action.params.get("_deadline")
                if not ready and (deadline is None or rel < deadline):
                    # condition ("drain" inflight / "window" open) not met
                    # yet: requeue a little later, bounded by a sim grace
                    # after which the crash fires plain anyway
                    new_params = dict(action.params)
                    new_params.setdefault("_deadline", rel + CRASH_GRACE_S)
                    with self._lock:
                        self._pending.append(dataclasses.replace(
                            action, at_s=rel + 0.03, params=new_params))
                    return
                if not ready:
                    mode = f"{mode}->plain"   # grace expired, fire anyway
                detail = self.crash_hook(mode.split("->")[0])
                detail = f"{mode}:{detail}"
        self.fired.append(f"{kind}@{rel:.3f}:{detail}")
        self.ctl.bus.publish(E.CHAOS_INJECTED, kind=kind, at_s=rel,
                             detail=detail)

    def _push_clear(self, at_rel: float, desc: str, fn) -> None:
        with self._lock:
            self._clears.append((at_rel, desc, fn))


class _Oracle:
    """Per-app restore oracle: committed content, keyed by commit *step*.

    Step, not ckpt id: the step is chosen by the driver before the commit
    is attempted, so it stays meaningful even when the attempt's ack is
    severed mid-flight (a controller crash can land the agent writes and
    journal barrier, then kill the client's blocking wait — recovery
    legitimately serves that checkpoint, and the oracle must be able to
    judge its content).  Ckpt ids, by contrast, drift from steps the first
    time an attempt dies before the catalog allocates one."""

    def __init__(self, app: str, lossless: bool):
        self.app = app
        self.lossless = lossless
        self._by_step: Dict[int, Dict[str, Dict[int, np.ndarray]]] = {}

    def record(self, step: int,
               parts_by_region: Dict[str, Dict[int, np.ndarray]]) -> None:
        snap: Dict[str, Dict[int, np.ndarray]] = {}
        for region, parts in parts_by_region.items():
            snap[region] = {
                p: (np.copy(x) if self.lossless else _q8_roundtrip(x))
                for p, x in parts.items()}
        self._by_step[int(step)] = snap

    def verify(self, restored, out: List[dict]) -> None:
        """Append one restore-comparison record (consumed by the
        ``restore_bit_identity`` invariant)."""
        if restored is None:
            out.append({
                "app": self.app,
                "ckpt": -1,
                "ok": True,
                "detail": "nothing restartable (skipped)",
                "skipped": True,
            })
            return
        meta, parts_by_region, level = restored
        ckpt = int(meta.ckpt_id)
        want = self._by_step.get(int(meta.step))
        if want is None:
            out.append({
                "app": self.app,
                "ckpt": ckpt,
                "ok": False,
                "detail": f"restored ckpt {ckpt} (step {meta.step}) was "
                          f"never attempted by the harness",
            })
            return
        for region, parts in want.items():
            got_parts = parts_by_region.get(region, {})
            for p, ref in parts.items():
                got = got_parts.get(p)
                if got is None or got.shape != ref.shape or \
                        not np.array_equal(np.asarray(got), ref):
                    out.append({
                        "app": self.app,
                        "ckpt": ckpt,
                        "ok": False,
                        "detail": f"{region}[{p}] mismatch vs oracle "
                                  f"(level={level})",
                    })
                    return
        out.append({
            "app": self.app,
            "ckpt": ckpt,
            "ok": True,
            "detail": f"bit-identical (level={level})",
        })


class _BetaDriver:
    """Main-loop-stepped q8-delta app with churn and the overlap resize."""

    def __init__(self, cluster: ICheckCluster, client: ICheckClient,
                 schedule: ChaosSchedule, seed: int, horizon_s: float,
                 oracle: _Oracle, ev_sink: dict, self_test: bool,
                 crash_self_test: bool = False):
        self.cluster = cluster
        self.client = client
        self.schedule = schedule
        self.horizon_s = horizon_s
        self.oracle = oracle
        self.sink = ev_sink
        self.self_test = self_test
        self.crash_self_test = crash_self_test
        self._self_test_done = False
        self.rng = np.random.default_rng(seed + 7919)
        self.x = self.rng.normal(size=6144).astype(np.float32)
        self.num_parts = client.ranks
        self.parts = block_parts(self.x, self.num_parts)
        self.step = 0
        self.work_done = 0.0
        self.last_commit_t: Optional[float] = None
        self.interval_s = 0.30
        self.slice_s = 0.02
        self.handle = None          # open ResizeCutoverHandle
        self.resize_done = schedule.resize_at_s is None
        self.done = False

    # ----------------------------------------------------------- stepping
    def tick(self, now: float, t0: float) -> None:
        if self.done:
            return
        rel = now - t0
        clock = self.cluster.clock
        self._maybe_resize(rel)
        if self.last_commit_t is None or \
                now - self.last_commit_t >= self.interval_s:
            self._commit()
            self.last_commit_t = clock.now()
            return
        dt = min(self.slice_s, self.horizon_s - self.work_done)
        clock.sleep(dt)
        self.work_done += dt
        if self.work_done >= self.horizon_s and self.resize_done \
                and self.handle is None:
            self.done = True

    def _churn(self) -> None:
        # sparse churn: mutate ~1/16 of the field so q8 deltas stay sparse
        # but never empty
        idx = self.rng.integers(0, self.x.size, size=self.x.size // 16)
        self.x[idx] += self.rng.normal(scale=0.1,
                                       size=idx.size).astype(np.float32)
        self.parts = block_parts(self.x, self.num_parts)

    def _commit(self) -> None:
        self._churn()
        drain = self.step % 2 == 0   # exercise L2 drains + L3 trickle
        # record before the attempt: if a fault severs the *ack* after the
        # agent writes land, the checkpoint is still durable and the
        # oracle must be able to judge a restore of it by content
        self.oracle.record(self.step, {"field": self.parts})
        try:
            self.client.commit(self.step, {"field": self.parts},
                               blocking=True, drain=drain)
            self.sink["commit_counts"]["beta"] += 1
        except TOLERATED_ERRORS as exc:
            self.sink["notes"].append(
                f"beta commit {self.step} failed under fault: "
                f"{type(exc).__name__}")
        self.step += 1
        if self.self_test and not self._self_test_done and \
                self.sink["commit_counts"]["beta"] >= 2:
            self._suppress_chain_reset()
        if self.crash_self_test and not self._self_test_done and \
                self.sink["commit_counts"]["beta"] >= 2:
            self._suppress_journal()

    def _suppress_chain_reset(self) -> None:
        """Self-test fault: detach the catalog's mandatory chain-reset
        subscriber, then fire a rank failure while a delta chain is live —
        the ``delta_chain_reset_policy`` check must go CRIT."""
        self._self_test_done = True
        ctl = self.cluster.controller
        ctl.catalog._unsub_chain()
        ctl.bus.publish(E.APP_RANK_FAILED, app=self.client.app_id, rank=0)
        self.sink["notes"].append("self-test: chain-reset subscriber "
                                  "suppressed + rank failure injected")

    def _suppress_journal(self) -> None:
        """Crash self-test fault: silently stop journaling, keep committing
        and draining, then let the scheduled controller crash fire — the
        recovery must come up knowing less than the PFS holds, and the
        ``recovery_fidelity`` check must go CRIT."""
        self._self_test_done = True
        j = self.cluster.controller.journal
        if j is not None:
            j.enabled = False
        self.sink["notes"].append("self-test: journal writes suppressed "
                                  "ahead of the controller crash")

    # ------------------------------------------------------------- resize
    def _maybe_resize(self, rel: float) -> None:
        sc = self.schedule
        if self.resize_done and self.handle is None:
            return
        if self.handle is None and rel >= sc.resize_at_s:
            try:
                self.handle = self.client.redistribute(
                    "field", sc.resize_new_parts, via="peer", overlap=True)
            except TOLERATED_ERRORS as exc:
                self.sink["notes"].append(
                    f"overlap open failed: {type(exc).__name__}")
                self.resize_done = True
            return
        if self.handle is not None and \
                rel >= sc.resize_at_s + sc.resize_window_s:
            self._cutover()

    def _cutover(self) -> None:
        handle, self.handle = self.handle, None
        self.resize_done = True
        if not handle.wait(timeout=CUTOVER_WAIT_S):
            self.sink["stalls"].append(
                f"cutover handle not ready within {CUTOVER_WAIT_S:.0f}s "
                f"wall (wedged overlap window)")
            handle.cancel()
            return
        try:
            new_parts = handle.cutover()
        except TOLERATED_ERRORS as exc:
            self.sink["notes"].append(
                f"cutover degraded: {type(exc).__name__}")
            handle.cancel()
            return
        self.num_parts = self.schedule.resize_new_parts
        self.client.commit_redistribution("field", self.num_parts)
        self.x = np.concatenate(
            [np.asarray(new_parts[p]).reshape(-1)
             for p in sorted(new_parts)]).astype(np.float32)
        self.parts = dict(new_parts)
        self.sink["resizes"] += 1

    def abort(self) -> None:
        if self.handle is not None:
            self.handle.cancel()
            self.handle = None


def run_campaign(seed: int, schedule: Optional[ChaosSchedule] = None,
                 self_test: bool = False, controller_crash: bool = False,
                 crash_self_test: bool = False) -> dict:
    """Run one campaign; returns the deterministic JSON-able report.

    ``controller_crash=True`` draws one controller crash into the seed's
    schedule (crash -> journal replay -> reconciliation -> epoch fencing,
    judged by the ``recovery_fidelity`` invariant).  ``crash_self_test``
    suppresses journal writes mid-campaign and schedules a crash — the
    fidelity check must then go CRIT (a green run is a runner failure).
    """
    if schedule is None:
        if crash_self_test:
            # a quiet campaign plus one late plain crash: the only signal
            # competing for the verdict is the suppressed journal itself.
            # _deadline far past the horizon disables the plain-mode
            # fallback — the crash defers until the violation is armed
            # (suppression fired + one unjournaled commit landed)
            schedule = ChaosSchedule(
                seed=seed, horizon_s=2.4, actions=(
                    ChaosAction(at_s=1.7, kind="controller_crash",
                                params={"mode": 0.0, "_deadline": 1e9}),))
        elif self_test:
            # the deliberate violation needs a quiet campaign: no scheduled
            # faults competing with the suppressed reset for the verdict
            schedule = ChaosSchedule(seed=seed, horizon_s=2.4, actions=())
        else:
            schedule = generate_schedule(seed,
                                         controller_crash=controller_crash)
    horizon = schedule.horizon_s
    apps = ("alpha", "beta")
    # trace=True: spans only read the sim clock, so tracing is free of
    # side effects on determinism — and a red seed's flight dump then
    # carries the span tree of the failure window, not just raw events
    cluster = ICheckCluster(n_icheck_nodes=3, n_spare_nodes=2,
                            adaptive_interval=False, l3=True,
                            keep_l1=3, keep_l2=2, keep_l3=4,
                            delta_keyframe_every=4, trace=True)
    sink = {
        "commit_counts": {"alpha": 0, "beta": 0},
        "notes": [],
        "stalls": [],
        "resizes": 0,
    }
    restore_checks: List[dict] = []
    driver_errors: List[str] = []
    recovery_reports: List[dict] = []
    obs: Dict[str, List[Tuple[int, Optional[int]]]] = {a: [] for a in apps}
    try:
        ctl = cluster.controller
        rng_a = np.random.default_rng(seed + 101)
        arr_a = rng_a.normal(size=4096).astype(np.float32)
        # alpha runs erasure-coded L1 durability (k=4, m=1): every commit
        # scatters 4 data + 1 parity fragments across failure domains, so
        # the multi_agent_death action class and the node losses exercise
        # the peer-rebuild path instead of whole-shard re-replication
        alpha = ICheckClient("alpha", ctl, ranks=4, codec="raw",
                             durability="ec", ec_k=4, ec_m=1).init(
                                 ckpt_bytes_estimate=arr_a.nbytes)
        alpha.add_adapt("state", arr_a.shape, "float32")
        alpha_parts = block_parts(arr_a, 4)
        beta = ICheckClient("beta", ctl, ranks=6, codec="q8-delta",
                            keyframe_every=4).init(ckpt_bytes_estimate=0)
        beta.add_adapt("field", (6144,), "float32")

        oracle_a = _Oracle("alpha", lossless=True)
        oracle_b = _Oracle("beta", lossless=False)
        t0 = cluster.clock.now()
        injector = ChaosInjector(cluster, schedule, apps, t0)
        beta_drv = _BetaDriver(cluster, beta, schedule, seed, horizon,
                               oracle_b, sink, self_test,
                               crash_self_test=crash_self_test)

        def crash_ready(mode: str) -> bool:
            if crash_self_test:
                # the self-test crash defers until the violation is armed:
                # journal suppressed *and* one unjournaled commit has been
                # acknowledged (else a slow start could crash before the
                # journal and catalog ever diverge, and the run reads green)
                return (beta_drv._self_test_done
                        and sink["commit_counts"]["beta"] >= 3)
            if mode == "drain":
                return ctl.drains.stats()["active"] > 0
            if mode == "window":
                return beta_drv.handle is not None
            return True

        def do_controller_crash(mode: str) -> str:
            """The tentpole's end-to-end sequence, fired mid-chaos: capture
            ground truth, hard-crash the control plane, warm-recover from
            the journal, then prove fencing and restorability."""
            j = ctl.journal
            truth_before = dict(j.truth()) if j is not None else {}
            pfs_before = {
                app: max(cluster.pfs.list_checkpoints(app), default=-1)
                for app in apps}
            # the journal-before-state barrier means every checkpoint id the
            # live catalog has issued was journaled *first* — so recovery's
            # max_known must cover the pre-crash catalog, deterministically,
            # no matter where the crash lands relative to drain timing
            known_before = {}
            with ctl._lock:
                for app in apps:
                    try:
                        ids = list(ctl.app(app).checkpoints)
                    except TOLERATED_ERRORS:
                        ids = []
                    known_before[app] = max(ids, default=-1)
            old_epoch = ctl.fence.current
            ctl.crash()
            report = ctl.recover()
            # stale-epoch probe: an op stamped with the pre-crash epoch
            # must be refused by the fence, not silently applied
            probe = "skipped"
            for agent in ctl.agents_for("alpha") + ctl.agents_for("beta"):
                try:
                    fut = agent.put(
                        ShardKey("alpha", 999_999, "_staleprobe", 0),
                        b"\x00" * 8, epoch=old_epoch)
                    fut.result(timeout=STALE_PROBE_WAIT_S)
                    probe = "accepted"      # fence failed — CRIT downstream
                    break
                except StaleEpochError:
                    probe = "rejected"
                    break
                except TOLERATED_ERRORS:
                    continue                # dead/stopped agent: try another
            # post-recovery restores, judged against the same numpy
            # oracles; a tolerated fault-window exception is *skipped*
            # here, not failed — other scheduled faults are still live at
            # this point, and the post-quiesce final sweep is the
            # authoritative judge of restorability
            post: List[dict] = []
            for client, oracle in ((alpha, oracle_a), (beta, oracle_b)):
                try:
                    oracle.verify(client.restart(), post)
                except TOLERATED_ERRORS as exc:
                    post.append({"app": client.app_id, "ckpt": -1,
                                 "ok": True, "skipped": True,
                                 "detail": f"post-recovery restore raised "
                                           f"{type(exc).__name__} under "
                                           f"live faults (skipped)"})
            restore_checks.extend(post)
            post_latest: Dict[str, Optional[int]] = {}
            for app in apps:
                try:
                    got = ctl.latest_restartable(app)
                except TOLERATED_ERRORS:
                    got = None
                post_latest[app] = None if got is None \
                    else int(got[0].ckpt_id)
            # the live workloads keep committing *during* the recovery
            # sequence, so the "never newer than journaled truth" bound is
            # the journal as of after the post_latest measurement — truth
            # only grows, and anything restartable at measurement time was
            # journaled (barrier write) before it committed
            truth_after = dict(j.truth()) if j is not None else {}
            recovery_reports.append({
                "mode": mode,
                "epoch": int(report["epoch"]),
                "truth_before": truth_before,
                "truth_after": truth_after,
                "pfs_before": pfs_before,
                "known_before": known_before,
                "max_known": {
                    a: int(report["apps"].get(a, {}).get("max_known", -1))
                    for a in apps},
                "post_latest": post_latest,
                "stale_probe": probe,
                "post_restores": post,
                "chains_reset": int(report["chains_reset"]),
                "downgraded": len(report["downgraded"]),
                "drains_resubmitted": int(report["drains_resubmitted"]),
            })
            return f"epoch={report['epoch']} probe={probe}"

        injector.crash_ready = crash_ready
        injector.crash_hook = do_controller_crash

        # alpha's rank-failure times: seeded, inside the active window
        frng = np.random.default_rng(seed + 0xA1FA)
        fail_times = [t0 + float(x) for x in
                      np.sort(frng.uniform(0.25, 0.95,
                                           size=int(frng.integers(1, 3))))
                      * horizon]

        def observe() -> None:
            for app in apps:
                got = ctl.latest_restartable(app)
                obs[app].append((len(ctl.events),
                                 None if got is None
                                 else int(got[0].ckpt_id)))

        def on_tick(now: float) -> None:
            injector.poll(now)

        def on_restart(restored) -> None:
            oracle_a.verify(restored, restore_checks)

        def alpha_main() -> None:
            oracle_a.record(0, {"state": alpha_parts})
            try:
                stats = run_ckpt_workload(
                    cluster, alpha, {"state": alpha_parts},
                    total_work_s=horizon, failure_times=fail_times,
                    interval_fn=lambda: 0.25, work_slice_s=0.02,
                    keep_l1=3, on_tick=on_tick, on_restart=on_restart)
                sink["commit_counts"]["alpha"] = int(stats["commits"])
            except TOLERATED_ERRORS as exc:
                sink["notes"].append(
                    f"alpha workload aborted under fault: "
                    f"{type(exc).__name__}")
            except Exception as exc:  # noqa: BLE001 - judged by no_stall
                driver_errors.append(
                    f"alpha: {exc!r}\n{traceback.format_exc()}")

        # alpha's oracle can't see individual commit steps (the workload
        # owns its commit loop) — but alpha never mutates its parts, so
        # every checkpoint has identical content and one record per step
        # suffices; pre-register a generous step range
        for ck in range(200):
            oracle_a.record(ck, {"state": alpha_parts})
        alpha_thread = threading.Thread(target=alpha_main, daemon=True,
                                        name="chaos-alpha")
        wall_start = time.monotonic()
        alpha_thread.start()

        pumped = 0.0
        while not (beta_drv.done and not alpha_thread.is_alive()):
            now = cluster.clock.now()
            injector.poll(now)
            beta_drv.tick(now, t0)
            observe()
            if beta_drv.done and alpha_thread.is_alive():
                # keep sim time flowing so pending fault *clears* fire while
                # alpha drains its tail — but bounded, or a wall-blocked
                # alpha would let this idle loop inflate sim time past the
                # no-stall bound
                if pumped < 2.0 * horizon:
                    cluster.clock.sleep(0.02)
                    pumped += 0.02
                time.sleep(0.002)
            if time.monotonic() - wall_start > WALL_BUDGET_S:
                sink["stalls"].append(
                    f"campaign exceeded {WALL_BUDGET_S:.0f}s wall budget")
                break
        alpha_thread.join(timeout=ALPHA_JOIN_S)
        if alpha_thread.is_alive():
            sink["stalls"].append(
                f"alpha workload thread still running after "
                f"{ALPHA_JOIN_S:.0f}s wall join")
        if crash_self_test and not recovery_reports:
            # the deferred self-test crash never found its arming window
            # inside the loop (e.g. a wall-budget bailout): fire it now —
            # by end of campaign the suppressed journal has provably
            # diverged from the catalog, so the verdict stays meaningful
            sink["notes"].append("self-test crash fired post-loop "
                                 "(in-loop deferral never armed)")
            do_controller_crash("plain")
        beta_drv.abort()

        # settle: clear transients, let the health loop finish processing
        injector.quiesce()
        time.sleep(0.4)
        cluster.clock.sleep(0.2)

        # final restore sweep: both apps, faults cleared
        for client, oracle in ((alpha, oracle_a), (beta, oracle_b)):
            try:
                oracle.verify(client.restart(), restore_checks)
            except TOLERATED_ERRORS as exc:
                restore_checks.append(
                    {"app": client.app_id, "ckpt": -1, "ok": False,
                     "detail": f"final restore raised "
                               f"{type(exc).__name__} after quiesce"})
        observe()

        snapshot = cluster.telemetry.snapshot()
        evidence = CampaignEvidence(
            cluster=cluster, apps=apps, records=list(ctl.events),
            telemetry_snapshot=snapshot, restore_checks=restore_checks,
            restartable_obs=obs,
            commit_counts=dict(sink["commit_counts"]),
            stalls=list(sink["stalls"]), driver_errors=driver_errors,
            notes=list(sink["notes"]), resizes=int(sink["resizes"]),
            final_sim_t=cluster.clock.now() - t0,
            sim_bound_s=SIM_BOUND_FACTOR * horizon + 10.0,
            recovery_reports=list(recovery_reports))
        results = run_checks(evidence)
        # any non-OK verdict dumps the flight recorder while the cluster is
        # still alive: the last N events + spans around the failure, keyed
        # by seed so one red seed produces exactly one dump
        flight_dump = None
        failing = [r.as_dict() for r in results if int(r.status) >= 1]
        if failing:
            suffix = "_selftest" if (self_test or crash_self_test) else ""
            flight_dump = ctl.flight.dump(
                f"chaos_seed_{seed}{suffix}",
                extra={"seed": int(seed), "failing_checks": failing})
        for client in (alpha, beta):
            try:
                client.finalize()
            except TOLERATED_ERRORS:
                pass
    finally:
        cluster.close()

    worst = max((r.status for r in results), default=0)
    return {
        "seed": int(seed),
        "self_test": bool(self_test or crash_self_test),
        "ok": int(worst) < 2,
        "worst": ["OK", "WARN", "CRIT"][int(worst)],
        "schedule": schedule.as_dict(),
        "checks": [r.as_dict() for r in results],
        "recovery_reports": recovery_reports,
        "flight_dump": flight_dump,
    }
