"""Seeded chaos schedules.

A :class:`ChaosSchedule` is a fully materialized list of
:class:`ChaosAction`s — *what* breaks, *when* (sim-time offset from
campaign start) and for *how long* — generated from a single integer seed
via ``np.random.default_rng`` so the same seed always produces the same
schedule, bit for bit.  Targets are symbolic (node/agent *indices*, not
ids) and resolved against the live cluster at fire time, which keeps a
schedule replayable against any campaign topology of the same shape.

Schedules serialize to JSON (``--schedule-json``) so a red CI seed can be
replayed locally byte-identically even across generator changes.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

# every fault kind the injector knows how to fire.  "mid_window_fault" is a
# second-order kind: its at_s is pinned inside the overlap-resize window
# and its params carry the concrete fault to fire there.
# "controller_crash" is never drawn in the primary loop — it only appears
# when a campaign opts in (``generate_schedule(controller_crash=True)``).
KINDS = (
    "agent_death",
    "multi_agent_death",
    "node_loss",
    "nic_degrade",
    "nic_down",
    "straggler",
    "partition",
    "l3_outage",
    "mid_window_fault",
    "controller_crash",
)

# the primary draw pool — identical to the pre-controller_crash KINDS[:-1]
# slice so every historical seed still materializes bit-identically
_PRIMARY_KINDS = KINDS[:8]

# what a mid-window fault can concretely be
MID_WINDOW_FAULTS = ("agent_death", "node_loss", "nic_down")

# how a controller crash is timed relative to control-plane activity:
# "plain" fires at its offset; "drain" waits for an active L1->L2 drain;
# "window" waits for an open overlap-resize window (both with a bounded
# grace, falling back to plain when the condition never arrives)
CRASH_MODES = ("plain", "drain", "window")


@dataclasses.dataclass(frozen=True)
class ChaosAction:
    """One scheduled fault: fire ``kind`` at sim offset ``at_s``.

    ``target`` holds symbolic indices (``node``, ``app``, ``agent_slot``,
    ``peer``) resolved at fire time; ``params`` carries knobs (slowdown
    factor, recovery duration ``duration_s`` for transient kinds).
    """

    at_s: float
    kind: str
    target: Dict[str, int] = dataclasses.field(default_factory=dict)
    params: Dict[str, float] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "at_s": round(float(self.at_s), 6),
            "kind": self.kind,
            "target": {k: int(v) for k, v in sorted(self.target.items())},
            "params": {k: round(float(v), 6)
                       for k, v in sorted(self.params.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosAction":
        return cls(at_s=float(d["at_s"]), kind=str(d["kind"]),
                   target=dict(d.get("target", {})),
                   params=dict(d.get("params", {})))


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """A seed's full campaign script: faults plus the resize directive."""

    seed: int
    horizon_s: float
    actions: Tuple[ChaosAction, ...]
    # overlap-resize directive for the resizing app (None = no resize this
    # campaign): open the window at resize_at_s, cut over window_s later
    resize_at_s: Optional[float] = None
    resize_window_s: float = 0.0
    resize_new_parts: int = 0

    def as_dict(self) -> dict:
        return {
            "seed": int(self.seed),
            "horizon_s": round(float(self.horizon_s), 6),
            "resize_at_s": None if self.resize_at_s is None
            else round(float(self.resize_at_s), 6),
            "resize_window_s": round(float(self.resize_window_s), 6),
            "resize_new_parts": int(self.resize_new_parts),
            "actions": [a.as_dict() for a in self.actions],
        }

    def to_json(self) -> str:
        return json.dumps(self.as_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosSchedule":
        return cls(
            seed=int(d["seed"]),
            horizon_s=float(d["horizon_s"]),
            actions=tuple(ChaosAction.from_dict(a)
                          for a in d.get("actions", ())),
            resize_at_s=(None if d.get("resize_at_s") is None
                         else float(d["resize_at_s"])),
            resize_window_s=float(d.get("resize_window_s", 0.0)),
            resize_new_parts=int(d.get("resize_new_parts", 0)),
        )

    @classmethod
    def from_json(cls, text: str) -> "ChaosSchedule":
        return cls.from_dict(json.loads(text))


def generate_schedule(seed: int, horizon_s: float = 2.4, n_nodes: int = 3,
                      n_apps: int = 2,
                      controller_crash: bool = False) -> ChaosSchedule:
    """Materialize the seed's schedule.

    Composition rules (so a campaign stays *survivable* — the invariants
    assert correctness under faults, not behavior with every node dead):

      * 1–4 primary actions at offsets inside [0.15, 0.75] x horizon;
      * at most one ``node_loss`` and one ``l3_outage`` per campaign
        (counting the mid-window fault's concrete kind);
      * transient kinds (NIC degrade/down, straggler, partition, outage)
        carry a bounded ``duration_s`` and are cleared by the injector;
      * roughly half of the seeds get an overlap resize; when one is
        scheduled, one extra fault may be pinned *inside* the window
        (the mid-overlap-window failure shape);
      * ``controller_crash=True`` adds exactly one controller crash in
        [0.5, 0.75] x horizon with a seeded :data:`CRASH_MODES` timing
        mode.  The crash draws happen *after* every other draw, so a
        seed's fault schedule is bit-identical with the flag on or off.
    """
    rng = np.random.default_rng(seed)
    actions: List[ChaosAction] = []
    used_node_loss = False
    used_l3 = False

    # resize directive first so a mid-window fault can anchor to it
    resize_at: Optional[float] = None
    window_s = 0.0
    new_parts = 0
    if rng.random() < 0.55:
        resize_at = float(rng.uniform(0.30, 0.50)) * horizon_s
        window_s = float(rng.uniform(0.25, 0.45)) * horizon_s
        new_parts = int(rng.choice((4, 8, 9)))

    n_actions = int(rng.integers(1, 5))
    for _ in range(n_actions):
        kind = str(rng.choice(_PRIMARY_KINDS))  # special kinds drawn below
        at = float(rng.uniform(0.15, 0.75)) * horizon_s
        if kind == "node_loss":
            if used_node_loss:
                kind = "nic_degrade"
            else:
                used_node_loss = True
        if kind == "l3_outage":
            if used_l3:
                kind = "straggler"
            else:
                used_l3 = True
        target: Dict[str, int] = {}
        params: Dict[str, float] = {}
        node = int(rng.integers(0, n_nodes))
        if kind == "agent_death":
            target = {"app": int(rng.integers(0, n_apps)),
                      "agent_slot": int(rng.integers(0, 4))}
        elif kind == "multi_agent_death":
            # m *simultaneous* agent deaths — the erasure-coded app's
            # survival envelope (spanning nodes, so fragments of one
            # stripe vanish from several failure domains at once)
            target = {"app": int(rng.integers(0, n_apps)),
                      "agent_slot": int(rng.integers(0, 4))}
            params = {"count": float(rng.integers(2, 4))}
        elif kind == "node_loss":
            target = {"node": node}
        elif kind == "nic_degrade":
            target = {"node": node}
            params = {"slowdown": float(rng.uniform(4.0, 16.0)),
                      "duration_s": float(rng.uniform(0.2, 0.5))}
        elif kind == "nic_down":
            target = {"node": node}
            params = {"duration_s": float(rng.uniform(0.1, 0.35))}
        elif kind == "straggler":
            target = {"app": int(rng.integers(0, n_apps)),
                      "agent_slot": int(rng.integers(0, 4))}
            params = {"slowdown": float(rng.uniform(3.0, 10.0)),
                      "duration_s": float(rng.uniform(0.2, 0.6))}
        elif kind == "partition":
            peer = int(rng.integers(0, n_nodes))
            if peer == node:
                peer = (node + 1) % n_nodes
            target = {"node": node, "peer": peer}
            params = {"duration_s": float(rng.uniform(0.15, 0.45))}
        elif kind == "l3_outage":
            params = {"duration_s": float(rng.uniform(0.3, 0.8))}
        actions.append(ChaosAction(at_s=at, kind=kind, target=target,
                                   params=params))

    if resize_at is not None and rng.random() < 0.6:
        sub = str(rng.choice(MID_WINDOW_FAULTS))
        if sub == "node_loss" and used_node_loss:
            sub = "nic_down"
        at = resize_at + float(rng.uniform(0.15, 0.85)) * window_s
        target = {"node": int(rng.integers(0, n_nodes))}
        params: Dict[str, float] = {}
        if sub == "agent_death":
            target = {"app": 1, "agent_slot": int(rng.integers(0, 4))}
        elif sub == "nic_down":
            params = {"duration_s": float(rng.uniform(0.1, 0.3))}
        actions.append(ChaosAction(
            at_s=at, kind="mid_window_fault", target=target,
            params={"sub": float(MID_WINDOW_FAULTS.index(sub)), **params}))

    if controller_crash:
        # drawn last so enabling the crash never perturbs the fault draws
        at = float(rng.uniform(0.50, 0.75)) * horizon_s
        mode = int(rng.integers(0, len(CRASH_MODES)))
        actions.append(ChaosAction(at_s=at, kind="controller_crash",
                                   params={"mode": float(mode)}))

    actions.sort(key=lambda a: (a.at_s, a.kind))
    return ChaosSchedule(seed=seed, horizon_s=horizon_s,
                         actions=tuple(actions), resize_at_s=resize_at,
                         resize_window_s=window_s,
                         resize_new_parts=new_parts)
