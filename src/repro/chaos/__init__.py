"""Deterministic chaos campaigns for the iCheck service core.

A campaign is: one seeded :class:`~repro.chaos.schedule.ChaosSchedule`
(agent death, node loss, NIC degradation/down, stragglers, partial
partitions, mid-overlap-window failures, L3 outage) injected at sim-time
offsets into a fixed multi-app workload, then judged by the invariant
registry (``repro.chaos.invariants``) — checks-as-code, each returning
OK/WARN/CRIT.

Run the matrix::

    python -m repro.chaos.run --seeds 0..99

Reproduce a red seed exactly::

    python -m repro.chaos.run --seed 17 --schedule-json <dumped schedule>
"""
from __future__ import annotations

from .campaign import run_campaign
from .invariants import CheckResult, Status, invariant, run_checks
from .schedule import ChaosAction, ChaosSchedule, generate_schedule

__all__ = [
    "ChaosAction",
    "ChaosSchedule",
    "CheckResult",
    "Status",
    "generate_schedule",
    "invariant",
    "run_campaign",
    "run_checks",
]
