"""Chaos campaign CLI.

Matrix over a seed range (the CI gate)::

    python -m repro.chaos.run --seeds 0..99 --report artifacts/chaos.json

Reproduce one red seed bit-exactly (the line the runner prints on CRIT)::

    python -m repro.chaos.run --seed 17 --schedule-json \
        artifacts/chaos/schedule_17.json

Controller-crash shard (every seed additionally crashes and warm-recovers
the control plane mid-chaos; ``recovery_fidelity`` judges the rebuild)::

    python -m repro.chaos.run --seeds 100..124 --controller-crash

Self-test (deliberate violation: the mandatory delta-chain reset is
suppressed mid-campaign; the matching invariant must go CRIT)::

    python -m repro.chaos.run --self-test --seed 0

Crash self-test (journal writes silently suppressed before a scheduled
controller crash; ``recovery_fidelity`` must go CRIT)::

    python -m repro.chaos.run --self-test --controller-crash --seed 0

Exit status: 0 when no campaign has a CRIT check (WARNs print but pass),
1 otherwise.  When ``$GITHUB_STEP_SUMMARY`` is set, red seeds append their
reproduction command there too.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Tuple

from .campaign import run_campaign
from .schedule import ChaosSchedule

ART_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "..", "..", "artifacts", "chaos")


def _parse_seeds(spec: str) -> List[int]:
    if ".." in spec:
        lo, hi = spec.split("..", 1)
        return list(range(int(lo), int(hi) + 1))
    return [int(spec)]


def _dump_schedule(report: dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.abspath(
        os.path.join(ART_DIR, f"schedule_{report['seed']}.json"))
    with open(path, "w") as f:
        f.write(ChaosSchedule.from_dict(report["schedule"]).to_json())
    return path


def _repro_line(seed: int, schedule_path: str) -> str:
    return (f"REPRODUCE: PYTHONPATH=src python -m repro.chaos.run "
            f"--seed {seed} --schedule-json {schedule_path}")


def _run_one(seed: int, schedule: Optional[ChaosSchedule],
             self_test: bool,
             controller_crash: bool = False) -> Tuple[dict, List[str]]:
    """One campaign -> (report, printed lines)."""
    lines: List[str] = []
    crash_self = self_test and controller_crash
    try:
        report = run_campaign(seed, schedule=schedule,
                              self_test=self_test and not controller_crash,
                              controller_crash=controller_crash,
                              crash_self_test=crash_self)
    except Exception as exc:  # noqa: BLE001 - a crash is a red campaign
        report = {
            "seed": int(seed),
            "self_test": bool(self_test),
            "ok": False,
            "worst": "CRIT",
            "schedule": None,
            "checks": [{
                "name": "campaign_completed",
                "status": "CRIT",
                "detail": f"campaign raised: {exc!r}",
            }],
        }
    status = report["worst"]
    lines.append(f"seed {report['seed']:>4}  {status}")
    for check in report["checks"]:
        if check["status"] != "OK":
            lines.append(f"    {check['status']:<4} {check['name']}: "
                         f"{check['detail']}")
    if status == "CRIT" and report.get("schedule") is not None:
        path = _dump_schedule(report)
        lines.append("    " + _repro_line(report["seed"], path))
    if report.get("flight_dump"):
        lines.append(f"    FLIGHT-RECORDER: {report['flight_dump']}")
    return report, lines


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.chaos.run",
        description="deterministic chaos campaigns over the iCheck core")
    ap.add_argument("--seeds", help="inclusive range A..B (or one seed)")
    ap.add_argument("--seed", type=int, help="single seed")
    ap.add_argument("--schedule-json",
                    help="replay this exact schedule (ignores the "
                         "generator; requires --seed)")
    ap.add_argument("--report", help="write the JSON report here")
    ap.add_argument("--self-test", action="store_true",
                    help="deliberately violate the chain-reset invariant "
                         "(or, with --controller-crash, suppress journal "
                         "writes before a crash) and assert the matching "
                         "check goes CRIT")
    ap.add_argument("--controller-crash", action="store_true",
                    help="additionally crash + warm-recover the controller "
                         "mid-campaign on every seed (recovery_fidelity "
                         "judges the rebuild)")
    args = ap.parse_args(argv)

    if args.seed is not None:
        seeds = [args.seed]
    elif args.seeds:
        seeds = _parse_seeds(args.seeds)
    else:
        seeds = [0]
    schedule = None
    if args.schedule_json:
        with open(args.schedule_json) as f:
            schedule = ChaosSchedule.from_json(f.read())

    reports: List[dict] = []
    red: List[dict] = []
    for seed in seeds:
        report, lines = _run_one(seed, schedule, args.self_test,
                                 args.controller_crash)
        reports.append(report)
        print("\n".join(lines), flush=True)
        if report["worst"] == "CRIT":
            red.append(report)

    summary = {
        "campaigns": len(reports),
        "crit": len(red),
        "warn": sum(1 for r in reports if r["worst"] == "WARN"),
        "ok": sum(1 for r in reports if r["worst"] == "OK"),
        "reports": reports,
    }
    if args.report:
        os.makedirs(os.path.dirname(os.path.abspath(args.report)),
                    exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)

    if args.self_test:
        # the deliberate violation must be *caught*: green here is a failure
        want = ("recovery_fidelity" if args.controller_crash
                else "delta_chain_reset_policy")
        caught = any(
            c["name"] == want and c["status"] == "CRIT"
            for r in reports for c in r["checks"])
        if caught:
            print(f"self-test: OK (deliberate violation detected as CRIT "
                  f"by {want})")
            return 0
        print(f"self-test: FAILED — {want} stayed green through a "
              f"deliberate violation")
        return 1

    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if red and step_summary:
        with open(step_summary, "a") as f:
            f.write("## Chaos campaign failures\n\n")
            for r in red:
                f.write(f"- seed **{r['seed']}**: "
                        + ", ".join(c["name"] for c in r["checks"]
                                    if c["status"] == "CRIT") + "\n")
                if r.get("schedule") is not None:
                    rel = os.path.join("artifacts", "chaos",
                                       f"schedule_{r['seed']}.json")
                    f.write(f"  - `{_repro_line(r['seed'], rel)}`\n")
                if r.get("flight_dump"):
                    f.write(f"  - flight recorder: `{r['flight_dump']}`\n")
    print(f"chaos: {summary['ok']} ok / {summary['warn']} warn / "
          f"{summary['crit']} crit over {summary['campaigns']} campaigns")
    return 1 if red else 0


if __name__ == "__main__":
    sys.exit(main())
