from .pipeline import DataState, SyntheticLMData

__all__ = ["SyntheticLMData", "DataState"]
