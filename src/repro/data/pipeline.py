"""Deterministic, shardable, checkpointable synthetic data pipeline.

Batches are a pure function of (seed, step) via a counter-based Philox
generator, so the pipeline state is a *single integer*: it checkpoints as an
iCheck region (``icheck_add_adapt("data_state", ...)``), restarts exactly,
and is embarrassingly redistributable across resizes -- every host can
regenerate its slice of any step's global batch from (seed, step, host_id).

The synthetic "language" has learnable structure (a fixed random Markov
chain over the vocab) so that a training run shows a genuinely decreasing
loss, not noise-fitting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def as_array(self) -> np.ndarray:
        return np.asarray([self.seed, self.step], dtype=np.int64)

    @staticmethod
    def from_array(a) -> "DataState":
        a = np.asarray(a).reshape(-1)
        return DataState(seed=int(a[0]), step=int(a[1]))


class SyntheticLMData:
    """Markov-chain token stream + modality stubs (frames / patches)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 order_vocab: int = 512):
        self.cfg = cfg
        self.shape = shape
        self.state = DataState(seed=seed, step=0)
        self.effective_vocab = min(cfg.vocab_size, order_vocab)
        self._reseed(seed)

    def _reseed(self, seed: int) -> None:
        # fixed transition structure, derived from the seed (not steps)
        root = np.random.Generator(np.random.Philox(key=[seed, 0]))
        self._shift = root.integers(1, self.effective_vocab,
                                    size=(8,), dtype=np.int64)

    # --------------------------------------------------------------- batches
    def _rng(self, step: int, lane: int = 0) -> np.random.Generator:
        # counter-based: one Philox key per (seed, lane), step in the key
        return np.random.Generator(np.random.Philox(
            key=[(self.state.seed << 16) ^ lane, step + 1]))

    def batch_at(self, step: int, batch_size: Optional[int] = None,
                 hosts: int = 1, host_id: int = 0) -> Dict[str, np.ndarray]:
        """The (deterministic) global batch of ``step``; hosts>1 slices it."""
        cfg, shape = self.cfg, self.shape
        b = batch_size or shape.global_batch
        assert b % hosts == 0, (b, hosts)
        lo = (b // hosts) * host_id
        hi = lo + b // hosts
        rng = self._rng(step)
        v = self.effective_vocab
        t = shape.seq_len
        start = rng.integers(0, v, size=(b, 1), dtype=np.int64)
        ks = rng.integers(0, len(self._shift), size=(b, t - 1))
        steps = self._shift[ks]                       # Markov-ish increments
        toks = (start + np.concatenate(
            [np.zeros((b, 1), np.int64), np.cumsum(steps, axis=1)],
            axis=1)) % v
        batch = {"tokens": toks[lo:hi].astype(np.int32),
                 "labels": toks[lo:hi].astype(np.int32)}
        if cfg.frontend == "frames":
            batch["frames"] = rng.standard_normal(
                (b, cfg.num_frames, cfg.d_model))[lo:hi].astype(np.float32)
        if cfg.frontend == "patches":
            batch["patches"] = rng.standard_normal(
                (b, cfg.num_patches, cfg.d_model))[lo:hi].astype(np.float32)
        return batch

    def next_batch(self, batch_size: Optional[int] = None, hosts: int = 1,
                   host_id: int = 0) -> Dict[str, np.ndarray]:
        out = self.batch_at(self.state.step, batch_size, hosts, host_id)
        self.state.step += 1
        return out

    # ------------------------------------------------------------ checkpoint
    def state_array(self) -> np.ndarray:
        return self.state.as_array()

    def restore(self, arr) -> None:
        self.state = DataState.from_array(arr)
        self._reseed(self.state.seed)
