"""The blockwise q8 layout — one definition shared by every codec path.

Three things implement "blockwise int8 with one f32 scale per BLOCK values":
the Pallas kernel (``kernel.py``), the jnp oracle (``ref.py``), and the
host-side wire codec (``repro.core.tiers``).  The layout constants and the
numpy reference live *here*, dependency-free (no jax import), so the host
codec and the device kernels cannot drift: ``tiers.py`` imports this module
directly and the kernel tests assert the Pallas/XLA outputs match it.

All functions operate on *flattened, padded* buffers of shape
(num_blocks, BLOCK), exactly like the kernels.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

try:  # np.dtype("bfloat16") — registered by jax's ml_dtypes dependency
    import ml_dtypes  # noqa: F401
except Exception:  # pragma: no cover - optional
    pass

BLOCK = 256  # values per quantization block (one f32 scale each)


def to_blocks_np(x: np.ndarray) -> Tuple[np.ndarray, int]:
    """Flatten + zero-pad to (nb, BLOCK) float32. Returns (blocks, orig_n)."""
    flat = np.ravel(x).astype(np.float32)
    n = flat.size
    nb = -(-max(n, 1) // BLOCK)
    blocks = np.zeros((nb, BLOCK), np.float32)
    blocks.reshape(-1)[:n] = flat
    return blocks, n


def quantize_np(blocks: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(nb, BLOCK) f32 -> (int8 codes (nb, BLOCK), f32 scales (nb, 1)).

    The numpy mirror of ``ref.quantize_ref`` / the Pallas quantize kernel:
    absmax/127 scale per block (1.0 for all-zero blocks), round-to-nearest,
    clip to [-127, 127].
    """
    blocks = blocks.astype(np.float32, copy=False)
    absmax = np.max(np.abs(blocks), axis=-1, keepdims=True)
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(blocks / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_np(q: np.ndarray, scale: np.ndarray, n: int,
                  dtype) -> np.ndarray:
    """Invert :func:`quantize_np`: codes * scales, trimmed to ``n`` values.

    Float math is f32 (identical bit-for-bit to the device dequantize) and
    only the final cast goes to ``dtype``.
    """
    x = (q.astype(np.float32) * scale).reshape(-1)[:n]
    return x.astype(np.dtype(dtype))
