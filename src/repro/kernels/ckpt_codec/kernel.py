"""Pallas TPU kernel for the checkpoint codec (blockwise int8 quantization +
XOR delta against the previous checkpoint's codes).

Tiling: the flattened checkpoint buffer is shaped (num_blocks, BLOCK=256);
each grid step processes a (ROWS_PER_TILE, 256) tile held in VMEM -- 256
lanes = 2 VREG lanes wide, rows a multiple of 8 sublanes, so the tile is
hardware-aligned.  The whole codec is a single pass over HBM: read x (and
prev codes for the delta variant), write int8 codes + f32 scales.  Arithmetic
intensity is O(1) so the kernel is HBM-bandwidth-bound by design -- the point
is to emit 4x fewer bytes for the agent transfer than a raw f32 snapshot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocks import BLOCK

ROWS_PER_TILE = 64  # (64, 256) f32 tile = 64 KiB in VMEM


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _quantize_delta_kernel(x_ref, prev_ref, d_ref, s_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    d_ref[...] = jnp.bitwise_xor(q, prev_ref[...])
    s_ref[...] = scale


def _dequantize_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]) \
        .astype(x_ref.dtype)


def _pad_rows(x, rows):
    nb = x.shape[0]
    up = pl.cdiv(nb, rows) * rows
    if up == nb:
        return x
    return jax.numpy.pad(x, ((0, up - nb),) + ((0, 0),) * (x.ndim - 1))


def quantize_pallas(x, *, interpret: bool = False):
    """x: (nb, BLOCK) float -> (codes int8 (nb, BLOCK), scales f32 (nb, 1))."""
    nb = x.shape[0]
    rows = min(ROWS_PER_TILE, nb)
    x = _pad_rows(x, rows)          # whole tiles only: no OOB reads
    nbp = x.shape[0]
    grid = (nbp // rows,)
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nbp, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nbp, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:nb], s[:nb]


def quantize_delta_pallas(x, prev_q, *, interpret: bool = False):
    """Fused quantize + XOR delta. Returns (delta, scales, codes)."""
    nb = x.shape[0]
    rows = min(ROWS_PER_TILE, nb)
    x = _pad_rows(x, rows)
    prev_q = _pad_rows(prev_q, rows)
    nbp = x.shape[0]
    grid = (nbp // rows,)
    d, s, q = pl.pallas_call(
        _quantize_delta_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0)),
                   pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nbp, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nbp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((nbp, BLOCK), jnp.int8)],
        interpret=interpret,
    )(x, prev_q)
    return d[:nb], s[:nb], q[:nb]


def dequantize_pallas(q, scale, dtype=jnp.float32, *, interpret: bool = False):
    nb = q.shape[0]
    rows = min(ROWS_PER_TILE, nb)
    q = _pad_rows(q, rows)
    scale = _pad_rows(scale, rows)
    nbp = q.shape[0]
    grid = (nbp // rows,)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, BLOCK), dtype),
        interpret=interpret,
    )(q, scale)
    return out[:nb]
