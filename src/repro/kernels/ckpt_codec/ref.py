"""Pure-jnp oracle for the checkpoint codec.

The codec is the TPU-native answer to "reduce the bytes iCheck's agents must
move" (DESIGN.md SS2): checkpoints are (1) block-quantized to int8 with one
f32 scale per block of 256 values, and (2) XOR-diffed against the previous
checkpoint's quantized form, so that unchanged blocks become zero bytes and
compress to nothing under zstd on the agent side.

All functions operate on *flattened, padded* buffers of shape
(num_blocks, BLOCK); padding/unpadding to that layout is done by ``ops``.
``BLOCK`` and the numpy reference live in :mod:`.blocks` (shared with the
host-side wire codec in ``repro.core.tiers`` so the two cannot drift).
"""
from __future__ import annotations

import jax.numpy as jnp

from .blocks import BLOCK

__all__ = ["BLOCK", "quantize_ref", "dequantize_ref", "xor_delta_ref",
           "quantize_delta_ref"]


def quantize_ref(x):
    """(nb, BLOCK) float -> (int8 codes (nb, BLOCK), f32 scales (nb, 1))."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def xor_delta_ref(curr_q, prev_q):
    """Bitwise delta between two int8 code buffers (identical -> zeros)."""
    return jnp.bitwise_xor(curr_q, prev_q)


def quantize_delta_ref(x, prev_q):
    """Fused quantize + XOR-delta: what the agent receives for an
    *incremental* commit. Returns (delta codes, scales, current codes)."""
    q, scale = quantize_ref(x)
    return jnp.bitwise_xor(q, prev_q), scale, q
