"""Pallas TPU kernels for the Reed-Solomon k+m erasure encode.

The commit hot path turns one shard payload (viewed as ``k`` uint8 data
rows, see :func:`.rs.split_rows`) into ``m`` parity rows, ``P = C @ D``
over GF(2^8).  ``k`` and ``m`` are compile-time constants, so the whole
field multiply unrolls into xtime (carry-less double + conditional
reduction by the field polynomial) and XOR steps — no log/exp table
gathers, which TPUs hate.  Parity row 0 has all-ones coefficients and
degenerates to the pure-XOR kernel.

Tiling: rows are padded to the int32 sublane multiple (8) and columns to
a lane multiple (128); the grid walks column tiles with all k rows
resident, so each step is one (K_PAD, COLS_PER_TILE) VMEM block in and
one (M_PAD, COLS_PER_TILE) block out.  Bytes travel as int32 lanes (the
TPU VPU has no uint8 ALU path worth using here) and are masked back to
uint8 range by construction — xtime never leaves [0, 255].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import next_multiple
from .rs import rs_generator_matrix

ROW_PAD = 8          # int32 sublane multiple
COL_PAD = 128        # lane multiple
COLS_PER_TILE = 512


def _xtime(v):
    """GF(2^8) multiply-by-x on int32 lanes holding byte values."""
    return (v << 1) ^ ((v >> 7) * 0x11D)


def _gf_mul_const(v, coef: int):
    """Multiply byte lanes by the compile-time constant ``coef``.

    Russian-peasant product fully unrolled over the (static) bits of
    ``coef``: at most 8 xtime + 8 XOR ops, usually far fewer.
    """
    coef = int(coef)
    if coef == 0:
        return jnp.zeros_like(v)
    acc = None
    cur = v
    while coef:
        if coef & 1:
            acc = cur if acc is None else acc ^ cur
        coef >>= 1
        if coef:
            cur = _xtime(cur)
    return acc


def _parity_rows(d, coef):
    """Shared kernel body: (rows, cols) int32 data -> list of parity rows."""
    outs = []
    for row in coef:
        acc = None
        for i, c in enumerate(row):
            term = _gf_mul_const(d[i:i + 1, :], int(c))
            acc = term if acc is None else acc ^ term
        outs.append(acc)
    return outs


def rs_encode_ref(data_rows, m: int):
    """Pure-jnp oracle: (k, stride) int32 byte lanes -> (m, stride)."""
    k = data_rows.shape[0]
    coef = rs_generator_matrix(k, m)
    return jnp.concatenate(_parity_rows(data_rows, coef), axis=0)


def _make_encode_kernel(coef, m_pad: int):
    def kernel(d_ref, p_ref):
        d = d_ref[...]
        outs = _parity_rows(d, coef)
        if m_pad > len(outs):
            outs.append(jnp.zeros((m_pad - len(outs), d.shape[1]),
                                  dtype=d.dtype))
        p_ref[...] = jnp.concatenate(outs, axis=0)
    return kernel


def rs_encode_pallas(data_rows, m: int, *, interpret: bool = False):
    """(k, stride) int32 byte lanes -> (m, stride) parity byte lanes."""
    k, stride = data_rows.shape
    coef = rs_generator_matrix(k, m)
    k_pad = next_multiple(k, ROW_PAD)
    m_pad = next_multiple(m, ROW_PAD)
    cols = next_multiple(stride, COL_PAD)
    tile = min(COLS_PER_TILE, cols)
    cols = next_multiple(cols, tile)
    x = jnp.pad(data_rows, ((0, k_pad - k), (0, cols - stride)))
    grid = (cols // tile,)
    parity = pl.pallas_call(
        _make_encode_kernel(coef, m_pad),
        grid=grid,
        in_specs=[pl.BlockSpec((k_pad, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m_pad, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m_pad, cols), data_rows.dtype),
        interpret=interpret,
    )(x)
    return parity[:m, :stride]
