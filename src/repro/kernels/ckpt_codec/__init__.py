from .ops import dequantize, quantize, quantize_delta, undelta_dequantize
from .ref import BLOCK

__all__ = ["quantize", "quantize_delta", "dequantize", "undelta_dequantize",
           "BLOCK"]
