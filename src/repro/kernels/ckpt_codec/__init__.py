"""Checkpoint codec: blockwise int8 quantization + XOR delta.

``blocks`` (the layout constants + numpy reference) is imported eagerly and
stays jax-free; the jit'd device ops resolve lazily (PEP 562) so that
``repro.core.tiers`` can share the blockwise reference without pulling jax
into every ``repro.core`` import.
"""
from __future__ import annotations

from importlib import import_module

from .blocks import BLOCK, dequantize_np, quantize_np, to_blocks_np

_OPS = ("quantize", "quantize_delta", "dequantize", "undelta_dequantize")

__all__ = ["BLOCK", "to_blocks_np", "quantize_np", "dequantize_np", *_OPS]


def __getattr__(name: str):
    if name in _OPS:
        value = getattr(import_module(".ops", __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
