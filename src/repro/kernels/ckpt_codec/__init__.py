"""Checkpoint codec: blockwise int8 quantization + XOR delta + RS erasure.

``blocks`` and ``rs`` (layout constants + numpy references) are imported
eagerly and stay jax-free; the jit'd device ops resolve lazily (PEP 562)
so that ``repro.core.tiers`` can share the blockwise and erasure
references without pulling jax into every ``repro.core`` import.
"""
from __future__ import annotations

from importlib import import_module

from .blocks import BLOCK, dequantize_np, quantize_np, to_blocks_np
from .rs import (join_rows, rs_decode_np, rs_encode_np, rs_generator_matrix,
                 split_rows)

_OPS = ("quantize", "quantize_delta", "dequantize", "undelta_dequantize",
        "rs_encode")

__all__ = ["BLOCK", "to_blocks_np", "quantize_np", "dequantize_np",
           "rs_encode_np", "rs_decode_np", "rs_generator_matrix",
           "split_rows", "join_rows", *_OPS]


def __getattr__(name: str):
    if name in _OPS:
        value = getattr(import_module(".ops", __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
