"""Public, jit-friendly checkpoint-codec ops (pad/flatten + impl dispatch).

``repro.core.snapshot.snapshot_pytree(codec="q8"|"q8-delta")`` calls these
on the commit hot path: every float region part goes through
:func:`quantize` (or :func:`quantize_delta` against the catalog's
previous-codes state) *on device*, before the D2H copy, so the host — and
then the client→agent fabric and every storage tier — moves int8 codes +
1/256 overhead of f32 scales instead of the raw f32 payload (~4x fewer
bytes; for ``q8-delta`` the host packs the XOR deltas into sparse frames
where unchanged blocks cost zero wire bytes).  Shape/dtype restoration
metadata and the delta-frame bookkeeping travel in ``RegionMeta``
(``dtype``, ``partition``, plus ``frame``/``chain`` on the per-checkpoint
copies); :func:`undelta_dequantize` is the device-side replay primitive
that folds a delta frame back onto the previous codes — the host restart
path (``repro.core.tiers.q8_chain_decode``) applies the same XOR +
dequantize in numpy, asserted bit-identical in the test suite.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..common import resolve_impl
from . import kernel as K
from . import ref as R
from . import rs_kernel as RS
from .ref import BLOCK


def _to_blocks(x):
    """Flatten + zero-pad to (nb, BLOCK). Returns (blocks, orig_size)."""
    flat = jnp.ravel(x)
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    flat = jnp.pad(flat, (0, nb * BLOCK - n))
    return flat.reshape(nb, BLOCK), n


@partial(jax.jit, static_argnames=("impl",))
def quantize(x, impl: str | None = None):
    """Array -> (codes int8 (nb, BLOCK), scales f32 (nb, 1)).

    Shape/dtype restoration metadata travels with the caller (RegionMeta).
    """
    blocks, _ = _to_blocks(x)
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        return R.quantize_ref(blocks)
    return K.quantize_pallas(blocks, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("impl",))
def quantize_delta(x, prev_q, impl: str | None = None):
    """Array + previous codes -> (delta int8, scales f32, codes int8)."""
    blocks, _ = _to_blocks(x)
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        return R.quantize_delta_ref(blocks, prev_q)
    return K.quantize_delta_pallas(blocks, prev_q, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("shape", "dtype", "impl"))
def dequantize(q, scale, shape, dtype=jnp.float32, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        blocks = R.dequantize_ref(q, scale, dtype)
    else:
        blocks = K.dequantize_pallas(q, scale, dtype,
                                     interpret=(impl == "interpret"))
    n = int(np.prod(shape)) if shape else 1
    return jnp.ravel(blocks)[:n].reshape(shape)


@partial(jax.jit, static_argnames=("shape", "dtype", "impl"))
def undelta_dequantize(delta, prev_q, scale, shape, dtype=jnp.float32,
                       impl: str | None = None):
    """Invert a delta commit: codes = delta ^ prev_q, then dequantize."""
    return dequantize(jnp.bitwise_xor(delta, prev_q), scale, shape, dtype,
                      impl=impl)


@partial(jax.jit, static_argnames=("m", "impl"))
def rs_encode(data_rows, m: int = 1, impl: str | None = None):
    """Reed-Solomon parity: (k, stride) uint8 data -> (m, stride) parity.

    The device-side twin of :func:`repro.kernels.ckpt_codec.rs.rs_encode_np`
    (asserted bit-identical in the test suite); the erasure-coded L1
    durability path in ``repro.core.tiers`` runs the numpy reference on the
    host, this op exists for on-device encode ahead of the D2H copy.
    """
    x = data_rows.astype(jnp.int32)
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        parity = RS.rs_encode_ref(x, m)
    else:
        parity = RS.rs_encode_pallas(x, m, interpret=(impl == "interpret"))
    return parity.astype(jnp.uint8)
