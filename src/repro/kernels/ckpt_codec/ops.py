"""Public, jit-friendly checkpoint-codec ops (pad/flatten + impl dispatch).

These are what ``repro.core.snapshot`` calls on the commit path when the
client is configured with ``codec="q8"`` / ``codec="q8-delta"``: the encode
runs *on device* before the D2H copy, so the host/agent fabric moves ~4x
fewer bytes (int8 codes + 1/256 overhead of f32 scales).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..common import resolve_impl
from . import kernel as K
from . import ref as R
from .ref import BLOCK


def _to_blocks(x):
    """Flatten + zero-pad to (nb, BLOCK). Returns (blocks, orig_size)."""
    flat = jnp.ravel(x)
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    flat = jnp.pad(flat, (0, nb * BLOCK - n))
    return flat.reshape(nb, BLOCK), n


@partial(jax.jit, static_argnames=("impl",))
def quantize(x, impl: str | None = None):
    """Array -> (codes int8 (nb, BLOCK), scales f32 (nb, 1)).

    Shape/dtype restoration metadata travels with the caller (RegionMeta).
    """
    blocks, _ = _to_blocks(x)
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        return R.quantize_ref(blocks)
    return K.quantize_pallas(blocks, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("impl",))
def quantize_delta(x, prev_q, impl: str | None = None):
    """Array + previous codes -> (delta int8, scales f32, codes int8)."""
    blocks, _ = _to_blocks(x)
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        return R.quantize_delta_ref(blocks, prev_q)
    return K.quantize_delta_pallas(blocks, prev_q, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("shape", "dtype", "impl"))
def dequantize(q, scale, shape, dtype=jnp.float32, impl: str | None = None):
    impl = resolve_impl(impl)
    if impl in ("xla", "ref"):
        blocks = R.dequantize_ref(q, scale, dtype)
    else:
        blocks = K.dequantize_pallas(q, scale, dtype,
                                     interpret=(impl == "interpret"))
    n = int(np.prod(shape)) if shape else 1
    return jnp.ravel(blocks)[:n].reshape(shape)


@partial(jax.jit, static_argnames=("shape", "dtype", "impl"))
def undelta_dequantize(delta, prev_q, scale, shape, dtype=jnp.float32,
                       impl: str | None = None):
    """Invert a delta commit: codes = delta ^ prev_q, then dequantize."""
    return dequantize(jnp.bitwise_xor(delta, prev_q), scale, shape, dtype,
                      impl=impl)
