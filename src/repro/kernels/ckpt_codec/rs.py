"""GF(2^8) Reed-Solomon k+m erasure codec — jax-free numpy reference.

One shared definition of the stripe math: the host-side fragment codec in
``repro.core.tiers`` (the commit/rebuild/restore data path) and the Pallas
encode kernels in :mod:`.rs_kernel` both derive from the tables and the
generator defined here, so the device kernels and the durability layer
cannot drift.  Everything below is plain numpy — importable by the agents
and the catalog without touching jax.

Layout: a shard payload is split into ``k`` contiguous data fragments
(stride = ceil(len/k), zero-padded), viewed as uint8 rows of one matrix
``D`` of shape (k, stride).  ``m`` parity rows are ``P = C @ D`` over
GF(2^8) with the Vandermonde-style generator ``C[j][i] = g^(j*i)``
(g = 2, the primitive element of the field under ``_PRIM_POLY``):

  * row 0 is all-ones — parity 0 is the pure XOR of the data rows, so the
    single-parity (m=1) hot path never multiplies;
  * rows 0..m-1 for m <= 2 form an MDS code (the classic RAID-6
    construction): *any* k of the k+m fragments reconstruct the payload.

Decode inverts the k x k matrix of surviving rows (Gauss-Jordan in
GF(2^8)) and multiplies it back onto the surviving fragments.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

# x^8 + x^4 + x^3 + x^2 + 1 — the AES/QR-code field polynomial
_PRIM_POLY = 0x11D
_GENERATOR = 2


def _build_tables():
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= _PRIM_POLY
    exp[255:510] = exp[:255]       # wraparound so mul never reduces mod 255
    return exp, log


GF_EXP, GF_LOG = _build_tables()


def gf_mul(a: int, b: int) -> int:
    """Scalar GF(2^8) multiply (tables)."""
    if a == 0 or b == 0:
        return 0
    return int(GF_EXP[int(GF_LOG[a]) + int(GF_LOG[b])])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("gf_inv(0)")
    return int(GF_EXP[255 - int(GF_LOG[a])])


def gf_mul_row(coef: int, row: np.ndarray) -> np.ndarray:
    """Multiply a uint8 vector by one GF(2^8) constant (vectorized tables)."""
    if coef == 0:
        return np.zeros_like(row)
    if coef == 1:
        return row.copy()
    shift = int(GF_LOG[coef])
    out = np.zeros_like(row)
    nz = row != 0
    out[nz] = GF_EXP[GF_LOG[row[nz].astype(np.int32)] + shift]
    return out


def rs_generator_matrix(k: int, m: int) -> np.ndarray:
    """(m, k) parity generator: coef[j][i] = g^(j*i); row 0 = all ones."""
    if k < 1 or m < 0:
        raise ValueError(f"need k >= 1 and m >= 0, got k={k} m={m}")
    if m > 2:
        # rows g^(j*i) are only guaranteed MDS for m <= 2 (RAID-6); keep
        # the promise honest instead of silently weakening durability
        raise ValueError(f"m <= 2 supported by this generator, got m={m}")
    coef = np.zeros((m, k), dtype=np.uint8)
    for j in range(m):
        for i in range(k):
            coef[j, i] = GF_EXP[(j * i) % 255]
    return coef


def rs_encode_np(data_rows: np.ndarray, m: int) -> np.ndarray:
    """(k, stride) uint8 data rows -> (m, stride) parity rows, P = C @ D."""
    data_rows = np.ascontiguousarray(data_rows, dtype=np.uint8)
    k = data_rows.shape[0]
    coef = rs_generator_matrix(k, m)
    parity = np.zeros((m, data_rows.shape[1]), dtype=np.uint8)
    for j in range(m):
        acc = np.zeros(data_rows.shape[1], dtype=np.uint8)
        for i in range(k):
            acc ^= gf_mul_row(int(coef[j, i]), data_rows[i])
        parity[j] = acc
    return parity


def _gf_matrix_inv(mat: np.ndarray) -> np.ndarray:
    """Invert a (k, k) GF(2^8) matrix by Gauss-Jordan elimination."""
    k = mat.shape[0]
    a = mat.astype(np.uint8).copy()
    inv = np.eye(k, dtype=np.uint8)
    for col in range(k):
        pivot = next((r for r in range(col, k) if a[r, col]), None)
        if pivot is None:
            raise ValueError("survivor matrix is singular (not enough "
                             "independent fragments)")
        if pivot != col:
            a[[col, pivot]] = a[[pivot, col]]
            inv[[col, pivot]] = inv[[pivot, col]]
        piv_inv = gf_inv(int(a[col, col]))
        a[col] = gf_mul_row(piv_inv, a[col])
        inv[col] = gf_mul_row(piv_inv, inv[col])
        for r in range(k):
            if r != col and a[r, col]:
                f = int(a[r, col])
                a[r] ^= gf_mul_row(f, a[col])
                inv[r] ^= gf_mul_row(f, inv[col])
    return inv


def rs_decode_np(fragments: Dict[int, np.ndarray], k: int,
                 m: int) -> np.ndarray:
    """Reconstruct the (k, stride) data rows from any k surviving fragments.

    ``fragments`` maps fragment index -> uint8 row, where indices 0..k-1
    are data rows and k..k+m-1 are parity rows.  Raises ``ValueError``
    when fewer than k fragments survive.
    """
    if len(fragments) < k:
        raise ValueError(f"need {k} fragments to decode, have "
                         f"{len(fragments)}")
    have_data = [i for i in sorted(fragments) if i < k]
    if len(have_data) == k:        # healthy read: no field math at all
        return np.stack([np.asarray(fragments[i], dtype=np.uint8)
                         for i in range(k)])
    coef = rs_generator_matrix(k, m)
    # full (k+m, k) encode matrix: identity on top, parity rows below
    full = np.vstack([np.eye(k, dtype=np.uint8), coef])
    use: List[int] = sorted(fragments)[:k]
    sub = full[use]
    inv = _gf_matrix_inv(sub)
    rows = [np.asarray(fragments[i], dtype=np.uint8) for i in use]
    stride = rows[0].shape[0]
    data = np.zeros((k, stride), dtype=np.uint8)
    for r in range(k):
        acc = np.zeros(stride, dtype=np.uint8)
        for c in range(k):
            acc ^= gf_mul_row(int(inv[r, c]), rows[c])
        data[r] = acc
    return data


def split_rows(payload: bytes, k: int) -> np.ndarray:
    """bytes -> (k, stride) uint8 rows, stride = ceil(len/k), zero-padded."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    stride = max(1, -(-len(payload) // k))
    buf = np.zeros(k * stride, dtype=np.uint8)
    buf[:len(payload)] = np.frombuffer(payload, dtype=np.uint8)
    return buf.reshape(k, stride)


def join_rows(rows: Sequence[np.ndarray], orig_len: int) -> bytes:
    """Inverse of :func:`split_rows`: concat data rows, strip the padding."""
    return np.concatenate([np.asarray(r, dtype=np.uint8)
                           for r in rows]).tobytes()[:orig_len]
