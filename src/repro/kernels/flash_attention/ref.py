"""Pure-jnp oracle for (GQA, causal / sliding-window) attention.

Materializes the full (T, S) score matrix -- O(T*S) memory -- and is only
used as the numerical reference for the Pallas kernel and the blockwise XLA
path.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def allowed_mask(t, s, causal: bool, window: int | None, offset: int):
    """(t, s) boolean mask of *allowed* positions.

    ``offset`` is the absolute position of query row 0 relative to key row 0
    (for decode, offset = S - T: queries are the last T positions).
    """
    qpos = jnp.arange(t)[:, None] + offset
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((t, s), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return ok


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """q: (B, Hq, T, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.

    Returns (B, Hq, T, D) in q.dtype; softmax in f32.
    """
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1)
    scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf)
    ok = allowed_mask(t, s, causal, window, offset=s - t)
    scores = jnp.where(ok[None, None], scores, NEG_INF)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhts,bhsd->bhtd", p, vf)
    return out.astype(q.dtype)
