"""Pallas TPU flash attention (causal / sliding-window, GQA).

Design (TPU-native, not a CUDA port):
  grid = (B, Hq, T/bq, S/bk) with the KV axis innermost ("arbitrary"
  iteration order semantics): the online-softmax accumulators (acc, m, l)
  live in VMEM scratch and persist across the KV-block sweep for a fixed
  (b, h, iq); the output tile is written once, on the last KV block.

  Tiles: q (bq, D), k/v (bk, D) staged HBM->VMEM by BlockSpec; the score
  tile (bq, bk) hits the MXU via jnp.dot in f32.  bq = bk = 128 aligns every
  matmul operand to the 128x128 systolic array.  GQA is handled in the
  BlockSpec index_map (query head h reads KV head h // group), so KV tiles
  are fetched once per group from HBM, never materialized repeated.

  Causal/sliding-window blocks that are fully masked are skipped with
  pl.when -- no MXU work, no accumulator update; for causal attention this
  halves the swept area.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import next_multiple

NEG_INF = -1e30
LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int | None,
               offset: int, s_valid: int, bq: int, bk: int):
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = pl.program_id(2)
    # absolute positions of this tile's queries / keys
    q_lo = iq * bq + offset              # first query's absolute position
    k_lo = jk * bk

    # block-level skip: is any (qpos, kpos) pair in this tile live?
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + bq - 1    # earliest key <= latest query
    if window is not None:
        live &= k_lo + bk - 1 > q_lo - window  # latest key inside window
    live &= k_lo < s_valid               # not a fully padded KV tile

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < s_valid
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0, 1.0, l)    # fully-masked rows -> zeros
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[:, :1] + jnp.log(l))[:, 0]


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False,
                           return_lse: bool = False):
    """q: (B, Hq, T, D), k/v: (B, Hkv, S, D) -> (B, Hq, T, D) [, lse]."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bq = min(block_q, next_multiple(t, 8))
    bk = min(block_k, next_multiple(s, 128))
    tp, sp = next_multiple(t, bq), next_multiple(s, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    grid = (b, hq, tp // bq, sp // bk)
    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        offset=s - t, s_valid=s, bq=bq, bk=bk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, tp, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, tp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out, lse = out[0][:, :, :t, :], out[1][:, :, :t]
    if return_lse:
        return out, lse
    return out
