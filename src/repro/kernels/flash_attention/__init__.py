from .ops import attention
from .ref import attention_ref

__all__ = ["attention", "attention_ref"]
