"""Public attention op with impl dispatch (pallas / interpret / xla / ref).

The ``xla`` path is a blockwise online-softmax written with nested
``lax.scan`` so that it has the *same working set* as the flash kernel
(never materializes a T x S score matrix).  It is what the multi-pod dry-run
lowers on CPU, so the reported HBM bytes of the compiled step reflect a
flash-style attention, and it is also a perfectly usable TPU fallback.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import next_multiple, resolve_impl
from .kernel import flash_attention_pallas
from .ref import NEG_INF, attention_ref


def _mask(iq, jk, bq, bk, offset, s, causal, window):
    qpos = iq * bq + offset + jnp.arange(bq)[:, None]
    kpos = jk * bk + jnp.arange(bk)[None, :]
    ok = kpos < s
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return ok


def _blocked(q, k, v, bq, bk):
    """Pad + reshape to blocks. Returns (qb, kb, vb, dims)."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    tp, sp = next_multiple(t, bq), next_multiple(s, bk)
    nq, nk = tp // bq, sp // bk
    qf = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    qb = qf.reshape(b, hkv, g, nq, bq, d).transpose(3, 0, 1, 2, 4, 5)
    kb = kf.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    vb = vf.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    return qb, kb, vb, (b, hq, hkv, g, t, s, tp, sp, nq, nk, d)


def _xla_blockwise(q, k, v, *, causal, window, scale,
                   block_q: int = 256, block_k: int = 1024,
                   return_lse: bool = False):
    b, hq, t, d = q.shape
    s = k.shape[2]
    bq = min(block_q, next_multiple(t, 8))
    bk = min(block_k, next_multiple(s, 128))
    qb, kb, vb, dims = _blocked(q, k, v, bq, bk)
    (_, _, hkv, g, _, _, tp, sp, nq, nk, _) = dims
    offset = s - t

    def q_block(carry, iq_and_q):
        iq, qt = iq_and_q          # qt: (B, Hkv, G, bq, D)
        qt = qt * scale

        def kv_block(state, jk_and_kv):
            m, l, acc = state
            jk, kt, vt = jk_and_kv
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", qt, kt)
            ok = _mask(iq, jk, bq, bk, offset, s, causal, window)
            sc = jnp.where(ok, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vt)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        lsafe = jnp.where(l == 0, 1.0, l)
        lse = (m[..., 0] + jnp.log(lsafe[..., 0]))      # (B,Hkv,G,bq)
        return carry, (acc / lsafe, lse)

    _, (ob, lseb) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, tp, d)[:, :, :t, :]
    out = out.astype(q.dtype)
    if not return_lse:
        return out
    lse = lseb.transpose(1, 2, 3, 0, 4).reshape(b, hq, tp)[:, :, :t]
    return out, lse


def _xla_flash_bwd(q, k, v, o, lse, do, *, causal, window, scale,
                   block_q: int = 256, block_k: int = 1024):
    """Flash backward: recomputes p per block from the saved logsumexp;
    never materializes a T x S matrix and stores no per-block residuals."""
    b, hq, t, d = q.shape
    s = k.shape[2]
    bq = min(block_q, next_multiple(t, 8))
    bk = min(block_k, next_multiple(s, 128))
    qb, kb, vb, dims = _blocked(q, k, v, bq, bk)
    (_, _, hkv, g, _, _, tp, sp, nq, nk, _) = dims
    offset = s - t
    dof = jnp.pad(do.astype(jnp.float32),
                  ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    dob = dof.reshape(b, hkv, g, nq, bq, d).transpose(3, 0, 1, 2, 4, 5)
    of = jnp.pad(o.astype(jnp.float32),
                 ((0, 0), (0, 0), (0, tp - t), (0, 0)))
    # D_i = rowsum(do * o)
    Df = jnp.sum(dof * of, axis=-1)                     # (B,Hq,Tp)
    Db = Df.reshape(b, hkv, g, nq, bq).transpose(3, 0, 1, 2, 4)
    lsef = jnp.pad(lse.astype(jnp.float32), ((0, 0), (0, 0), (0, tp - t)),
                   constant_values=jnp.inf)
    lseb = lsef.reshape(b, hkv, g, nq, bq).transpose(3, 0, 1, 2, 4)

    def q_block(carry, xs):
        dk_acc, dv_acc = carry
        iq, qt, dot_, Dt, Lt = xs

        def kv_block(inner, jk_and_kv):
            dq_t, dk_a, dv_a = inner
            jk, kt, vt = jk_and_kv
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", qt * scale, kt)
            ok = _mask(iq, jk, bq, bk, offset, s, causal, window)
            p = jnp.where(ok, jnp.exp(sc - Lt[..., None]), 0.0)
            dv_blk = jnp.einsum("bhgqk,bhgqd->bhkd", p, dot_)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dot_, vt)
            ds = p * (dp - Dt[..., None])
            dq_t = dq_t + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kt) * scale
            dk_blk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qt) * scale
            dk_a = jax.lax.dynamic_update_index_in_dim(
                dk_a, dk_a[jk] + dk_blk, jk, 0)
            dv_a = jax.lax.dynamic_update_index_in_dim(
                dv_a, dv_a[jk] + dv_blk, jk, 0)
            return (dq_t, dk_a, dv_a), None

        dq0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (dq_t, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_block, (dq0, dk_acc, dv_acc), (jnp.arange(nk), kb, vb))
        return (dk_acc, dv_acc), dq_t

    dk0 = jnp.zeros((nk, b, hkv, bk, d), jnp.float32)
    dv0 = jnp.zeros((nk, b, hkv, bk, d), jnp.float32)
    (dkb, dvb), dqb = jax.lax.scan(
        q_block, (dk0, dv0), (jnp.arange(nq), qb, dob, Db, lseb))
    dq = dqb.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, tp, d)[:, :, :t, :]
    dk = dkb.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sp, d)[:, :, :s, :]
    dv = dvb.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sp, d)[:, :, :s, :]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# nondiff_argnums (not *_argnames): works on every jax we support
@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _attention_core(q, k, v, causal, window, scale, impl, block_q, block_k):
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale)
    if impl == "xla":
        return _xla_blockwise(q, k, v, causal=causal, window=window,
                              scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=(impl == "interpret"))


def _attention_fwd(q, k, v, causal, window, scale, impl, block_q, block_k):
    # fwd via the dispatched impl; residuals = (q, k, v, o, lse) -- the
    # flash contract: backward recomputes p blockwise from the logsumexp.
    if impl in ("xla", "ref"):
        o, lse = _xla_blockwise(q, k, v, causal=causal, window=window,
                                scale=scale, return_lse=True)
    else:
        o, lse = flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k,
            interpret=(impl == "interpret"), return_lse=True)
    return o, (q, k, v, o, lse)


def _attention_bwd(causal, window, scale, impl, block_q, block_k, res, do):
    q, k, v, o, lse = res
    return _xla_flash_bwd(q, k, v, o, lse, do, causal=causal, window=window,
                          scale=scale)


_attention_core.defvjp(_attention_fwd, _attention_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "scale", "impl",
                                   "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, impl: str | None = None,
              block_q: int = 128, block_k: int = 128):
    """Flash attention. q: (B, Hq, T, D), k/v: (B, Hkv, S, D)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    impl = resolve_impl(impl)
    return _attention_core(q, k, v, causal, window, scale, impl,
                           block_q, block_k)
