"""Pallas TPU kernel for RWKV-6: chunked data-dependent-decay recurrence.

TPU adaptation (vs. the CUDA kernel in the RWKV repo, which runs one thread
per channel serially over time): we use the *chunked linear-attention* form.
The sequence is cut into chunks of C tokens; the recurrent state S (Dk x Dv,
f32) lives in VMEM scratch and persists across the chunk sweep (grid's last,
"arbitrary", axis), while all intra-chunk work is dense algebra on (C, D)
tiles that maps onto the MXU:

  inter-chunk:  o  += (r_t * e_t) @ S_in            e_t = exp(L_{t-1})
  intra-chunk:  A[t,i] = sum_c r[t,c] k[i,c] exp(L_{t-1,c} - L_{i,c}), i < t
                A[t,t] = sum_c r[t,c] u[c] k[t,c]   (bonus term)
                o  += A @ v
  state:        S_out = diag(exp(L_C)) S_in + (k * exp(L_C - L))^T @ v

with L = cumsum(log w) over the chunk.  All exponents are differences
"later minus earlier" along time, hence <= 0: *bounded*, no overflow for any
decay -- this is why the kernel computes the intra-chunk pairwise tensor
(C, C, D) explicitly in VMEM (1 MiB at C=64, D=64) instead of the
k/d-normalized matmul form, which overflows for strong decays.

Grid: (B, H, T/C); block tiles r/k/v/w: (C, D); scratch: S (Dk, Dv) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import next_multiple

LOG_W_MIN = -30.0  # clamp: exp(-30) ~ 1e-13, numerically zero decay


def _rwkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
                  s_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (C, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)               # (1, D) -> broadcast row
    S = s_ref[...]                                 # (Dk, Dv)

    lw = jnp.maximum(lw_ref[0, 0].astype(jnp.float32), LOG_W_MIN)
    L = jnp.cumsum(lw, axis=0)                     # inclusive decay  (C, D)
    Lx = L - lw                                    # exclusive decay  (C, D)

    # inter-chunk: contribution of the carried-in state
    re = r * jnp.exp(Lx)
    o = jax.lax.dot_general(re, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: pairwise decay tensor, strictly-lower mask, + bonus diag
    diff = Lx[:, None, :] - L[None, :, :]          # (C, C, D), t x i
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ij = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (ij < ti)[:, :, None]
    E = jnp.where(strict, jnp.exp(jnp.where(strict, diff, 0.0)), 0.0)
    A = jnp.sum(E * r[:, None, :] * k[None, :, :], axis=2)   # (C, C)
    diag = jnp.sum(r * u * k, axis=1)              # (C,)
    A += jnp.where(ti == ij, diag[:, None], 0.0)
    o += jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state update: decay-to-chunk-end factors are all <= 0 in log space
    Llast = L[-1:, :]                              # (1, D)
    kd = k * jnp.exp(Llast - L)                    # (C, D)
    s_ref[...] = jnp.exp(Llast.T) * S + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _emit_state():
        sT_ref[0, 0] = s_ref[...]


def rwkv6_pallas(r, k, v, log_w, u, s0=None, *, chunk: int = 64,
                 interpret: bool = False):
    """r/k/v/log_w: (B, H, T, D); u: (H, D). Returns (o, s_final)."""
    b, h, t, d = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    c = min(chunk, next_multiple(t, 8))
    tp = next_multiple(t, c)
    pad = ((0, 0), (0, 0), (0, tp - t), (0, 0))
    # padded tail: lw=0 (no decay), k=0 (no contribution) keeps state exact
    rp, kp, vp = (jnp.pad(x, pad) for x in (r, k, v))
    wp = jnp.pad(log_w, pad)
    kern = functools.partial(_rwkv6_kernel, chunk=c)
    o, sT = pl.pallas_call(
        kern,
        grid=(b, h, tp // c),
        in_specs=[
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, d), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, tp, d), v.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rp, kp, vp, wp, u, s0)
    return o[:, :, :t, :], sT
