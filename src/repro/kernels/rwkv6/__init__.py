from .ops import rwkv6
from .ref import rwkv6_ref

__all__ = ["rwkv6", "rwkv6_ref"]
