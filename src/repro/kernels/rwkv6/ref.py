"""Pure-jnp oracle for the RWKV-6 (Finch) time-mix recurrence.

Per head, with state S in R^{Dk x Dv}:

    o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(exp(lw_t)) S_{t-1} + k_t^T v_t

where lw_t <= 0 is the *data-dependent* per-channel log-decay (the defining
feature of RWKV-6 vs RWKV-4/5; the model computes lw = -exp(w_proj)
natively, so the kernel API takes log-decay directly -- passing w and
re-taking log(w) is a numerically hostile autodiff roundtrip) and u is the
learned per-channel "bonus" for the current token.  The oracle is a plain
``lax.scan`` over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, log_w, u, s0=None):
    """r/k/v/log_w: (B, H, T, D) (log_w <= 0); u: (H, D);
    s0: (B, H, D, D) or None.

    Returns (o: (B, H, T, D) in v.dtype, s_final: (B, H, D, D) f32).
    """
    b, h, t, d = r.shape
    rf, kf, vf, lwf = (x.astype(jnp.float32) for x in (r, k, v, log_w))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    else:
        s0 = s0.astype(jnp.float32)

    def step(S, rkvw):
        rt, kt, vt, lwt = rkvw                     # each (B, H, D)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt,
                       S + uf[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, o

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (rf, kf, vf, lwf))
    s_final, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 2).astype(v.dtype), s_final
