"""Public RWKV-6 op with impl dispatch.

The ``xla`` path scans over chunks with the (Dk, Dv) state as carry and
computes the intra-chunk pairwise decay tensor exactly (same math as the
Pallas kernel: every exponent is a "later minus earlier" cumulative-log-decay
difference, hence <= 0 and overflow-free for *any* decay).  The naive
k/exp(L) matmul normalization overflows for strong decays, so we trade a
(C, C, D) transient (bounded by chunk=32 here) for unconditional numerical
safety.  This is what the multi-pod dry-run lowers on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import next_multiple, resolve_impl
from .kernel import rwkv6_pallas
from .ref import rwkv6_ref


def _xla_chunked(r, k, v, log_w, u, s0, chunk: int = 32):
    b, h, t, d = r.shape
    c = min(chunk, next_multiple(t, 8))
    tp = next_multiple(t, c)
    pad = ((0, 0), (0, 0), (0, tp - t), (0, 0))
    rf = jnp.pad(r.astype(jnp.float32), pad)
    kf = jnp.pad(k.astype(jnp.float32), pad)
    vf = jnp.pad(v.astype(jnp.float32), pad)
    wf = jnp.pad(log_w.astype(jnp.float32), pad)
    uf = u.astype(jnp.float32)
    nc = tp // c
    # (nc, B, H, C, D)
    rb, kb, vb, wb = (x.reshape(b, h, nc, c, d).transpose(2, 0, 1, 3, 4)
                      for x in (rf, kf, vf, wf))
    mask_strict = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def per_chunk(S, rkvw):
        rt, kt, vt, lw = rkvw                     # (B, H, C, D)
        L = jnp.cumsum(lw, axis=2)
        Lx = L - lw
        re = rt * jnp.exp(Lx)
        o = jnp.einsum("bhcd,bhde->bhce", re, S)
        # exact pairwise intra-chunk decays: (B, H, C_t, C_i, D), exps <= 0
        diff = Lx[:, :, :, None, :] - L[:, :, None, :, :]
        E = jnp.where(mask_strict[None, None, :, :, None],
                      jnp.exp(jnp.where(mask_strict[None, None, :, :, None],
                                        diff, 0.0)), 0.0)
        A = jnp.einsum("bhtic,bhtc,bhic->bhti", E, rt, kt)
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rt, uf, kt)
        o += jnp.einsum("bhti,bhid->bhtd", A, vt)
        o += diag[..., None] * vt
        Llast = L[:, :, -1:, :]
        kend = kt * jnp.exp(Llast - L)
        S = (jnp.exp(Llast[:, :, 0, :])[..., None] * S
             + jnp.einsum("bhck,bhcv->bhkv", kend, vt))
        return S, o

    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    # checkpoint each chunk: backward recomputes the (C, C, D) pairwise
    # tensor instead of storing it per chunk (flash-style memory contract)
    S, ob = jax.lax.scan(jax.checkpoint(per_chunk),
                         s0.astype(jnp.float32), (rb, kb, vb, wb))
    o = ob.transpose(1, 2, 0, 3, 4).reshape(b, h, tp, d)
    return o[:, :, :t, :].astype(v.dtype), S


def _dispatch(r, k, v, log_w, u, s0, chunk, impl):
    if impl == "ref":
        return rwkv6_ref(r, k, v, log_w, u, s0)
    if impl == "xla":
        return _xla_chunked(r, k, v, log_w, u, s0, chunk=chunk)
    return rwkv6_pallas(r, k, v, log_w, u, s0, chunk=chunk,
                        interpret=(impl == "interpret"))


# nondiff_argnums (not *_argnames): works on every jax we support
@partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _rwkv6_core(r, k, v, log_w, u, s0, chunk, impl):
    return _dispatch(r, k, v, log_w, u, s0, chunk, impl)


def _rwkv6_fwd(r, k, v, log_w, u, s0, chunk, impl):
    out = _dispatch(r, k, v, log_w, u, s0, chunk, impl)
    return out, (r, k, v, log_w, u, s0)


def _rwkv6_bwd(chunk, impl, res, ct):
    # gradients via the chunked XLA path (the Pallas kernel shares its
    # math; a dedicated bwd kernel is the TPU production extension)
    r, k, v, log_w, u, s0 = res
    _, vjp = jax.vjp(
        lambda *args: _xla_chunked(*args, chunk=chunk), r, k, v, log_w, u, s0)
    return vjp(ct)


_rwkv6_core.defvjp(_rwkv6_fwd, _rwkv6_bwd)


@partial(jax.jit, static_argnames=("chunk", "impl"))
def rwkv6(r, k, v, log_w, u, s0=None, *, chunk: int = 64,
          impl: str | None = None):
    """RWKV-6 time-mix core. r/k/v/log_w: (B, H, T, D), log_w <= 0;
    u: (H, D).

    Returns (o: (B, H, T, D), s_final: (B, H, Dk, Dv) f32).
    """
    impl = resolve_impl(impl)
    if s0 is None:
        b, h, _, d = r.shape
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    return _rwkv6_core(r, k, v, log_w, u, s0, chunk, impl)
