"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships three paths (see ``common.resolve_impl``):
``kernel.py`` -- pl.pallas_call + BlockSpec VMEM tiling (TPU production);
``ref.py``    -- pure-jnp oracle used by the test suite;
``ops.py``    -- jit'd public op with a blockwise XLA fallback that the
                 CPU multi-pod dry-run lowers (flash-style working set).
"""
from .ckpt_codec import dequantize, quantize, quantize_delta, undelta_dequantize
from .common import resolve_impl
from .flash_attention import attention, attention_ref
from .rglru import rglru, rglru_ref
from .rwkv6 import rwkv6, rwkv6_ref

__all__ = [
    "attention", "attention_ref", "rwkv6", "rwkv6_ref", "rglru", "rglru_ref",
    "quantize", "quantize_delta", "dequantize", "undelta_dequantize",
    "resolve_impl",
]
