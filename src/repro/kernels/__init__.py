"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships three paths (see ``common.resolve_impl``):
``kernel.py`` -- pl.pallas_call + BlockSpec VMEM tiling (TPU production);
``ref.py``    -- pure-jnp oracle used by the test suite;
``ops.py``    -- jit'd public op with a blockwise XLA fallback that the
                 CPU multi-pod dry-run lowers (flash-style working set).

Exports resolve lazily (PEP 562): importing :mod:`repro.kernels` (or a
jax-free submodule such as ``ckpt_codec.blocks``, which the host-side wire
codec in ``repro.core.tiers`` depends on) does not import jax until a
kernel op is actually touched.
"""
from __future__ import annotations

from importlib import import_module

_EXPORTS = {
    "attention": ".flash_attention", "attention_ref": ".flash_attention",
    "rwkv6": ".rwkv6", "rwkv6_ref": ".rwkv6",
    "rglru": ".rglru", "rglru_ref": ".rglru",
    "quantize": ".ckpt_codec", "quantize_delta": ".ckpt_codec",
    "dequantize": ".ckpt_codec", "undelta_dequantize": ".ckpt_codec",
    "resolve_impl": ".common",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        value = getattr(import_module(_EXPORTS[name], __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
