"""Shared kernel-dispatch machinery.

Every kernel package exposes three execution paths:

  ``pallas``     -- ``pl.pallas_call`` compiled for TPU (the production path).
  ``interpret``  -- the same kernel body executed in Pallas interpret mode on
                    CPU; used by the test suite to validate numerics against
                    the pure-jnp oracle in ``ref.py``.
  ``xla``        -- a blockwise jnp/lax implementation with the *same working
                    set* as the kernel (online softmax / chunked recurrence),
                    used when lowering on CPU (multi-pod dry-run) so that
                    ``cost_analysis()`` reflects the flash-style memory
                    behaviour rather than a naive T x T buffer.

``resolve_impl`` picks a path: explicit argument > REPRO_KERNEL_IMPL env var >
backend autodetection (TPU -> pallas, otherwise xla).
"""
from __future__ import annotations

import os
from functools import lru_cache

VALID_IMPLS = ("pallas", "interpret", "xla", "ref")


@lru_cache(maxsize=1)
def _default_backend() -> str:
    import jax

    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover
        return "cpu"


def resolve_impl(impl: str | None = None) -> str:
    if impl is None:
        impl = os.environ.get("REPRO_KERNEL_IMPL") or "auto"
    if impl == "auto":
        impl = "pallas" if _default_backend() == "tpu" else "xla"
    if impl not in VALID_IMPLS:
        raise ValueError(f"impl must be one of {VALID_IMPLS} or 'auto', got {impl!r}")
    return impl


def next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
