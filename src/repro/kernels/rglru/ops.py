"""Public RG-LRU op with impl dispatch.

The ``xla`` path uses ``lax.associative_scan`` over (a, g) pairs -- the
log-depth formulation XLA lowers to an efficient parallel scan; memory is
O(T * D) (no pairwise tensor), which is what the dry-run lowers on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import resolve_impl
from .kernel import rglru_pallas
from .ref import rglru_ref


def _xla_assoc(log_a, g, h0=None):
    la = log_a.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    if h0 is not None:
        gf = gf.at[:, 0, :].add(jnp.exp(la[:, 0, :]) * h0.astype(jnp.float32))

    def combine(x, y):
        ax, gx = x
        ay, gy = y
        return ax + ay, jnp.exp(ay) * gx + gy

    _, h = jax.lax.associative_scan(combine, (la, gf), axis=1)
    return h.astype(g.dtype), h[:, -1, :].astype(jnp.float32)


def _dispatch(log_a, g, h0, chunk, impl):
    if impl == "ref":
        return rglru_ref(log_a, g, h0)
    if impl == "xla":
        return _xla_assoc(log_a, g, h0)
    return rglru_pallas(log_a, g, h0, chunk=chunk,
                        interpret=(impl == "interpret"))


# nondiff_argnums (not *_argnames): works on every jax we support
@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _rglru_core(log_a, g, h0, chunk, impl):
    return _dispatch(log_a, g, h0, chunk, impl)


def _rglru_fwd(log_a, g, h0, chunk, impl):
    h, h_last = _dispatch(log_a, g, h0, chunk, impl)
    return (h, h_last), (log_a, g, h0, h)


def _rglru_bwd(chunk, impl, res, ct):
    """Analytic adjoint of the diagonal recurrence via a reverse
    associative scan -- O(T * D) memory, no stored combine tree.

      lam_t = dh_t + a_{t+1} lam_{t+1}
      dg_t = lam_t;  dlog_a_t = lam_t * h_{t-1} * a_t;  dh0 = a_0 lam_0
    """
    log_a, g, h0, h = res
    dh, dh_last = ct
    la = log_a.astype(jnp.float32)
    dhf = dh.astype(jnp.float32)
    dhf = dhf.at[:, -1, :].add(dh_last.astype(jnp.float32))

    # reverse scan: lam_t = dh_t + a_{t+1} * lam_{t+1}
    a_next = jnp.concatenate(
        [la[:, 1:, :], jnp.full_like(la[:, :1, :], -jnp.inf)], axis=1)

    def combine(x, y):
        ax, lx = x
        ay, ly = y
        return ax + ay, jnp.exp(ay) * lx + ly

    _, lam = jax.lax.associative_scan(combine, (a_next, dhf), axis=1,
                                      reverse=True)
    hf = h.astype(jnp.float32)
    h0f = jnp.zeros_like(hf[:, 0, :]) if h0 is None \
        else h0.astype(jnp.float32)
    h_prev = jnp.concatenate([h0f[:, None, :], hf[:, :-1, :]], axis=1)
    a = jnp.exp(la)
    dlog_a = lam * h_prev * a
    dg = lam.astype(g.dtype)
    dh0 = None if h0 is None else (lam[:, 0, :] * a[:, 0, :]).astype(h0.dtype)
    return dlog_a.astype(log_a.dtype), dg, dh0


_rglru_core.defvjp(_rglru_fwd, _rglru_bwd)


@partial(jax.jit, static_argnames=("chunk", "impl"))
def rglru(log_a, g, h0=None, *, chunk: int = 64, impl: str | None = None):
    """RG-LRU core: h_t = exp(log_a_t) * h_{t-1} + g_t.

    log_a, g: (B, T, D); h0: (B, D) or None.
    Returns (h: (B, T, D), h_final: (B, D) f32).
    """
    impl = resolve_impl(impl)
    return _rglru_core(log_a, g, h0, chunk, impl)
