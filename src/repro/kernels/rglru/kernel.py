"""Pallas TPU kernel for the RG-LRU diagonal gated linear recurrence.

TPU adaptation: RecurrentGemma's GPU kernel is a sequential per-channel scan.
Here the channel axis is laid out across VPU lanes (tiles of (C, bd) with
bd a multiple of 128) and time is chunked: within a chunk of C tokens the
prefix is computed *in closed form* from the cumulative log-decay,

    h_t = exp(L_t) * h_in + sum_{i<=t} exp(L_t - L_i) * g_i,

via an exact pairwise (C, C, bd) tensor in VMEM -- every exponent is a
"later minus earlier" difference of a monotone cumsum, hence <= 0 and
overflow-free.  The carried state h (1, bd) persists in VMEM scratch across
the chunk sweep (grid's last axis).

Grid: (B, D/bd, T/C); tiles log_a/g: (C, bd); scratch: h (1, bd) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import next_multiple


def _rglru_kernel(la_ref, g_ref, o_ref, hT_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[0].astype(jnp.float32)             # (C, bd)
    g = g_ref[0].astype(jnp.float32)
    h_in = h_ref[...]                              # (1, bd)

    L = jnp.cumsum(la, axis=0)                     # (C, bd), monotone down
    # pairwise prefix: exp(L_t - L_i) for i <= t (<= 0 exponents)
    diff = L[:, None, :] - L[None, :, :]           # (C, C, bd)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ij = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lower = (ij <= ti)[:, :, None]
    E = jnp.where(lower, jnp.exp(jnp.where(lower, diff, 0.0)), 0.0)
    h_intra = jnp.sum(E * g[None, :, :], axis=1)   # (C, bd)
    h_seq = jnp.exp(L) * h_in + h_intra
    o_ref[0] = h_seq.astype(o_ref.dtype)
    h_ref[...] = h_seq[-1:, :]

    @pl.when(ci == nc - 1)
    def _emit():
        hT_ref[0] = h_ref[0]


def rglru_pallas(log_a, g, h0=None, *, chunk: int = 64, block_d: int = 512,
                 interpret: bool = False):
    """log_a, g: (B, T, D). Returns (h: (B, T, D), h_final: (B, D) f32)."""
    b, t, d = g.shape
    c = min(chunk, next_multiple(t, 8))
    bd = min(block_d, next_multiple(d, 128))
    tp, dp = next_multiple(t, c), next_multiple(d, bd)
    pad = ((0, 0), (0, tp - t), (0, dp - d))
    lap = jnp.pad(log_a, pad)
    gp = jnp.pad(g, pad)
    if h0 is not None:
        # fold the initial state into the first token: h_1 = a_1 h_0 + g_1
        h0p = jnp.pad(h0.astype(jnp.float32), ((0, 0), (0, dp - d)))
        gp = gp.at[:, 0, :].add(jnp.exp(lap[:, 0, :]) * h0p)
    kern = functools.partial(_rglru_kernel, chunk=c)
    h, hT = pl.pallas_call(
        kern,
        grid=(b, dp // bd, tp // c),
        in_specs=[
            pl.BlockSpec((1, c, bd), lambda b_, j, c_: (b_, c_, j)),
            pl.BlockSpec((1, c, bd), lambda b_, j, c_: (b_, c_, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, bd), lambda b_, j, c_: (b_, c_, j)),
            pl.BlockSpec((1, bd), lambda b_, j, c_: (b_, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, tp, dp), g.dtype),
            jax.ShapeDtypeStruct((b, dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(lap, gp)
    return h[:, :t, :d], hT[:, :d]
