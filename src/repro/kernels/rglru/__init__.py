from .ops import rglru
from .ref import rglru_ref

__all__ = ["rglru", "rglru_ref"]
