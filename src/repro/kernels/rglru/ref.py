"""Pure-jnp oracle for the RG-LRU (Griffin / RecurrentGemma) recurrence.

Diagonal gated linear recurrence, per channel:

    h_t = a_t * h_{t-1} + g_t        a_t = exp(log_a_t) in (0, 1]

where the model computes log_a_t = -c * softplus(Lambda) * sigmoid(r_t) and
g_t = sqrt(1 - a_t^2) * i_t * x_t (input gate + magnitude correction); the
kernel only sees (log_a, g) -- the canonical diagonal scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a, g, h0=None):
    """log_a, g: (B, T, D) (log_a <= 0); h0: (B, D) or None.

    Returns (h: (B, T, D) in g.dtype, h_final: (B, D) f32).
    """
    b, t, d = g.shape
    la = log_a.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, d), jnp.float32)

    def step(h, lag):
        la_t, g_t = lag
        h = jnp.exp(la_t) * h + g_t
        return h, h

    h_final, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(la, 1, 0), jnp.moveaxis(gf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(g.dtype), h_final
