"""Batched serving engine: prefill + greedy decode over the backbone's
cache API, with optional iCheck serving-state checkpointing (beyond-paper:
a preempted inference node can restore its KV cache / recurrent state from
agents instead of re-prefilling)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import restore_pytree, snapshot_pytree
from repro.models import decode_step, init_cache, prefill
from repro.sharding import get_rules, use_rules


def serve_max_len(cfg: ModelConfig, seq_len: int, gen: int = 0) -> int:
    n = seq_len + gen
    if cfg.frontend == "patches":
        n += cfg.num_patches
    return n


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 mesh=None, impl: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self.rules = get_rules(cfg.rules)
        self.impl = impl

        def _prefill(params, batch, cache):
            with use_rules(mesh, self.rules):
                return prefill(cfg, params, batch, cache, impl=impl)

        def _decode(params, cache, toks):
            with use_rules(mesh, self.rules):
                return decode_step(cfg, params, cache, toks, impl=impl)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=1)
        self.last_commit = None     # CommitHandle of the newest cache commit

    def generate(self, batch: Dict, gen_len: int = 16,
                 checkpoint_client=None) -> np.ndarray:
        """Greedy generation. batch: {"tokens": (B, T), ...modality}.

        ``checkpoint_client``: optional ICheckClient; if given, the filled
        cache is committed after prefill (serving-state fault tolerance).
        """
        b = batch["tokens"].shape[0]
        cache = init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        if checkpoint_client is not None:
            snap = snapshot_pytree(cache, step=0)
            checkpoint_client.add_adapt_snapshot(snap)
            self.last_commit = checkpoint_client.commit(
                0, {n: r.parts for n, r in snap.regions.items()})
        out = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
        for _ in range(gen_len - 1):
            logits, cache = self._decode(self.params, cache, out[-1])
            out.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
        return np.concatenate([np.asarray(t) for t in out], axis=1)

    def restore_serving_state(self, checkpoint_client, batch_size: int):
        """Rebuild the prefilled cache from the checkpoint service.

        The restart half of serving-state fault tolerance: a preempted
        inference node fetches the committed KV/recurrent cache from the
        agents (L1) or the PFS (L2) instead of re-running prefill.  Returns
        the restored cache pytree, or None when nothing was committed.
        """
        found = checkpoint_client.restart()
        if found is None:
            return None
        meta, regions, _level = found
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            init_cache(self.cfg, batch_size, self.max_len))
        region_meta = {name: meta.regions[name] for name in regions}
        return restore_pytree(template, regions, region_meta)
