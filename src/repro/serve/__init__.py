from .engine import ServeEngine, serve_max_len

__all__ = ["ServeEngine", "serve_max_len"]
