from .rules import (FSDP_RULES, SEQ_RULES, TP_RULES, Rules, active_rules,
                    constrain, get_rules, named_sharding, spec, use_rules)

__all__ = ["Rules", "TP_RULES", "FSDP_RULES", "SEQ_RULES", "spec",
           "named_sharding", "constrain", "use_rules", "active_rules",
           "get_rules"]
