"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter/activation axis carries a *logical name*; a rules table maps
logical names to mesh axes.  ``spec`` resolves a tuple of logical names into
a ``PartitionSpec``, validating divisibility against the active mesh so that
a rule that does not divide (e.g. kv_heads=8 over model=16) falls back to
the axis's ``fallback`` entry (or replication) instead of failing at pjit.

Rule sets:
  TP_RULES        -- plain tensor parallelism (heads/ff/experts/vocab over
                     "model", batch over ("pod", "data")): the paper-faithful
                     baseline distribution.
  FSDP_RULES      -- TP + ZeRO-3-style weight sharding: the *param* embed
                     axis additionally shards over ("pod", "data") and is
                     all-gathered per scanned layer.  Used by archs whose
                     params do not fit a chip under plain TP.
  SEQ_RULES       -- TP + sequence parallelism on long-context activations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Immutable mapping logical axis name -> mesh axes (+ fallbacks)."""

    table: Tuple[Tuple[str, MeshAxes], ...]
    fallbacks: Tuple[Tuple[str, MeshAxes], ...] = ()

    def lookup(self, name: str) -> MeshAxes:
        for k, v in self.table:
            if k == name:
                return v
        return None

    def fallback(self, name: str) -> MeshAxes:
        for k, v in self.fallbacks:
            if k == name:
                return v
        return None

    def with_rule(self, name: str, axes: MeshAxes) -> "Rules":
        table = tuple((k, v) for k, v in self.table if k != name)
        return dataclasses.replace(self, table=table + ((name, axes),))


def _axes_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec(logical_axes: Sequence[Optional[str]], rules: Rules,
         mesh: Optional[Mesh] = None,
         dims: Optional[Sequence[int]] = None) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec.

    If ``mesh`` and ``dims`` are given, a mapping is accepted only when the
    dimension divides evenly (pjit argument shardings reject padding); a
    non-dividing dimension falls back (then replicates).  A mesh axis is
    never used twice (first come, first served).
    """
    out = []
    used: set = set()
    for i, name in enumerate(logical_axes):
        cand = None if name is None else rules.lookup(name)
        for attempt in (cand, None if name is None else rules.fallback(name),
                        None):
            if attempt is None:
                chosen = None
                break
            ax = (attempt,) if isinstance(attempt, str) else tuple(attempt)
            if mesh is not None:
                # drop axes the mesh doesn't have (e.g. "pod" on single-pod)
                ax = tuple(a for a in ax if a in mesh.shape)
                if not ax:
                    chosen = None
                    break
            if any(a in used for a in ax):
                continue
            if mesh is not None and dims is not None:
                if dims[i] % _axes_size(mesh, ax) != 0:
                    continue
            chosen = ax[0] if len(ax) == 1 else ax
            break
        if chosen is not None:
            for a in ((chosen,) if isinstance(chosen, str) else chosen):
                used.add(a)
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def named_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   rules: Rules, dims: Optional[Sequence[int]] = None
                   ) -> NamedSharding:
    return NamedSharding(mesh, spec(logical_axes, rules, mesh, dims))


# --------------------------------------------------------------------------
# activation sharding constraints
# --------------------------------------------------------------------------
_ACTIVE: list = []  # stack of (mesh, rules); empty -> constraints are no-ops


class use_rules:
    """Context manager activating (mesh, rules) for ``constrain`` calls."""

    def __init__(self, mesh: Optional[Mesh], rules: Rules):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACTIVE.append(self.pair)
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def active_rules() -> Optional[Tuple[Optional[Mesh], Rules]]:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    if mesh is None:
        return x
    s = spec(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


# --------------------------------------------------------------------------
# canonical rule sets
# --------------------------------------------------------------------------
_COMMON = (
    # activations
    ("batch", ("pod", "data")),
    ("seq", None),
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_kv_heads", "model"),
    ("act_ff", "model"),
    ("act_experts", "model"),
    ("act_vocab", "model"),
    ("act_rnn", "model"),
    ("kv_seq", None),
    # params
    ("embed", None),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("head_dim", None),
    ("ff", "model"),
    ("experts", "model"),
    ("expert_ff", None),
    ("vocab", "model"),
    ("rnn", "model"),
    ("conv", None),
    ("layers", None),
    ("stack", None),
)

TP_RULES = Rules(table=_COMMON,
                 fallbacks=(("act_kv_heads", None), ("kv_seq", "model")))

FSDP_RULES = Rules(
    table=tuple((k, v) for k, v in _COMMON if k != "embed")
    + (("embed", ("pod", "data")),),
    fallbacks=(("act_kv_heads", None), ("kv_seq", "model")),
)

SEQ_RULES = Rules(
    table=tuple((k, v) for k, v in _COMMON if k != "seq")
    + (("seq", "model"),),
    fallbacks=(("act_kv_heads", None), ("kv_seq", "model")),
)


def get_rules(name: str) -> Rules:
    return {"tp": TP_RULES, "fsdp": FSDP_RULES, "seq": SEQ_RULES}[name]
