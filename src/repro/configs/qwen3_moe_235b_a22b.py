"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) MoE 128
experts top-8, per-expert d_ff=1536, vocab=151936 (EP-heavy).
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936,
    ffn="moe", num_experts=128, experts_per_token=8, moe_d_ff=1536,
    rope_theta=1000000.0,
    rules="fsdp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-tiny", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=48, vocab_size=256,
        ffn="moe", num_experts=8, experts_per_token=2, moe_d_ff=48,
        dtype="float32", rules="tp", remat_policy="none",
    )
