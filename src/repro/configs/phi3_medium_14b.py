"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 (RoPE SwiGLU GQA).  40 heads / kv=10 do not divide the 16-way
model axis: the fused projection dims (40*128=5120) still shard evenly, but
per-head activation constraints fall back to replication -- GSPMD resolves
the attention einsums around the sharded projections (see DESIGN.md SS5;
the proper fix, padding to 48 heads, is a documented hillclimb option).
[arXiv:2404.14219; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352,
    rules="tp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="phi3-tiny", family="dense",
        num_layers=2, d_model=80, num_heads=5, num_kv_heads=5,
        d_ff=160, vocab_size=256,
        dtype="float32", rules="tp", remat_policy="none",
    )
