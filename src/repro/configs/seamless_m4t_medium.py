"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  The speech frontend is a
STUB per the assignment: ``input_specs`` provides precomputed frame
embeddings (B, num_frames, d_model).  [arXiv:2308.11596; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    frontend="frames", num_frames=512,
    rules="tp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="seamless-tiny", family="audio",
        num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        frontend="frames", num_frames=16,
        dtype="float32", rules="tp", remat_policy="none",
    )
