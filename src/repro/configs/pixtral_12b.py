"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 (pixtral-ViT + mistral-nemo backbone).  The vision frontend is
a STUB per the assignment: ``input_specs`` provides precomputed patch
embeddings (B, num_patches, d_model) that are projected and prepended to
the token sequence.  [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=160, d_ff=14336, vocab_size=131072,
    rope_theta=1000000.0,
    frontend="patches", num_patches=256,
    rules="tp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="pixtral-tiny", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        frontend="patches", num_patches=8,
        dtype="float32", rules="tp", remat_policy="none",
    )
