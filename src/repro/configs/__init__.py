from .base import (ALL_SHAPES, ARCH_IDS, DECODE_32K, LONG_500K, PREFILL_32K,
                   TRAIN_4K, ModelConfig, ShapeConfig, get_config, get_shape,
                   shapes_for)

__all__ = ["ModelConfig", "ShapeConfig", "get_config", "get_shape",
           "shapes_for", "ARCH_IDS", "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K"]
