"""deepseek-7b [dense]: 30L d_model=4096 32H (MHA, kv=32) d_ff=11008
vocab=102400 (llama-arch).  [arXiv:2401.02954; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    rules="tp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="deepseek-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        dtype="float32", rules="tp", remat_policy="none",
    )
