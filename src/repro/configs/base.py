"""Model / run configuration system.

``ModelConfig`` is a frozen dataclass describing one architecture; the ten
assigned architectures each ship a module ``configs/<id>.py`` exposing
``CONFIG`` (full size) and ``tiny()`` (reduced same-family config for CPU
smoke tests).  ``get_config(name)`` resolves either.

Input shapes (the assignment's four cells) are described by ``ShapeConfig``
and produced by ``shapes_for(arch)`` -- ``long_500k`` is only emitted for
sub-quadratic archs (SSM / hybrid), per the assignment.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "audio", "vlm", "ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    mixer: str = "attention"         # attention | rwkv6 | rglru_hybrid
    ffn: str = "swiglu"              # swiglu | geglu | moe | rwkv_cmix
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    router_aux_coef: float = 0.01
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding window (local attention)
    logit_softcap: float = 0.0
    # --- hybrid (RG-LRU) ---
    pattern: Tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn"), scanned
    tail_layers: Tuple[str, ...] = ()  # layers appended after the scan
    rnn_width: int = 0               # RG-LRU state width (0 -> d_model)
    conv1d_width: int = 4
    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    # --- encoder-decoder ---
    encoder_layers: int = 0          # > 0 -> enc-dec (audio family)
    # --- frontend ---
    frontend: str = "token"          # token | frames | patches
    num_patches: int = 256           # VLM stub: patch embeddings per image
    num_frames: int = 512            # audio stub: source frames
    # --- numerics / distribution ---
    dtype: str = "bfloat16"
    kv_quant: bool = False           # int8 KV cache (per-position scales)
    rules: str = "tp"                # tp | fsdp | seq (sharding rule set)
    remat_policy: str = "full"       # full | dots | none
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the lm-head / embedding shard evenly
        on any mesh axis (hillclimb H4: a 256206-row table replicates, a
        256256-row one shards 16 ways; the tail logits are masked -inf)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True when decode state is O(1)/O(window) in sequence length."""
        return self.mixer in ("rwkv6", "rglru_hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (exact for our model definitions)."""
        from repro.models.params import count_params  # lazy: avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

ARCH_IDS = (
    "dbrx-132b", "qwen3-moe-235b-a22b", "seamless-m4t-medium", "yi-6b",
    "phi3-medium-14b", "deepseek-7b", "qwen2.5-3b", "pixtral-12b",
    "rwkv6-7b", "recurrentgemma-9b",
)


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, tiny: bool = False) -> ModelConfig:
    """Resolve ``--arch`` ids; ``tiny=True`` gives the reduced smoke config."""
    m = _module(name)
    return m.tiny() if tiny else m.CONFIG


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The assignment's shape cells valid for this arch.

    ``long_500k`` needs sub-quadratic attention: emitted only for SSM /
    hybrid archs (rwkv6-7b, recurrentgemma-9b); pure full-attention archs
    skip it (recorded in DESIGN.md SS4 and the roofline table).
    """
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        shapes.append(LONG_500K)
    return tuple(shapes)


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
