"""recurrentgemma-9b [hybrid] (Griffin): 38L d_model=4096 16H (GQA kv=1,
MQA) d_ff=12288, RG-LRU + local attention 1:2, window 2048, vocab=256000.
38 layers = 12 x (rec, rec, attn) scanned super-layers + 2 tail rec layers.
Sub-quadratic (O(window) attention state): runs the long_500k cell.
[arXiv:2402.19427; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    mixer="rglru_hybrid", ffn="geglu",
    pattern=("rec", "rec", "attn"), tail_layers=("rec", "rec"),
    window=2048, rnn_width=4096, conv1d_width=4,
    rules="tp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-tiny", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=256,
        mixer="rglru_hybrid", ffn="geglu",
        pattern=("rec", "rec", "attn"), tail_layers=("rec", "rec"),
        window=16, rnn_width=64, conv1d_width=4,
        dtype="float32", rules="tp", remat_policy="none",
    )
