"""rwkv6-7b [ssm] (Finch): 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 -- data-dependent decay linear recurrence, head_dim=64.
Sub-quadratic: runs the long_500k cell.  [arXiv:2404.05892; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    mixer="rwkv6", ffn="rwkv_cmix", rwkv_head_dim=64,
    rules="tp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-tiny", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        mixer="rwkv6", ffn="rwkv_cmix", rwkv_head_dim=16,
        dtype="float32", rules="tp", remat_policy="none",
    )
