"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) MoE 16 experts top-4,
per-expert d_ff=10752, vocab=100352 (fine-grained MoE).
[hf:databricks/dbrx-base; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    ffn="moe", num_experts=16, experts_per_token=4, moe_d_ff=10752,
    rope_theta=500000.0,
    rules="fsdp", remat_policy="full",
)


def tiny() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-tiny", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        ffn="moe", num_experts=4, experts_per_token=2, moe_d_ff=96,
        dtype="float32", rules="tp", remat_policy="none",
    )
