"""Simulated fabric for the RDMA / PFS data paths.

The container this framework is validated in has a single CPU node and no
fabric, so *timing* is simulated while *data movement is real* (bytes really
land in agent stores).  Every NIC is a shared-bandwidth resource: concurrent
streams divide the link.  Durations are computed analytically (deterministic,
what benchmarks report) and optionally realised as scaled wall-clock sleeps so
that the asynchrony of the agent threads is real.

Simulated seconds are the unit reported by all benchmarks; ``time_scale``
maps them to wall seconds (0 = don't sleep at all, for unit tests).
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class SimClock:
    """Virtual clock: sim_seconds = wall_seconds_elapsed / time_scale ... but
    because sleeps are scaled, sim time advances ~1:1 with the simulation."""

    def __init__(self, time_scale: float = 0.0):
        # time_scale: wall seconds slept per simulated second. 0 => no sleeping.
        self.time_scale = float(time_scale)
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._sim_offset = 0.0  # accumulated virtual time when time_scale == 0

    def now(self) -> float:
        if self.time_scale > 0:
            return (time.monotonic() - self._t0) / self.time_scale
        with self._lock:
            return self._sim_offset

    def sleep(self, sim_seconds: float) -> None:
        if sim_seconds <= 0:
            return
        if self.time_scale > 0:
            time.sleep(sim_seconds * self.time_scale)
        else:
            with self._lock:
                self._sim_offset += sim_seconds


class SimNIC:
    """A bandwidth-shared link (node NIC, or the PFS ingest aggregate).

    Effective rate for a transfer is ``bandwidth / concurrent_streams``
    sampled at start — a deliberately simple fluid model; good enough to
    reproduce the knee behaviour the paper's agent-count adaptivity relies on.
    """

    def __init__(self, name: str, bandwidth: float, latency: float = 0.0,
                 clock: Optional[SimClock] = None):
        self.name = name
        self.bandwidth = float(bandwidth)
        self.latency = float(latency)
        self.clock = clock or SimClock()
        self._lock = threading.Lock()
        self._active = 0
        self._bytes_total = 0
        self._busy_sim_seconds = 0.0
        # optional per-transfer observer ``(link_name, nbytes, sim_s)`` —
        # the telemetry service's per-hop latency/size histograms
        self.on_transfer = None
        # fault injection
        self._slowdown = 1.0
        self._down = False

    # -- fault / straggler injection -------------------------------------
    def set_slowdown(self, factor: float) -> None:
        with self._lock:
            self._slowdown = max(1.0, float(factor))

    def set_down(self, down: bool) -> None:
        with self._lock:
            self._down = bool(down)

    @property
    def active_streams(self) -> int:
        with self._lock:
            return self._active

    def utilization_estimate(self, window_rate: float = 0.0) -> float:
        """Crude utilisation: fraction of link spoken for right now."""
        with self._lock:
            return min(1.0, self._active / 4.0)

    # -- transfer ----------------------------------------------------------
    def transfer_time(self, nbytes: int, concurrent: Optional[int] = None) -> float:
        """Analytic duration for ``nbytes`` with ``concurrent`` streams."""
        with self._lock:
            streams = max(1, self._active if concurrent is None else concurrent)
            slow = self._slowdown
        rate = self.bandwidth / streams
        return self.latency + (nbytes / rate) * slow

    def transfer(self, nbytes: int) -> float:
        """Run one transfer; returns simulated seconds it took."""
        with self._lock:
            if self._down:
                raise ConnectionError(f"NIC {self.name} is down")
            self._active += 1
            streams = self._active
            slow = self._slowdown
        try:
            rate = self.bandwidth / streams
            dur = self.latency + (nbytes / rate) * slow
            self.clock.sleep(dur)
            with self._lock:
                self._bytes_total += nbytes
                self._busy_sim_seconds += dur
            observer = self.on_transfer
            if observer is not None:
                try:
                    observer(self.name, nbytes, dur)
                except Exception:  # noqa: BLE001 - observers must not break us
                    pass
            return dur
        finally:
            with self._lock:
                self._active -= 1

    def stats(self) -> dict:
        with self._lock:
            return {
                "name": self.name,
                "bytes_total": self._bytes_total,
                "busy_sim_seconds": self._busy_sim_seconds,
                "active_streams": self._active,
            }


class MemBus(SimNIC):
    """Node-local memory channel for intra-node peer copies.

    Peer-to-peer redistribution between two agents on the *same* iCheck node
    never touches the NIC: the bytes move at memory bandwidth with no
    per-message latency.  Modelled with the same fluid shared-bandwidth
    semantics as :class:`SimNIC` so concurrent intra-node copies contend for
    the memory system like concurrent transfers contend for a link.
    """

    def __init__(self, name: str, bandwidth: float = 200e9,
                 clock: Optional[SimClock] = None):
        super().__init__(name, bandwidth, latency=0.0, clock=clock)


class FaultInjector:
    """Central switchboard used by tests/benchmarks to break things on cue."""

    def __init__(self):
        self._lock = threading.Lock()
        self._dead_agents: set = set()
        self._dead_nodes: set = set()
        self._straggler_agents: dict = {}   # agent_id -> slowdown factor
        # node_id -> transports (NIC + MemBus) severed when the node dies
        self._transports: dict = {}
        # unordered node pairs with a partial partition between them
        self._partitions: set = set()

    def register_transport(self, node_id: str, *links: "SimNIC") -> None:
        """Attach a node's links so :meth:`kill_node` can sever them.

        Managers register their NIC and MemBus at construction: a dead node
        must drop transport, not just fail liveness checks — otherwise an
        in-flight ``peer_read`` against one of its agents completes instead
        of raising."""
        with self._lock:
            self._transports.setdefault(node_id, []).extend(links)

    def kill_agent(self, agent_id: str) -> None:
        with self._lock:
            self._dead_agents.add(agent_id)

    def revive_agent(self, agent_id: str) -> None:
        with self._lock:
            self._dead_agents.discard(agent_id)

    def kill_node(self, node_id: str) -> None:
        with self._lock:
            self._dead_nodes.add(node_id)
            links = list(self._transports.get(node_id, ()))
        # sever outside the lock: set_down takes each link's own lock
        for link in links:
            link.set_down(True)

    # -- partial partitions ----------------------------------------------
    def partition_nodes(self, node_a: str, node_b: str) -> None:
        """Block peer traffic between two (live) nodes in both directions."""
        with self._lock:
            self._partitions.add(frozenset((node_a, node_b)))

    def heal_partition(self, node_a: str, node_b: str) -> None:
        with self._lock:
            self._partitions.discard(frozenset((node_a, node_b)))

    def partitioned(self, node_a: str, node_b: str) -> bool:
        if node_a == node_b:
            return False
        with self._lock:
            return frozenset((node_a, node_b)) in self._partitions

    def make_straggler(self, agent_id: str, slowdown: float) -> None:
        with self._lock:
            self._straggler_agents[agent_id] = float(slowdown)

    def clear_straggler(self, agent_id: str) -> None:
        with self._lock:
            self._straggler_agents.pop(agent_id, None)

    def agent_dead(self, agent_id: str) -> bool:
        with self._lock:
            return agent_id in self._dead_agents

    def node_dead(self, node_id: str) -> bool:
        with self._lock:
            return node_id in self._dead_nodes

    def agent_slowdown(self, agent_id: str) -> float:
        with self._lock:
            return self._straggler_agents.get(agent_id, 1.0)


class EWMA:
    """Exponentially-weighted moving average — the managers' predictor for
    node usage parameters (paper §II: "monitoring and predicting the node
    usage parameters (e.g., memory usage, bandwidth usage)")."""

    def __init__(self, alpha: float = 0.3, init: float = 0.0):
        self.alpha = float(alpha)
        self.value = float(init)
        self._seen = False

    def update(self, x: float) -> float:
        if not self._seen:
            self.value = float(x)
            self._seen = True
        else:
            self.value = self.alpha * float(x) + (1 - self.alpha) * self.value
        return self.value

    def predict(self) -> float:
        return self.value
