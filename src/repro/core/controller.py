"""The iCheck Controller — a thin coordinator over the checkpoint services.

"The controller has a global view and performs the agent and node selection
for connected applications based on the iCheck agent scheduling policies ...
The controller may also request the resource manager for additional resources
based on resource availability.  In addition, the controller will also
orchestrate the writing of the checkpoint data into PFS by minimizing the
effect on running applications." (§II)

The behaviour itself lives in focused subsystems (see ARCHITECTURE.md):

  * :class:`~.services.placement.PlacementService` — policy-driven agent
    placement + ``icheck_probe_agents`` adaptivity (paper §II steps 1-6)
  * :class:`~.services.catalog.CheckpointCatalog` — checkpoint lifecycle
    (PENDING → IN_L1 → DRAINING → IN_L2) and the multi-level read path
  * :class:`~.services.drain.DrainOrchestrator` — bounded-concurrency PFS
    drains + L1 GC (interference control, §II)
  * :class:`~.services.health.HealthMonitor` — heartbeats, re-replication,
    straggler advice, RM node retake/migration (§III-A items 2-3)
  * :class:`~.services.resize.ResizePlanner` — resize forewarning →
    pre-staged redistribution plans (§III-A item 4)
  * :class:`~.services.lifecycle.StorageLifecycleService` — watermark-driven
    L1 demotion, background L2→L3 trickle into the remote object store,
    keep-last-K retention/GC with pinning (beyond paper)

Services communicate through the :class:`~.events.EventBus`; the legacy
``Controller.events`` audit list is an :class:`~.events.AuditLog` subscriber
and stays byte-compatible with the pre-refactor format.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Tuple

from . import events as E
from . import plan as planlib
from .agent import Agent
from .events import AuditLog, EventBus, NODE_ADDED, NODE_REQUEST_DENIED, \
    APP_REGISTERED, REDISTRIBUTION_FALLBACK as E_REDISTRIBUTION_FALLBACK
from .manager import Manager
from .policies import NodeView, SchedulingPolicy
from .rm import ResourceManager
from .services import (CheckpointCatalog, DrainOrchestrator, EpochFence,
                       HealthMonitor, IntervalController, MetadataJournal,
                       PlacementService, ResizePlanner,
                       StorageLifecycleService, TelemetryService)
from .services.journal import meta_from_ckpt_doc
from .simnet import FaultInjector, SimClock
from .tiers import PFSTier, RemoteObjectTier, region_doc, region_from_doc
from ..obs import FlightRecorder, TraceCollector
from .types import (AppId, AppRecord, AppStatus, CheckpointMeta, CkptId,
                    CkptStatus, ICheckError, NodeSpec, RegionMeta, ShardInfo)


class Controller:
    def __init__(self, rm: ResourceManager, pfs: PFSTier,
                 policy: "str | SchedulingPolicy" = "adaptive",
                 initial_nodes: int = 1, clock: Optional[SimClock] = None,
                 fault: Optional[FaultInjector] = None,
                 keep_l1: int = 2, max_concurrent_drains: int = 2,
                 heartbeat_interval_s: float = 0.05,
                 spill_bytes: int = 0, adaptive_interval: bool = True,
                 default_mtbf_s: float = 3600.0,
                 l3: Optional[RemoteObjectTier] = None,
                 watermark_high: float = 0.85, watermark_low: float = 0.60,
                 keep_l2: int = 0, keep_l3: int = 0,
                 delta_keyframe_every: int = 8,
                 trace: bool = False, trace_path: Optional[str] = None,
                 obs_dir: Optional[str] = None, journal: bool = True):
        self.rm = rm
        self.pfs = pfs
        self.l3 = l3
        self.clock = clock or SimClock()
        self.fault = fault or FaultInjector()
        self.keep_l1 = keep_l1
        self.spill_bytes = int(spill_bytes)
        self._managers: Dict[str, Manager] = {}
        self._apps: Dict[AppId, AppRecord] = {}
        self._regions: Dict[AppId, Dict[str, RegionMeta]] = {}
        self._lock = threading.RLock()

        # control plane: event bus + audit log (legacy ``events`` list)
        self.bus = EventBus(self.clock)
        self.audit = AuditLog()
        self.bus.subscribe(self.audit)

        # observability: tracer (no-op unless trace/trace_path asked for
        # it) + always-on bounded flight recorder; publish stamps the
        # current trace context on every event
        self.trace_path = trace_path
        self.tracer = TraceCollector(clock=self.clock,
                                     enabled=bool(trace) or
                                     trace_path is not None)
        self.bus.tracer = self.tracer
        self.flight = FlightRecorder(clock=self.clock, out_dir=obs_dir)
        self.bus.subscribe(self.flight.on_event)
        self.tracer.add_listener(self.flight.on_span)
        self.bus.subscribe(self._on_fallback,
                           events=(E_REDISTRIBUTION_FALLBACK,))

        # crash-consistent control plane: write-ahead metadata journal on
        # the PFS (a non-``ckpt_*`` sibling, invisible to shard walks) +
        # the epoch fence that recovery bumps to seal out zombie work
        self.journal = MetadataJournal(os.path.join(pfs.root, "_journal"),
                                       clock=self.clock) if journal else None
        self.fence = EpochFence()
        rm.fence = self.fence
        if l3 is not None:
            l3.bus = self.bus      # retry_exhausted telemetry from L3 ops
        # demotions/promotions and EC stripe placement are journaled as
        # audit records (recovery probes the live tiers rather than trust
        # a replayed placement, but the history is in the log)
        if self.journal is not None:
            self.bus.subscribe(self._journal_audit_event,
                               events=(E.SHARD_DEMOTED, E.SHARD_PROMOTED,
                                       E.EC_STRIPE_COMMITTED))

        # service core
        self.placement = PlacementService(self, policy)
        self.catalog = CheckpointCatalog(
            self, delta_keyframe_every=delta_keyframe_every)
        self.drains = DrainOrchestrator(self, max_concurrent=max_concurrent_drains,
                                        keep_l1=keep_l1)
        self.health = HealthMonitor(self, heartbeat_interval_s)
        self.resize = ResizePlanner(self)
        # adaptive loop: telemetry must subscribe before the interval
        # controller so a COMMIT_DONE updates the estimates first and the
        # solver then reads the fresh values (bus fans out in order)
        self.telemetry = TelemetryService(self, default_mtbf_s=default_mtbf_s)
        # shared-tier links feed the same per-hop histograms as node NICs:
        # a drain's PFS ingest or a cold L3 read is a hop like any other
        self.pfs.ingest.on_transfer = self.telemetry.observe_transfer
        if l3 is not None:
            l3.link.on_transfer = self.telemetry.observe_transfer
        self.intervals = IntervalController(self, self.telemetry) \
            if adaptive_interval else None
        # storage lifecycle: watermark demotion acts whenever a node has a
        # lower tier to demote into; the L2→L3 trickle and retention act
        # when an L3 tier is configured
        self.lifecycle = StorageLifecycleService(
            self, l3=l3, watermark_high=watermark_high,
            watermark_low=watermark_low, keep_l2=keep_l2, keep_l3=keep_l3)

        # wire the RM plugin callbacks (§III-A)
        rm.on_retake = self.health.on_rm_retake
        rm.on_migrate = self.health.on_rm_migrate
        rm.on_app_info = self.resize.on_app_info

        for _ in range(initial_nodes):
            spec = rm.request_icheck_node(epoch=self.fence.current)
            if spec is None:
                raise ICheckError("RM has no free nodes for iCheck bootstrap")
            self._add_node(spec)

        self.drains.start()
        self.health.start()

    def _journal_audit_event(self, ev) -> None:
        """Journal tier moves and EC stripe commits (audit records)."""
        p = ev.payload
        if ev.name == E.EC_STRIPE_COMMITTED:
            self.journal.append("ec_stripe", app=p.get("app"),
                                ckpt=p.get("ckpt"), k=p.get("k"),
                                m=p.get("m"), stripes=p.get("stripes"))
        else:
            self.journal.append("tier_move", move=ev.name,
                                key=p.get("key"), src=p.get("src"),
                                dst=p.get("dst"))

    def _on_fallback(self, ev) -> None:
        """A redistribution fell back to the client funnel: something broke
        mid-window — ship the timeline."""
        p = ev.payload
        self.flight.dump(
            f"fallback_{p.get('app', '?')}_{p.get('region', '?')}",
            extra={"event": ev.as_record()})

    # ------------------------------------------------- legacy-compat surface
    @property
    def events(self) -> List[dict]:
        """Audit log (byte-compatible with the pre-service-core format)."""
        return self.audit.records

    @property
    def policy(self) -> SchedulingPolicy:
        return self.placement.policy

    @policy.setter
    def policy(self, p: SchedulingPolicy) -> None:
        self.placement.policy = p

    @property
    def _plans(self):
        return self.resize.plans

    # ================================================================= nodes
    def _add_node(self, spec: NodeSpec) -> Manager:
        mgr = Manager(spec, clock=self.clock, fault=self.fault, bus=self.bus,
                      spill_bytes=self.spill_bytes, fence=self.fence)
        # per-hop transfer observations feed the cluster-level NIC/MemBus
        # latency histograms (peer-hop p99s in snapshot()/prometheus())
        mgr.nic.on_transfer = self.telemetry.observe_transfer
        mgr.membus.on_transfer = self.telemetry.observe_transfer
        with self._lock:
            self._managers[spec.node_id] = mgr
        self.bus.publish(NODE_ADDED, node=spec.node_id)
        return mgr

    def managers(self) -> List[Manager]:
        with self._lock:
            return list(self._managers.values())

    def node_views(self) -> List[NodeView]:
        return [NodeView.of(m) for m in self.managers() if m.alive()]

    def request_more_memory(self) -> bool:
        """Ask the RM for one more iCheck node (paper §III-A interaction 1)."""
        spec = self.rm.request_icheck_node(epoch=self.fence.current)
        if spec is None:
            self.bus.publish(NODE_REQUEST_DENIED)
            return False
        self._add_node(spec)
        return True

    def total_free_memory(self) -> int:
        return sum(m.store.free_bytes for m in self.managers() if m.alive())

    # ================================================================== apps
    def register_app(self, app_id: AppId, ranks: int,
                     ckpt_bytes_estimate: int = 0, ckpt_interval_s: float = 60.0,
                     replication: int = 1, ec=None) -> List[Agent]:
        """Paper §II steps 1-6: register, place agents, hand back handles.

        ``ec=(k, m)`` opts the app into erasure-coded L1 durability: each
        committed shard is scattered as k data + m parity fragments instead
        of ``replication`` whole copies."""
        with self._lock:
            if app_id in self._apps:
                # reconnect (restart path): reuse the existing record
                app = self._apps[app_id]
                app.ranks = ranks
                app.status = AppStatus.CONNECTED
                return self.agents_for(app_id)
            app = AppRecord(app_id=app_id, ranks=ranks,
                            ckpt_bytes_estimate=ckpt_bytes_estimate,
                            ckpt_interval_s=ckpt_interval_s,
                            replication=replication,
                            ec=tuple(ec) if ec else None)
            if self.journal is not None:
                self.journal.append("app", app=app_id, ranks=ranks,
                                    replication=replication,
                                    ec=list(app.ec) if app.ec else None,
                                    interval_s=ckpt_interval_s,
                                    bytes_estimate=ckpt_bytes_estimate)
            self._apps[app_id] = app
            self._regions[app_id] = {}
            self.catalog.open_app(app_id)
        self.rm.register_app(app_id, ranks, epoch=self.fence.current)
        self.placement.ensure_memory(app)
        agents = self.placement.place_app(app)
        with self._lock:
            app.agents = [a.agent_id for a in agents]
            app.status = AppStatus.CONNECTED
        if app.ec is not None:
            # scatter targets must span failure domains, or a single node
            # death takes more than m fragments of every stripe with it
            agents = self.placement.ensure_failure_domains(
                app, sum(app.ec))
        self.bus.publish(APP_REGISTERED, app=app_id,
                         agents=[a.agent_id for a in agents])
        return agents

    def agents_for(self, app_id: AppId) -> List[Agent]:
        with self._lock:
            ids = list(self._apps[app_id].agents)
        out = []
        for aid in ids:
            node_id = aid.split("/")[0]
            mgr = self._managers.get(node_id)
            if mgr is None:
                continue
            a = mgr.agent(aid)
            if a is not None and a.alive():
                out.append(a)
        return out

    def app(self, app_id: AppId) -> AppRecord:
        with self._lock:
            return self._apps[app_id]

    def register_region(self, app_id: AppId, region: RegionMeta) -> None:
        with self._lock:
            old = self._regions[app_id].get(region.name)
            if self.journal is not None:
                self.journal.append("region", app=app_id, name=region.name,
                                    doc=region_doc(region))
            self._regions[app_id][region.name] = region
        if old is not None and old.partition != region.partition:
            # resize/redistribution (grow *or* shrink, or new mesh boxes):
            # previous codes no longer line up part-for-part — mandatory
            # chain reset so the next commit emits a keyframe
            self.catalog.reset_delta_chains(app_id=app_id, region=region.name,
                                            reason="resize")
            # pre-staged plans/programs were computed against the old
            # layout: a later resize to a previously-planned part count
            # must re-plan, never reuse the stale cache
            self.resize.invalidate(app_id, region.name)

    def regions_of(self, app_id: AppId) -> Dict[str, RegionMeta]:
        with self._lock:
            return dict(self._regions.get(app_id, {}))

    def notify_finished(self, app_id: AppId) -> None:
        with self._lock:
            app = self._apps.get(app_id)
            if app:
                app.status = AppStatus.FINISHED
        # release the app's delta-chain state (host codes + device-resident
        # codes_dev arrays) — long-lived controllers see many apps come and
        # go, and a finished app will keyframe anyway if it reconnects
        self.catalog.reset_delta_chains(app_id=app_id, reason="app_finished")
        # likewise the pre-staged resize plans/transfer programs
        self.resize.invalidate(app_id)

    # =================================================== service delegation
    # checkpoints (catalog)
    def new_checkpoint(self, app_id: AppId, step: int,
                       regions: Dict[str, RegionMeta],
                       userdata: bytes = b"") -> CheckpointMeta:
        return self.catalog.new_checkpoint(app_id, step, regions, userdata)

    def record_shard(self, meta: CheckpointMeta, info: ShardInfo) -> None:
        self.catalog.record_shard(meta, info)

    def finalize_checkpoint(self, meta: CheckpointMeta, drain: bool = True) -> None:
        self.catalog.finalize(meta, drain=drain)

    def latest_restartable(self, app_id: AppId) -> Optional[Tuple[CheckpointMeta, str]]:
        return self.catalog.latest_restartable(app_id)

    def fetch_shard(self, app_id: AppId, ckpt_id: CkptId, region: str,
                    part: int) -> bytes:
        return self.catalog.fetch_shard(app_id, ckpt_id, region, part)

    # q8-delta chains (catalog-owned previous-codes state)
    def delta_chain(self, app_id: AppId, region: str, num_parts: int):
        return self.catalog.delta_chain(app_id, region, num_parts)

    def advance_delta_chain(self, app_id: AppId, ckpt_id: CkptId, region: str,
                            states, frame: str):
        return self.catalog.advance_chain(app_id, ckpt_id, region, states,
                                          frame)

    def reset_delta_chains(self, app_id: Optional[AppId] = None,
                           region: Optional[str] = None,
                           reason: str = "") -> int:
        return self.catalog.reset_delta_chains(app_id, region, reason)

    def set_delta_keyframe_every(self, app_id: AppId,
                                 k: Optional[int]) -> None:
        self.catalog.set_keyframe_every(app_id, k)

    # drains
    def wait_for_drains(self, timeout: float = 30.0) -> dict:
        """Block until the drain queue empties.  Always returns a report —
        a timeout is ``{"ok": False, ...}`` with the pending counts (and a
        published ``wait_timeout`` event), never a silent return with work
        still queued."""
        try:
            self.drains.wait_idle(timeout)
        except TimeoutError:
            st = self.drains.stats()
            report = {"ok": False, "timed_out": True, "what": "drains",
                      "pending": st["inflight"], "queued": st["queued"],
                      "active": st["active"], "completed": st["completed"]}
            self.bus.publish(E.WAIT_TIMEOUT, what="drains",
                             timeout_s=timeout, pending=report["pending"],
                             queued=report["queued"], active=report["active"])
            return report
        st = self.drains.stats()
        return {"ok": True, "timed_out": False, "what": "drains",
                "pending": 0, "queued": 0, "active": 0,
                "completed": st["completed"]}

    # storage lifecycle
    def wait_for_uploads(self, timeout: float = 30.0) -> dict:
        """Block until the background L2→L3 trickle (and drains) settle.
        Same report contract as :meth:`wait_for_drains`."""
        try:
            self.lifecycle.wait_uploads(timeout)
        except TimeoutError:
            st = self.drains.stats()
            pending = st["background_inflight"] + st["inflight"]
            report = {"ok": False, "timed_out": True, "what": "uploads",
                      "pending": pending,
                      "background_inflight": st["background_inflight"],
                      "drain_inflight": st["inflight"],
                      "completed": st["background_completed"]}
            self.bus.publish(E.WAIT_TIMEOUT, what="uploads",
                             timeout_s=timeout, pending=pending)
            return report
        st = self.drains.stats()
        return {"ok": True, "timed_out": False, "what": "uploads",
                "pending": 0, "completed": st["background_completed"]}

    def pin_checkpoint(self, app_id: AppId, ckpt_id: CkptId,
                       pinned: bool = True) -> bool:
        """Exempt one checkpoint from retention/GC on every tier."""
        return self.lifecycle.pin(app_id, ckpt_id, pinned)

    # placement / adaptivity
    def handle_capacity_pressure(self, app_id: AppId) -> List[Agent]:
        return self.placement.handle_capacity_pressure(app_id)

    def probe_agents(self, app_id: AppId,
                     last_commit_sim_s: Optional[float] = None) -> List[Agent]:
        return self.placement.probe(app_id, last_commit_sim_s)

    # health / straggler advice
    def transfer_deadline(self, nbytes: int, agent: Agent,
                          factor: float = 4.0, slack: float = 1e-3) -> float:
        return self.health.transfer_deadline(nbytes, agent, factor, slack)

    # redistribution planning / peer execution
    def plan_for_resize(self, app_id: AppId, region_name: str,
                        new_parts: int) -> List[planlib.Move]:
        return self.resize.plan_for_resize(app_id, region_name, new_parts)

    def transfer_programs(self, app_id: AppId, region_name: str,
                          new_parts: int):
        """Pre-staged per-destination transfer programs (None = layout the
        peer path cannot express; use the client funnel)."""
        return self.resize.transfer_programs(app_id, region_name, new_parts)

    def execute_redistribution(self, app_id: AppId, region: RegionMeta,
                               ckpt_id: CkptId, programs):
        """Run transfer programs agent→agent; see
        :meth:`PeerRedistributionEngine.execute`."""
        return self.resize.engine.execute(app_id, region, ckpt_id, programs)

    def release_redistribution(self, results) -> None:
        self.resize.engine.release(results)

    def begin_overlap_redistribution(self, app_id: AppId, region: RegionMeta,
                                     ckpt_id: CkptId, programs):
        """Open a zero-stall resize window: stream the base checkpoint in the
        background while the app keeps stepping; see
        :meth:`PeerRedistributionEngine.begin_overlap`."""
        return self.resize.engine.begin_overlap(app_id, region, ckpt_id,
                                                programs)

    def cutover_redistribution(self, window):
        """Land an overlap window: replay the tail deltas (or re-hydrate)
        and return ``(results, stats, patches)``."""
        return self.resize.engine.cutover(window)

    def abort_overlap_redistribution(self, window) -> None:
        self.resize.engine.abort(window)

    # ================================== crash-consistent control plane
    def maybe_compact_journal(self) -> None:
        """Publish a compacted snapshot once enough WAL records accumulated
        since the last one, keeping replay O(live state)."""
        j = self.journal
        if j is None or not j.compaction_due():
            return
        with self._lock:
            j.write_snapshot(self._snapshot_doc())

    def _snapshot_doc(self) -> dict:
        """Serialize the full control-plane state (call under ``_lock``)."""
        doc: dict = {"epoch": self.fence.current, "apps": {}, "chains": {},
                     "holds": {}}
        with self._lock:
            for app_id, app in self._apps.items():
                doc["apps"][app_id] = {
                    "ranks": app.ranks,
                    "replication": app.replication,
                    "ec": list(app.ec) if app.ec else None,
                    "interval_s": app.ckpt_interval_s,
                    "bytes_estimate": app.ckpt_bytes_estimate,
                    "next_ckpt": max(app.checkpoints, default=-1) + 1,
                    "regions": {n: region_doc(r) for n, r
                                in self._regions.get(app_id, {}).items()},
                    "ckpts": {str(cid): MetadataJournal.ckpt_doc(m)
                              for cid, m in app.checkpoints.items()},
                }
        with self.catalog._chain_lock:
            for (app_id, region), rc in self.catalog._chains.items():
                doc["chains"][f"{app_id}\x00{region}"] = list(rc.chain)
            for (app_id, region), n in self.catalog._holds.items():
                doc["holds"][f"{app_id}\x00{region}"] = int(n)
        return doc

    def crash(self) -> None:
        """Simulate controller process death: every piece of in-memory
        control-plane state vanishes — app records, regions, catalog id
        sequences, delta chains, holds, pre-staged resize plans — with no
        events and no journaling (a crash doesn't get to say goodbye).
        Durable bytes in L1/L2/L3 and the PFS-backed journal survive, and
        agents keep running with whatever they hold."""
        with self._lock:
            self._apps.clear()
            self._regions.clear()
        self.catalog._seq.clear()
        with self.catalog._chain_lock:
            self.catalog._chains.clear()
            self.catalog._holds.clear()
        self.resize.plans.clear()
        self.lifecycle.reset_inflight()

    def recover(self) -> dict:
        """Warm recovery: replay the journal (snapshot + WAL tail) into a
        fresh catalog, bump the epoch fence, then reconcile the replayed
        view against what agents/PFS/L3 actually still hold — downgrading
        any checkpoint whose claimed tier no longer has it and conservatively
        resetting every delta chain or hold open at crash time.  Returns a
        recovery report."""
        j = self.journal
        if j is None:
            raise ICheckError("recovery requires a metadata journal")
        t0 = self.clock.now()
        state = j.replay_state()
        # fence first: queued pre-crash work must already be stale while we
        # rebuild, and the new epoch is the first post-recovery WAL record
        new_epoch = self.fence.bump(at_least=state.epoch + 1)
        j.append("epoch", epoch=new_epoch)

        downgraded: List[dict] = []
        resubmitted = 0
        with self._lock:
            for app_id, doc in state.apps.items():
                app = AppRecord(
                    app_id=app_id, ranks=int(doc.get("ranks", 0)),
                    ckpt_bytes_estimate=int(doc.get("bytes_estimate", 0)),
                    ckpt_interval_s=float(doc.get("interval_s", 60.0)),
                    replication=int(doc.get("replication", 1)),
                    ec=tuple(doc["ec"]) if doc.get("ec") else None)
                self._apps[app_id] = app
                self._regions[app_id] = {
                    name: region_from_doc(name, r)
                    for name, r in doc.get("regions", {}).items()}
                self.catalog.set_seq(app_id, int(doc.get("next_ckpt", 0)))
                for ck in doc.get("ckpts", {}).values():
                    meta = meta_from_ckpt_doc(app_id, ck)
                    app.checkpoints[meta.ckpt_id] = meta
                # app→agent assignment is not journaled (it changes with
                # every placement decision): rebuild it from live managers
                agents: List[str] = []
                for mgr in self.managers():
                    agents.extend(mgr.agent_ids_for(app_id))
                app.agents = agents

        # reconciliation: probe live tiers for every non-terminal
        # checkpoint; the journal says what *should* exist, the probes say
        # what does — believe the probes, downgrade the rest
        for app_id in list(state.apps):
            app = self._apps[app_id]
            for meta in sorted(app.checkpoints.values(),
                               key=lambda m: m.ckpt_id):
                before = meta.status
                if before in (CkptStatus.EXPIRED, CkptStatus.FAILED):
                    continue
                actual = self._reconcile_one(meta)
                if actual is CkptStatus.IN_L1:
                    # L1-only (the drain was cut short): kick it again
                    self.drains.submit(meta)
                    resubmitted += 1
                if actual is not before:
                    downgraded.append({"app": app_id, "ckpt": meta.ckpt_id,
                                       "from": before.value,
                                       "to": actual.value})

        # any chain or hold open at crash time is unrecoverable state (the
        # per-part previous-codes handles died with the process): reset so
        # the next commit keyframes, and zero the journaled hold counts
        for (app_id, region), chain in state.open_chains.items():
            j.append("chain_reset", app=app_id, region=region,
                     reason="controller_recovered")
            self.bus.publish(E.DELTA_CHAIN_RESET, app=app_id, region=region,
                             reason="controller_recovered",
                             chain_len=len(chain))
        for (app_id, region), n in state.holds.items():
            for _ in range(int(n)):
                j.append("chain_release", app=app_id, region=region)

        # the trickle dedup set died with the process; recovered IN_L2
        # checkpoints re-enter the (epoch-fenced) background lane
        self.lifecycle.reset_inflight()
        for app_id in list(state.apps):
            for meta in self._apps[app_id].checkpoints.values():
                if meta.status is CkptStatus.IN_L2 and \
                        self.lifecycle.trickle_to_l3:
                    self.lifecycle.schedule_upload(app_id, meta.ckpt_id)

        # collapse the replayed history into a fresh snapshot so the next
        # recovery replays O(live state), not this one's tail again
        with self._lock:
            j.write_snapshot(self._snapshot_doc())

        report = {
            "epoch": new_epoch,
            "duration_s": max(self.clock.now() - t0, 0.0),
            "replay": state.stats,
            "truth": j.truth(),
            "apps": {app_id: {
                "max_known": max(self._apps[app_id].checkpoints, default=-1),
                "checkpoints": len(self._apps[app_id].checkpoints)}
                for app_id in state.apps},
            "chains_reset": len(state.open_chains),
            "downgraded": downgraded,
            "drains_resubmitted": resubmitted,
        }
        self.bus.publish(E.CONTROLLER_RECOVERED, epoch=new_epoch,
                         apps=len(state.apps),
                         downgraded=len(downgraded),
                         chains_reset=len(state.open_chains),
                         duration_s=report["duration_s"])
        return report

    def _reconcile_one(self, meta: CheckpointMeta) -> CkptStatus:
        """Probe where one recovered checkpoint actually lives and settle
        its status there (WAL-first via ``set_status``).  PENDING at crash
        time means the commit never acked — its transfers died with the
        submitting client call, so it can only be failed."""
        cat, pfs, l3 = self.catalog, self.pfs, self.l3
        if meta.status is CkptStatus.PENDING:
            self.catalog.set_status(meta, CkptStatus.FAILED)
            return CkptStatus.FAILED
        if pfs.checkpoint_complete(meta):
            pfs.write_manifest(meta)        # a crash mid-drain may have
            actual = CkptStatus.IN_L2       # landed bytes but no manifest
        elif l3 is not None and l3.checkpoint_complete(meta):
            actual = CkptStatus.IN_L3
        elif cat.l1_complete(meta):
            actual = CkptStatus.IN_L1
        else:
            actual = CkptStatus.FAILED
        self.catalog.set_status(meta, actual)
        return actual

    # ================================================================== misc
    def close(self) -> None:
        if self.trace_path is not None and self.tracer.enabled:
            try:
                self.tracer.write_chrome_trace(self.trace_path)
            except OSError:
                pass
        self.lifecycle.close()
        self.catalog.close()
        self.drains.close()
        self.health.close()
        if self.intervals is not None:
            self.intervals.close()
        self.telemetry.close()
        if self.journal is not None:
            self.journal.close()
        for mgr in self.managers():
            mgr.close()
