"""The iCheck Controller.

"The controller has a global view and performs the agent and node selection
for connected applications based on the iCheck agent scheduling policies ...
The controller may also request the resource manager for additional resources
based on resource availability.  In addition, the controller will also
orchestrate the writing of the checkpoint data into PFS by minimizing the
effect on running applications." (§II)

Implements:
  * application registration and policy-driven agent placement (§II steps 1-6)
  * checkpoint lifecycle: PENDING → IN_L1 → DRAINING → IN_L2, with L1 GC
  * orchestrated PFS drains (bounded concurrency = interference control)
  * agent-count adaptivity (``icheck_probe_agents`` handling)
  * node grant/retake/migrate against the malleable RM (§III-A)
  * failure detection (heartbeats) + shard re-replication, straggler advice
  * resize forewarning → pre-staged redistribution plans (§III-A item 4)
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Dict, List, Optional, Tuple

from . import plan as planlib
from .agent import Agent
from .manager import Manager
from .policies import NodeView, SchedulingPolicy, get_policy
from .rm import ResourceManager
from .simnet import FaultInjector, SimClock
from .store import PFSStore
from .types import (AppId, AppRecord, AppStatus, CheckpointMeta, CkptId,
                    CkptStatus, ICheckError, NodeSpec, PartitionDesc,
                    PartitionScheme, RegionMeta, ShardInfo, ShardKey)


class Controller:
    def __init__(self, rm: ResourceManager, pfs: PFSStore,
                 policy: str | SchedulingPolicy = "adaptive",
                 initial_nodes: int = 1, clock: Optional[SimClock] = None,
                 fault: Optional[FaultInjector] = None,
                 keep_l1: int = 2, max_concurrent_drains: int = 2,
                 heartbeat_interval_s: float = 0.05):
        self.rm = rm
        self.pfs = pfs
        self.clock = clock or SimClock()
        self.fault = fault or FaultInjector()
        self.policy = get_policy(policy) if isinstance(policy, str) else policy
        self.keep_l1 = keep_l1
        self._managers: Dict[str, Manager] = {}
        self._apps: Dict[AppId, AppRecord] = {}
        self._regions: Dict[AppId, Dict[str, RegionMeta]] = {}
        self._plans: Dict[Tuple[AppId, str, int], List[planlib.Move]] = {}
        self._lock = threading.RLock()
        self._ckpt_seq: Dict[AppId, itertools.count] = {}
        # flush orchestration
        self._drain_q: "queue.Queue" = queue.Queue()
        self._drain_sem = threading.Semaphore(max_concurrent_drains)
        self._stop = threading.Event()
        self._flusher = threading.Thread(target=self._flush_loop, daemon=True,
                                         name="icheck-flusher")
        self._monitor = threading.Thread(target=self._monitor_loop, daemon=True,
                                         name="icheck-monitor")
        self._hb_interval = heartbeat_interval_s
        self.events: List[dict] = []          # audit log for tests/benchmarks

        # wire the RM plugin callbacks (§III-A)
        rm.on_retake = self._on_rm_retake
        rm.on_migrate = self._on_rm_migrate
        rm.on_app_info = self._on_rm_app_info

        for _ in range(initial_nodes):
            spec = rm.request_icheck_node()
            if spec is None:
                raise ICheckError("RM has no free nodes for iCheck bootstrap")
            self._add_node(spec)

        self._flusher.start()
        self._monitor.start()

    # ================================================================= nodes
    def _add_node(self, spec: NodeSpec) -> Manager:
        mgr = Manager(spec, clock=self.clock, fault=self.fault)
        with self._lock:
            self._managers[spec.node_id] = mgr
        self._log("node_added", node=spec.node_id)
        return mgr

    def managers(self) -> List[Manager]:
        with self._lock:
            return list(self._managers.values())

    def node_views(self) -> List[NodeView]:
        return [NodeView.of(m) for m in self.managers() if m.alive()]

    def request_more_memory(self) -> bool:
        """Ask the RM for one more iCheck node (paper §III-A interaction 1)."""
        spec = self.rm.request_icheck_node()
        if spec is None:
            self._log("node_request_denied")
            return False
        self._add_node(spec)
        return True

    def total_free_memory(self) -> int:
        return sum(m.store.free_bytes for m in self.managers() if m.alive())

    # ================================================================== apps
    def register_app(self, app_id: AppId, ranks: int,
                     ckpt_bytes_estimate: int = 0, ckpt_interval_s: float = 60.0,
                     replication: int = 1) -> List[Agent]:
        """Paper §II steps 1-6: register, place agents, hand back handles."""
        with self._lock:
            if app_id in self._apps:
                # reconnect (restart path): reuse the existing record
                app = self._apps[app_id]
                app.ranks = ranks
                app.status = AppStatus.CONNECTED
                return self.agents_for(app_id)
            app = AppRecord(app_id=app_id, ranks=ranks,
                            ckpt_bytes_estimate=ckpt_bytes_estimate,
                            ckpt_interval_s=ckpt_interval_s,
                            replication=replication)
            self._apps[app_id] = app
            self._regions[app_id] = {}
            self._ckpt_seq[app_id] = itertools.count()
        self.rm.register_app(app_id, ranks)
        self._ensure_memory(app)
        agents = self._place_agents(app)
        with self._lock:
            app.agents = [a.agent_id for a in agents]
            app.status = AppStatus.CONNECTED
        self._log("app_registered", app=app_id, agents=[a.agent_id for a in agents])
        return agents

    def _ensure_memory(self, app: AppRecord) -> None:
        need = app.ckpt_bytes_estimate * app.replication * max(1, self.keep_l1)
        guard = 0
        while self.total_free_memory() < need and guard < 16:
            if not self.request_more_memory():
                break
            guard += 1

    def _place_agents(self, app: AppRecord) -> List[Agent]:
        placement = self.policy.place(self.node_views(), app)
        agents: List[Agent] = []
        for node_id, count in placement:
            mgr = self._managers[node_id]
            for _ in range(count):
                agents.append(mgr.launch_agent(app.app_id))
        return agents

    def handle_capacity_pressure(self, app_id: AppId) -> List[Agent]:
        """A commit hit a full node (paper SSIII-A: "when iCheck runs out of
        memory in a node, the controller can request more memory and get
        additional nodes from RM").  Grow by one node if the RM has any;
        either way, give the app an agent on the freest node it doesn't
        already use, and return the refreshed agent set."""
        self.request_more_memory()
        with self._lock:
            have = set(self._apps[app_id].agents)
        used_nodes = {aid.split("/")[0] for aid in have}
        views = sorted(self.node_views(), key=lambda nv: -nv.free_memory)
        for prefer_new in (True, False):
            for nv in views:
                if prefer_new and nv.node_id in used_nodes:
                    continue
                mgr = self._managers[nv.node_id]
                if len(mgr.agents()) < mgr.spec.max_agents:
                    agent = mgr.launch_agent(app_id)
                    with self._lock:
                        self._apps[app_id].agents.append(agent.agent_id)
                    self._log("capacity_grow", app=app_id,
                              node=nv.node_id, agent=agent.agent_id)
                    return self.agents_for(app_id)
        return self.agents_for(app_id)

    def agents_for(self, app_id: AppId) -> List[Agent]:
        with self._lock:
            ids = list(self._apps[app_id].agents)
        out = []
        for aid in ids:
            node_id = aid.split("/")[0]
            mgr = self._managers.get(node_id)
            if mgr is None:
                continue
            a = mgr.agent(aid)
            if a is not None and a.alive():
                out.append(a)
        return out

    def app(self, app_id: AppId) -> AppRecord:
        with self._lock:
            return self._apps[app_id]

    def register_region(self, app_id: AppId, region: RegionMeta) -> None:
        with self._lock:
            self._regions[app_id][region.name] = region

    def regions_of(self, app_id: AppId) -> Dict[str, RegionMeta]:
        with self._lock:
            return dict(self._regions.get(app_id, {}))

    def notify_finished(self, app_id: AppId) -> None:
        with self._lock:
            app = self._apps.get(app_id)
            if app:
                app.status = AppStatus.FINISHED

    # ============================================================ checkpoints
    def new_checkpoint(self, app_id: AppId, step: int,
                       regions: Dict[str, RegionMeta],
                       userdata: bytes = b"") -> CheckpointMeta:
        with self._lock:
            app = self._apps[app_id]
            ckpt_id = next(self._ckpt_seq[app_id])
            meta = CheckpointMeta(app_id=app_id, ckpt_id=ckpt_id, step=step,
                                  regions=dict(regions), userdata=userdata)
            app.checkpoints[ckpt_id] = meta
            total = sum(r.nbytes for r in regions.values())
            app.ckpt_bytes_estimate = max(app.ckpt_bytes_estimate, total)
        return meta

    def record_shard(self, meta: CheckpointMeta, info: ShardInfo) -> None:
        with self._lock:
            meta.shards[info.key] = info

    def finalize_checkpoint(self, meta: CheckpointMeta, drain: bool = True) -> None:
        """All shards acked in L1 → durable pipeline."""
        with self._lock:
            if not meta.is_complete_in_l1():
                raise ICheckError(
                    f"checkpoint {meta.ckpt_id} incomplete: "
                    f"{len(meta.shards)}/{meta.expected_shards()} shards")
            meta.status = CkptStatus.IN_L1
            meta.completed_at = self.clock.now()
        self._log("ckpt_in_l1", app=meta.app_id, ckpt=meta.ckpt_id, step=meta.step)
        if drain:
            self._drain_q.put(meta)

    def latest_restartable(self, app_id: AppId) -> Optional[Tuple[CheckpointMeta, str]]:
        """Newest usable checkpoint: L1 preferred (fast), else L2 (durable)."""
        with self._lock:
            app = self._apps.get(app_id)
            metas = sorted(app.checkpoints.values(), key=lambda m: -m.ckpt_id) \
                if app else []
        for meta in metas:
            if meta.status in (CkptStatus.IN_L1, CkptStatus.DRAINING) \
                    and self._l1_complete(meta):
                return meta, "l1"
            if meta.status == CkptStatus.IN_L2:
                if self._l1_complete(meta):
                    return meta, "l1"
                return meta, "l2"
        # cold restart: nothing in memory (e.g. new controller) — scan PFS
        for ckpt_id in reversed(self.pfs.list_checkpoints(app_id)):
            meta = self.pfs.read_manifest(app_id, ckpt_id)
            if meta is not None and self.pfs.checkpoint_complete(meta):
                meta.status = CkptStatus.IN_L2
                with self._lock:
                    if app is not None:
                        app.checkpoints.setdefault(ckpt_id, meta)
                return meta, "l2"
        return None

    def _l1_complete(self, meta: CheckpointMeta) -> bool:
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                if next(self._agents_with(meta.app_id, meta.ckpt_id, name,
                                          part), None) is None:
                    return False
        return True

    def _agents_with(self, app_id: AppId, ckpt_id: CkptId, region: str,
                     part: int):
        """Live (agent, key) pairs holding any replica of the shard."""
        for mgr in self.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                if not agent.alive():        # failover: skip dead replicas
                    continue
                for rep in range(4):
                    k = ShardKey(app_id, ckpt_id, region, part, rep)
                    if agent.has(k):
                        yield agent, k

    def fetch_shard(self, app_id: AppId, ckpt_id: CkptId, region: str,
                    part: int) -> bytes:
        """Restart/redistribution read path: L1 via any *live* holding agent
        (replicas tried in turn), else L2 (PFS)."""
        for agent, k in self._agents_with(app_id, ckpt_id, region, part):
            try:
                return agent.get(k)
            except (AgentDead, ConnectionError):
                continue                     # race with a failure: next copy
        key = ShardKey(app_id, ckpt_id, region, part)
        if self.pfs.has_shard(key):
            return self.pfs.read_shard(key)
        raise KeyError(f"shard {app_id}/{ckpt_id}/{region}/{part} lost")

    # ------------------------------------------------------- drain / L1 GC
    def _flush_loop(self) -> None:
        while not self._stop.is_set():
            try:
                meta = self._drain_q.get(timeout=0.05)
            except queue.Empty:
                continue
            self._drain_sem.acquire()
            try:
                self._drain_one(meta)
            finally:
                self._drain_sem.release()

    def _drain_one(self, meta: CheckpointMeta) -> None:
        with self._lock:
            meta.status = CkptStatus.DRAINING
        # each agent drains the shards it holds → parallel PFS writers
        futures = []
        for mgr in self.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                keys = [k for k in agent.store.keys()
                        if k.app_id == meta.app_id and k.ckpt_id == meta.ckpt_id
                        and k.replica == 0]
                if keys:
                    futures.append(agent.drain(keys, self.pfs))
        ok = True
        for f in futures:
            try:
                f.result(timeout=60)
            except Exception:
                ok = False
        if ok and self.pfs.checkpoint_complete(meta):
            self.pfs.write_manifest(meta)
            with self._lock:
                meta.status = CkptStatus.IN_L2
            self._log("ckpt_in_l2", app=meta.app_id, ckpt=meta.ckpt_id)
            self._gc_l1(meta.app_id)
        else:
            self._log("drain_failed", app=meta.app_id, ckpt=meta.ckpt_id)

    def _gc_l1(self, app_id: AppId) -> None:
        """Keep only the newest ``keep_l1`` checkpoints in agent memory."""
        with self._lock:
            app = self._apps[app_id]
            durable = sorted((m.ckpt_id for m in app.checkpoints.values()
                              if m.status == CkptStatus.IN_L2))
        evict = durable[:-self.keep_l1] if self.keep_l1 > 0 else durable
        for ckpt_id in evict:
            for mgr in self.managers():
                mgr.store.drop_checkpoint(app_id, ckpt_id)

    def wait_for_drains(self, timeout: float = 30.0) -> None:
        """Testing/benchmark helper: block until the drain queue empties."""
        import time as _t
        deadline = _t.monotonic() + timeout
        while _t.monotonic() < deadline:
            with self._lock:
                busy = any(m.status == CkptStatus.DRAINING
                           for a in self._apps.values()
                           for m in a.checkpoints.values())
            if self._drain_q.empty() and not busy:
                return
            _t.sleep(0.01)
        raise TimeoutError("drains did not settle")

    # ======================================================== agent adaptivity
    def probe_agents(self, app_id: AppId,
                     last_commit_sim_s: Optional[float] = None) -> List[Agent]:
        """``icheck_probe_agents``: re-tune the agent count for transfer rate.

        Heuristic: a commit should take at most ``target_frac`` of the
        checkpoint interval.  Too slow → add an agent on the least-loaded
        node (requesting a new node from the RM if saturated).  More than 2×
        over-provisioned → drop an agent, freeing resources for other apps.
        """
        target_frac = 0.25
        with self._lock:
            app = self._apps[app_id]
        agents = self.agents_for(app_id)
        if last_commit_sim_s is None or app.ckpt_interval_s <= 0 or not agents:
            return agents
        budget = app.ckpt_interval_s * target_frac
        if last_commit_sim_s > budget:
            added = self._scale_up(app, agents)
            if added:
                self._log("agents_scaled_up", app=app_id,
                          n=len(self.agents_for(app_id)))
        elif last_commit_sim_s < budget / 4 and len(agents) > 1:
            victim = agents[-1]
            mgr = self._managers[victim.node_id]
            mgr.stop_agent(victim.agent_id)
            with self._lock:
                app.agents.remove(victim.agent_id)
            self._log("agents_scaled_down", app=app_id,
                      n=len(self.agents_for(app_id)))
        return self.agents_for(app_id)

    def _scale_up(self, app: AppRecord, agents: List[Agent]) -> bool:
        # prefer a node not yet serving this app (fresh NIC)
        used_nodes = {a.node_id for a in agents}
        candidates = [nv for nv in self.node_views()
                      if nv.n_agents < nv.max_agents]
        fresh = [nv for nv in candidates if nv.node_id not in used_nodes]
        if not fresh and not self.request_more_memory():
            fresh = candidates     # fall back to sharing a NIC
        else:
            fresh = fresh or [nv for nv in self.node_views()
                              if nv.node_id not in used_nodes]
        if not fresh:
            return False
        nv = sorted(fresh, key=lambda v: (v.bw_load, v.n_agents))[0]
        agent = self._managers[nv.node_id].launch_agent(app.app_id)
        with self._lock:
            app.agents.append(agent.agent_id)
        return True

    # ===================================================== straggler advice
    def transfer_deadline(self, nbytes: int, agent: Agent,
                          factor: float = 4.0, slack: float = 1e-3) -> float:
        """Sim-seconds after which a put to ``agent`` counts as straggling."""
        rate = max(1.0, agent.observed_rate())
        return factor * (nbytes / rate) + slack

    # ================================================= RM plugin callbacks
    def _on_rm_retake(self, node_id: str) -> None:
        """RM pulls a node: migrate its shards to the remaining nodes, move
        its agents, then let the RM have it (paper §III-A interaction 2)."""
        with self._lock:
            mgr = self._managers.get(node_id)
        if mgr is None:
            return
        self._log("node_retaken", node=node_id)
        others = [m for m in self.managers() if m.node_id != node_id and m.alive()]
        if not others:
            if self.request_more_memory():
                others = [m for m in self.managers()
                          if m.node_id != node_id and m.alive()]
        # migrate shard bytes
        for key in mgr.store.keys():
            payload = mgr.store.get(key, verify=False)
            dst = min(others, key=lambda m: m.store.used_bytes, default=None)
            if dst is None:
                self._log("migration_lost_shard", key=str(key))
                continue
            dst.store.put(key, payload)
        # relocate agents app-by-app
        with self._lock:
            apps = list(self._apps.values())
        for app in apps:
            moved = [aid for aid in app.agents if aid.split("/")[0] == node_id]
            for aid in moved:
                mgr.stop_agent(aid)
                with self._lock:
                    app.agents.remove(aid)
                if others:
                    dst = min(others, key=lambda m: len(m.agents()))
                    na = dst.launch_agent(app.app_id)
                    with self._lock:
                        app.agents.append(na.agent_id)
        mgr.close()
        with self._lock:
            self._managers.pop(node_id, None)

    def _on_rm_migrate(self, src: str, dst: str) -> None:
        """RM-directed migration src → dst (paper §III-A interaction 3):
        shard bytes AND the serving agents move, so L1 restart/redistribution
        keeps working from the destination node."""
        with self._lock:
            src_mgr = self._managers.get(src)
            dst_mgr = self._managers.get(dst)
        if src_mgr is None or dst_mgr is None:
            return
        for key in src_mgr.store.keys():
            payload = src_mgr.store.get(key, verify=False)
            dst_mgr.store.put(key, payload)
            src_mgr.store.drop(key)
        with self._lock:
            apps = list(self._apps.values())
        for app in apps:
            moved = [aid for aid in app.agents if aid.split("/")[0] == src]
            for aid in moved:
                src_mgr.stop_agent(aid)
                with self._lock:
                    app.agents.remove(aid)
                na = dst_mgr.launch_agent(app.app_id)
                with self._lock:
                    app.agents.append(na.agent_id)
        self._log("node_migrated", src=src, dst=dst)

    def _on_rm_app_info(self, app_id: str, info: dict) -> None:
        """Forewarning: pre-stage redistribution plans (§III-A interaction 4)."""
        if info.get("event") != "impending_resize":
            return
        new_ranks = int(info["new_ranks"])
        with self._lock:
            app = self._apps.get(app_id)
            if app is None:
                return
            app.pending_resize = new_ranks
            regions = dict(self._regions.get(app_id, {}))
        planned = 0
        for name, region in regions.items():
            # MESH regions replan against the *new mesh's* boxes, which only
            # the application knows at adapt time (redistribute_mesh)
            if region.partition.scheme == PartitionScheme.MESH:
                continue
            self.plan_for_resize(app_id, name, new_ranks)
            planned += 1
        self._log("resize_forewarned", app=app_id, new_ranks=new_ranks,
                  plans=planned)

    # ================================================ redistribution planning
    def plan_for_resize(self, app_id: AppId, region_name: str,
                        new_parts: int) -> List[planlib.Move]:
        key = (app_id, region_name, new_parts)
        with self._lock:
            if key in self._plans:
                return self._plans[key]
            region = self._regions[app_id][region_name]
        old = region.partition
        new = old.renumbered(new_parts)
        n = region.shape[old.axis] if old.scheme.value != "replicated" else 1
        moves = planlib.redistribution_moves(n, old, new) \
            if old.scheme.value != "replicated" else []
        with self._lock:
            self._plans[key] = moves
        return moves

    # ===================================================== failure monitoring
    def _monitor_loop(self) -> None:
        import time as _t
        while not self._stop.is_set():
            _t.sleep(self._hb_interval)
            try:
                self._check_health()
            except Exception:   # monitor must never die
                pass

    def _check_health(self) -> None:
        dead_nodes = [m.node_id for m in self.managers() if not m.alive()]
        for node_id in dead_nodes:
            self._handle_node_failure(node_id)
        # single-agent failures (process died, node fine)
        for mgr in self.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                if self.fault.agent_dead(agent.agent_id):
                    self._handle_agent_failure(mgr, agent)

    def _handle_agent_failure(self, mgr: Manager, agent: Agent) -> None:
        self._log("agent_failed", agent=agent.agent_id)
        mgr.stop_agent(agent.agent_id)
        with self._lock:
            apps = [a for a in self._apps.values() if agent.agent_id in a.agents]
        for app in apps:
            with self._lock:
                app.agents.remove(agent.agent_id)
            if mgr.alive() and len(mgr.agents()) < mgr.spec.max_agents:
                na = mgr.launch_agent(app.app_id)    # node memory survived
                with self._lock:
                    app.agents.append(na.agent_id)
                self._log("agent_replaced", old=agent.agent_id, new=na.agent_id)

    def _handle_node_failure(self, node_id: str) -> None:
        with self._lock:
            mgr = self._managers.pop(node_id, None)
            if mgr is None:
                return
        self._log("node_failed", node=node_id)
        mgr.close()
        # re-replicate every shard that lived there from surviving replicas/L2
        lost: List[ShardKey] = mgr.store.keys()
        for key in lost:
            base = key.base()
            try:
                payload = self.fetch_shard(base.app_id, base.ckpt_id,
                                           base.region, base.part)
            except KeyError:
                self._mark_ckpt_failed(base.app_id, base.ckpt_id)
                continue
            dst = [m for m in self.managers() if m.alive()]
            if dst:
                d = min(dst, key=lambda m: m.store.used_bytes)
                d.store.put(base, payload)
        # replace the node's agents
        with self._lock:
            apps = list(self._apps.values())
        for app in apps:
            gone = [aid for aid in app.agents if aid.split("/")[0] == node_id]
            if not gone:
                continue
            with self._lock:
                for aid in gone:
                    app.agents.remove(aid)
            survivors = [m for m in self.managers() if m.alive()]
            if not survivors and self.request_more_memory():
                survivors = [m for m in self.managers() if m.alive()]
            for _ in gone:
                if survivors:
                    d = min(survivors, key=lambda m: len(m.agents()))
                    na = d.launch_agent(app.app_id)
                    with self._lock:
                        app.agents.append(na.agent_id)
        self._log("node_recovered", node=node_id)

    def _mark_ckpt_failed(self, app_id: AppId, ckpt_id: CkptId) -> None:
        with self._lock:
            app = self._apps.get(app_id)
            meta = app.checkpoints.get(ckpt_id) if app else None
            if meta is not None and meta.status != CkptStatus.IN_L2:
                meta.status = CkptStatus.FAILED
                self._log("ckpt_failed", app=app_id, ckpt=ckpt_id)

    # ================================================================== misc
    def _log(self, event: str, **kw) -> None:
        kw["event"] = event
        kw["sim_t"] = self.clock.now()
        with self._lock:
            self.events.append(kw)

    def close(self) -> None:
        self._stop.set()
        self._flusher.join(timeout=5)
        self._monitor.join(timeout=5)
        for mgr in self.managers():
            mgr.close()
