"""Typed event bus — the control-plane spine of the checkpoint service core.

The paper's controller is "a composition of independent services" (§II):
agent placement, orchestrated PFS drains, failure detection, and resize
forewarning.  Those services communicate through this bus instead of through
a monolith's method calls: every subsystem *publishes* typed :class:`Event`s
and anything — the audit log, the elastic trainer's metrics, a future
Prometheus exporter — *subscribes*.

The legacy ``Controller.events`` audit list is re-implemented here as just
another subscriber (:class:`AuditLog`) that renders events into the exact
dict format the old ``Controller._log`` produced, so existing tests and
benchmarks keep working unchanged.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Iterable, List, Mapping, Optional, Tuple

# --------------------------------------------------------------------------
# canonical event names (the audit vocabulary)
# --------------------------------------------------------------------------
NODE_ADDED = "node_added"
NODE_REQUEST_DENIED = "node_request_denied"
NODE_RETAKEN = "node_retaken"
NODE_MIGRATED = "node_migrated"
NODE_FAILED = "node_failed"
NODE_RECOVERED = "node_recovered"
MIGRATION_LOST_SHARD = "migration_lost_shard"

APP_REGISTERED = "app_registered"
CAPACITY_GROW = "capacity_grow"
AGENTS_SCALED_UP = "agents_scaled_up"
AGENTS_SCALED_DOWN = "agents_scaled_down"
AGENT_FAILED = "agent_failed"
AGENT_REPLACED = "agent_replaced"
# the HealthMonitor's poll loop raised: the monitor survives (next tick
# retries) but a repeatedly-failing check means failures are going unseen —
# payload carries the repr'd error, and the obs flight ring is dumped so a
# wedged monitor is diagnosable instead of invisible
MONITOR_ERROR = "monitor_error"

# -- erasure-coded L1 durability (k data + m parity fragments) --------------
# a commit finished scattering one logical shard as an erasure-coded stripe;
# payload carries k/m, the logical bytes and the framed fragment bytes (the
# TelemetryService's EC overhead signal)
EC_STRIPE_COMMITTED = "ec_stripe_committed"
# the HealthMonitor launched a peer rebuild for fragments lost with an
# agent/node: a surviving agent gathers any k fragments over MemBus/NIC,
# GF-decodes the missing ones and re-hosts them
EC_REBUILD_STARTED = "ec_rebuild_started"
# the rebuild landed the regenerated fragment(s); payload carries the
# source ("peer" or the L2/L3 provider fallback), bytes moved and sim s
EC_REBUILD_DONE = "ec_rebuild_done"
# fewer than k fragments survive and no lower tier holds the shard: the
# stripe is lost and the checkpoint is marked failed
EC_REBUILD_FAILED = "ec_rebuild_failed"
# a read had to GF-decode around missing data fragments instead of the
# healthy gather-and-concat path (durability worked, but latency paid)
EC_DEGRADED_READ = "ec_degraded_read"

CKPT_IN_L1 = "ckpt_in_l1"
CKPT_IN_L2 = "ckpt_in_l2"
CKPT_FAILED = "ckpt_failed"
DRAIN_FAILED = "drain_failed"
# commit fully acked in L1, with the client-observed cost attached
# (bytes moved, busiest-NIC sim seconds, straggler retries) — the
# TelemetryService's commit-latency/-cost signal
COMMIT_DONE = "commit_done"
# a restart finished reassembling application state from a checkpoint;
# payload carries the source tier and sim seconds — the TelemetryService's
# restore-latency histogram signal, and the span that closes a trace tree
RESTORE_DONE = "restore_done"

RESIZE_FOREWARNED = "resize_forewarned"

# -- peer-to-peer redistribution (the adapt window) -------------------------
# an adapt-window redistribution began; payload carries via="peer" (agents
# move slices among themselves from pre-staged transfer programs) or
# via="client" (the legacy gather-through-the-client funnel)
REDISTRIBUTION_STARTED = "redistribution_started"
# the redistribution finished; payload carries bytes_moved (wire bytes of
# every slice transfer), peer_hops / cross / intra / tier read counts,
# bytes_through_client (only the parts the local new ranks fetched) and the
# simulated adapt-window seconds — the TelemetryService's resize signal
REDISTRIBUTION_DONE = "redistribution_done"
# the peer engine could not run (unsupported layout, agent death
# mid-transfer, lost source shard): the client funnel takes over so the
# adapt window completes instead of wedging
REDISTRIBUTION_FALLBACK = "redistribution_fallback"
# -- zero-stall (two-phase) resize ------------------------------------------
# phase 1 opened: the base checkpoint is streaming to the new partition in
# the background while the application keeps stepping (and keeps committing
# q8-deltas against the pre-resize chain)
RESIZE_OVERLAP_STARTED = "resize_overlap_started"
# phase 2 finished: tail deltas replayed onto the assembled scratch parts
# (or a keyframe re-hydration when the chain reset mid-window) and the app
# switched to the new partition; payload carries the bounded stall seconds,
# the hidden overlap seconds, commits absorbed during the window, tail frame
# count and whether re-hydration was needed
CUTOVER_DONE = "cutover_done"
CODEC_DEGRADED = "codec_degraded"
SHARD_SPILLED = "shard_spilled"
SHARD_PROMOTED = "shard_promoted"

# -- incremental (q8-delta) commit path -------------------------------------
# a commit's regions were delta/keyframe-encoded on the commit hot path;
# payload carries raw vs encoded (bytes-on-wire) totals, key/delta frame
# counts, changed/total block counts and the host-side encode seconds — the
# TelemetryService's compression-ratio and encode-time signal
CKPT_DELTA_COMMITTED = "ckpt_delta_committed"
# a region's delta chain was invalidated (resize/redistribution, rank or
# node/agent failure, a chain frame demoted or expired, commit failure):
# the next commit of that region must emit a full keyframe
DELTA_CHAIN_RESET = "delta_chain_reset"

# -- storage lifecycle (watermark demotion / L3 trickle / retention) -------
# a shard was pushed down a tier by policy (not by put-time capacity
# pressure): the StorageLifecycleService's watermark demotion
SHARD_DEMOTED = "shard_demoted"
# a requested demotion could not happen (no lower tier, shard not resident,
# lower tier full) — published so lifecycle decisions stay observable
# instead of silently returning False
DEMOTE_FAILED = "demote_failed"
# a node tier crossed its configured high watermark (direction="high") or
# was drained back under the low watermark (direction="low")
WATERMARK_CROSSED = "watermark_crossed"
# a checkpoint finished its background L2→L3 trickle and is durable in the
# remote object store
CKPT_IN_L3 = "ckpt_in_l3"
# the trickle exhausted its retries; the checkpoint stays IN_L2 (still
# durable on the PFS) and retention will not trim it
L3_UPLOAD_FAILED = "l3_upload_failed"
# retention/GC dropped a checkpoint's shards from one tier (payload carries
# ``tier``); a checkpoint expired from its last tier is gone for good
CKPT_EXPIRED = "ckpt_expired"

# an application rank died (injected by tests/benchmarks or reported by the
# RM plugin): the application loses all work since its last checkpoint.
# Feeds the TelemetryService's failure inter-arrival (MTBF) estimate.
APP_RANK_FAILED = "app_rank_failed"
# the IntervalController re-solved an application's checkpoint cadence
# (Young/Daly over telemetry estimates); clients/trainers re-pace on this
INTERVAL_CHANGED = "interval_changed"

# -- crash-consistent control plane (metadata journal + epoch fencing) ------
# the controller finished a warm recovery: journal snapshot+tail replayed
# into a fresh catalog, divergences reconciled against the live tiers, open
# chains/windows conservatively reset; payload carries the new epoch, the
# replay stats and the per-app recovered high-water marks
CONTROLLER_RECOVERED = "controller_recovered"
# an agent inbox op / drain queue entry / RM interaction carried a stale
# controller epoch and was refused — the fencing that stops a zombie
# controller (or its queued work) from corrupting post-recovery state
STALE_OP_REJECTED = "stale_op_rejected"
# a transient-fault retry policy (with_backoff) gave up: the per-op
# deadline would be exceeded — payload carries what/attempts/error; the
# underlying error is still raised to the caller
RETRY_EXHAUSTED = "retry_exhausted"
# Controller.wait_for_drains / wait_for_uploads timed out with work still
# queued; the returned report says what is pending, this event makes the
# silent-timeout hazard observable
WAIT_TIMEOUT = "wait_timeout"

# -- chaos campaigns (repro.chaos) ------------------------------------------
# the chaos injector fired one scheduled action (payload: kind, target,
# params, scheduled at_s) — the audit trail every invariant check can line
# failures up against
CHAOS_INJECTED = "chaos_injected"
# a transient chaos action (NIC degradation/down, straggler, partition,
# L3 outage) recovered at its scheduled end
CHAOS_CLEARED = "chaos_cleared"


@dataclasses.dataclass(frozen=True)
class Event:
    """One control-plane occurrence: a name, a sim timestamp, a payload.

    ``trace`` carries the :class:`~repro.obs.trace.TraceContext` the event
    was published under (None when tracing is off).  It deliberately stays
    *out* of :meth:`as_record` — the audit-dict format is byte-compatible
    with the pre-refactor log; trace identity travels beside it, read by
    the flight recorder, never by the audit consumers.
    """

    name: str
    sim_t: float
    payload: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    trace: Optional[Any] = dataclasses.field(default=None, compare=False,
                                             repr=False)

    def as_record(self) -> dict:
        """Render to the legacy audit-dict format (payload keys first)."""
        rec = dict(self.payload)
        rec["event"] = self.name
        rec["sim_t"] = self.sim_t
        return rec


Subscriber = Callable[[Event], None]


class EventBus:
    """Thread-safe publish/subscribe fan-out.

    Subscribers must never take the control plane down: exceptions raised by
    a handler are swallowed (the bus is telemetry, not a transaction log).
    """

    def __init__(self, clock=None):
        self.clock = clock
        # optional TraceCollector: when set, every publish stamps the
        # publisher thread's current trace context onto the event
        self.tracer = None
        self._lock = threading.Lock()
        self._subs: List[Tuple[Optional[frozenset], Subscriber]] = []

    def subscribe(self, handler: Subscriber,
                  events: Optional[Iterable[str]] = None) -> Callable[[], None]:
        """Register ``handler`` for ``events`` (None = all).

        Returns an unsubscribe callable.
        """
        filt = frozenset(events) if events is not None else None
        entry = (filt, handler)
        with self._lock:
            self._subs.append(entry)

        def unsubscribe() -> None:
            with self._lock:
                try:
                    self._subs.remove(entry)
                except ValueError:
                    pass

        return unsubscribe

    def publish(self, name: str, **payload) -> Event:
        sim_t = self.clock.now() if self.clock is not None else 0.0
        ctx = self.tracer.current() if self.tracer is not None else None
        ev = Event(name=name, sim_t=sim_t, payload=payload, trace=ctx)
        with self._lock:
            subs = list(self._subs)
        for filt, handler in subs:
            if filt is None or name in filt:
                try:
                    handler(ev)
                except Exception:   # noqa: BLE001 - telemetry must not break us
                    pass
        return ev


class AuditLog:
    """The old ``Controller.events`` list, rebuilt as a bus subscriber.

    ``records`` is byte-compatible with what ``Controller._log`` used to
    append: ``{**payload, "event": name, "sim_t": t}`` in that key order.

    Growth is bounded: beyond ``maxlen`` records the oldest are trimmed
    from the front and counted in ``dropped``, so long chaos campaigns
    and multi-app runs stop accumulating O(events) memory.  The default
    is far above what any test or campaign produces, keeping the list
    contiguous (index == publish order) on every short run.
    """

    def __init__(self, maxlen: int = 100_000):
        self._lock = threading.Lock()
        self.maxlen = max(1, int(maxlen))
        self.dropped = 0
        self.records: List[dict] = []

    def __call__(self, ev: Event) -> None:
        rec = ev.as_record()
        with self._lock:
            self.records.append(rec)
            if len(self.records) > self.maxlen:
                excess = len(self.records) - self.maxlen
                del self.records[:excess]
                self.dropped += excess

    def names(self) -> List[str]:
        with self._lock:
            return [r["event"] for r in self.records]
