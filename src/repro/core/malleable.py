"""Malleable-application runtime shim (InvasIC-MPI analogue, paper §III-B).

Mirrors the four malleable MPI routines the paper's infrastructure adds to
MPICH, so that synthetic applications, examples and tests can be written with
the exact control flow of paper Listing 1:

    MPI_Init_adapt         -> MalleableApp.init_adapt    (returns proc type)
    MPI_Probe_adapt        -> MalleableApp.probe_adapt
    MPI_Comm_adapt_begin   -> MalleableApp.adapt_begin
    MPI_Comm_adapt_commit  -> MalleableApp.adapt_commit

In the JAX adaptation an "application rank" is a slice of the device mesh;
the elastic trainer (repro.train.elastic) drives the same state machine with
mesh epochs instead of MPI process groups.
"""
from __future__ import annotations

import enum
from typing import Optional

from .rm import ResizeEvent, ResourceManager
from .types import AppId


class ProcType(str, enum.Enum):
    INITIAL = "initial"    # created at application launch
    JOINING = "joining"    # created during an expansion


class AdaptWindow:
    """The region between adapt_begin and adapt_commit where initial and
    joining processes exchange data (redistribution happens here)."""

    def __init__(self, app: "MalleableApp", event: ResizeEvent):
        self.app = app
        self.event = event
        self.old_ranks = app.ranks
        self.new_ranks = event.new_ranks

    def commit(self) -> None:
        self.app._commit_adapt(self)


class MalleableApp:
    def __init__(self, app_id: AppId, rm: ResourceManager, ranks: int,
                 proc_type: ProcType = ProcType.INITIAL):
        self.app_id = app_id
        self.rm = rm
        self.ranks = ranks
        self.proc_type = proc_type
        self._window: Optional[AdaptWindow] = None
        self.adaptations = 0

    # ----------------------------------------------------------------- MPI_*
    def init_adapt(self) -> ProcType:
        """Register with the RM; returns whether we are initial or joining."""
        if self.proc_type == ProcType.INITIAL:
            self.rm.register_app(self.app_id, self.ranks)
        return self.proc_type

    def probe_adapt(self) -> Optional[ResizeEvent]:
        """Non-blocking check for an RM-triggered resource change."""
        return self.rm.probe_resize(self.app_id)

    def adapt_begin(self) -> AdaptWindow:
        ev = self.rm.probe_resize(self.app_id)
        if ev is None and self.proc_type == ProcType.JOINING:
            # joining processes call adapt_begin unconditionally and wait
            ev = ResizeEvent(self.app_id, self.ranks, reason="join")
        if ev is None:
            raise RuntimeError("adapt_begin without a pending resize")
        self._window = AdaptWindow(self, ev)
        return self._window

    def adapt_commit(self) -> None:
        if self._window is None:
            raise RuntimeError("adapt_commit without adapt_begin")
        self._window.commit()

    # ------------------------------------------------------------------ guts
    def _commit_adapt(self, window: AdaptWindow) -> None:
        self.ranks = window.new_ranks
        self.rm.complete_resize(self.app_id)
        self.proc_type = ProcType.INITIAL     # joiners become initial
        self.adaptations += 1
        self._window = None
