"""Core datatypes for the iCheck checkpoint-management system.

These mirror the entities in the paper (§II): iCheck *nodes* host *agents*
launched by per-node *managers* under a global *controller*; applications
register *regions* (checkpointable arrays + their distribution mapping, paper
Listing 1 ``icheck_add_adapt``) and commit *checkpoints* that live in agent
memory (L1) and are drained to the PFS (L2).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Optional

# --------------------------------------------------------------------------
# identifiers
# --------------------------------------------------------------------------
AppId = str
NodeId = str
AgentId = str
CkptId = int


class PartitionScheme(str, enum.Enum):
    """Data-redistribution schemes supported by iCheck (paper §III-B).

    BLOCK / CYCLIC / REPLICATED are the paper's 1-d schemes; MESH is the
    beyond-paper N-d generalisation used for JAX arrays sharded over a
    (pod, data, model) device mesh.
    """

    BLOCK = "block"
    CYCLIC = "cyclic"
    REPLICATED = "replicated"
    MESH = "mesh"


class CkptStatus(str, enum.Enum):
    PENDING = "pending"          # commit issued, transfers in flight
    IN_L1 = "in_l1"              # complete in agent memory
    DRAINING = "draining"        # L1 -> L2 writeback in progress
    IN_L2 = "in_l2"              # durable on the PFS (may also still be in L1)
    IN_L3 = "in_l3"              # durable in the remote object store (and
    #                              possibly still in L2/L1 until retention
    #                              trims those copies)
    EXPIRED = "expired"          # retention dropped it from every tier
    FAILED = "failed"


class AppStatus(str, enum.Enum):
    REGISTERED = "registered"
    CONNECTED = "connected"
    ADAPTING = "adapting"        # inside MPI_Comm_adapt_begin/commit window
    FINISHED = "finished"
    FAILED = "failed"


@dataclasses.dataclass
class NodeSpec:
    """An iCheck node: dedicated memory + NIC the agents on it share."""

    node_id: NodeId
    memory_bytes: int = 64 << 30           # 64 GiB of checkpoint RAM
    nic_bandwidth: float = 25e9            # 25 GB/s (e.g. 200 Gb HDR)
    nic_latency: float = 2e-6              # RDMA one-sided put latency
    mem_bandwidth: float = 200e9           # intra-node copy bandwidth (DDR)
    max_agents: int = 16


@dataclasses.dataclass
class AgentSpec:
    agent_id: AgentId
    node_id: NodeId
    app_id: Optional[AppId] = None         # agents are assigned per application


@dataclasses.dataclass
class PartitionDesc:
    """How a registered region is distributed over application ranks.

    ``axis`` is the distributed axis of the global array; ``num_parts`` the
    number of application ranks holding it.  ``block`` is the cyclic block
    size (paper only needs block/cyclic; block=1 is classic cyclic).
    """

    scheme: PartitionScheme = PartitionScheme.BLOCK
    axis: int = 0
    num_parts: int = 1
    block: int = 1
    # MESH only: per-part bounds, tuple over parts of tuple over dims of
    # (lo, hi) global index ranges.
    bounds: Optional[tuple] = None

    def renumbered(self, new_parts: int) -> "PartitionDesc":
        return dataclasses.replace(self, num_parts=new_parts)


@dataclasses.dataclass
class RegionMeta:
    """One checkpointable array registered via ``icheck_add_adapt``."""

    name: str
    shape: tuple
    dtype: str
    partition: PartitionDesc
    nbytes: int
    # optional codec applied on the transfer path (beyond-paper, TPU-native)
    codec: str = "raw"                     # raw | zstd | q8 | q8-delta
    # q8-delta frame bookkeeping, set on the *per-checkpoint* RegionMeta
    # copies (the add_adapt registry meta keeps both None): ``frame`` says
    # whether this checkpoint's shards are a full q8 keyframe or a sparse
    # XOR-delta against the previous codes, and ``chain`` lists the ckpt ids
    # (keyframe first, this checkpoint last) a restore must replay in order
    frame: Optional[str] = None            # "key" | "delta"
    chain: Optional[tuple] = None          # (keyframe_ckpt, ..., this_ckpt)

    @property
    def itemsize(self) -> int:
        import numpy as np

        return int(np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class ShardKey:
    """Key of one stored shard: (app, checkpoint, region, part index)."""

    app_id: AppId
    ckpt_id: CkptId
    region: str
    part: int
    replica: int = 0

    def base(self) -> "ShardKey":
        return ShardKey(self.app_id, self.ckpt_id, self.region, self.part, 0)


@dataclasses.dataclass
class ShardInfo:
    key: ShardKey
    nbytes: int
    crc32: int
    agent_id: Optional[AgentId] = None     # where it currently lives (L1)
    in_l2: bool = False


@dataclasses.dataclass
class CheckpointMeta:
    app_id: AppId
    ckpt_id: CkptId
    step: int
    regions: dict = dataclasses.field(default_factory=dict)   # name -> RegionMeta
    shards: dict = dataclasses.field(default_factory=dict)    # ShardKey -> ShardInfo
    status: CkptStatus = CkptStatus.PENDING
    created_at: float = dataclasses.field(default_factory=time.monotonic)
    completed_at: Optional[float] = None
    # extra payload the application wants back verbatim on restart
    # (step counters, RNG keys, data-iterator cursors, ...)
    userdata: bytes = b""
    # pinned checkpoints are exempt from retention/GC on every tier
    pinned: bool = False

    def expected_shards(self) -> int:
        return sum(m.partition.num_parts for m in self.regions.values())

    def is_complete_in_l1(self) -> bool:
        base = {k.base() for k in self.shards}
        return len(base) >= self.expected_shards()


@dataclasses.dataclass
class AppRecord:
    """Controller-side record of a connected application (paper §II step 1)."""

    app_id: AppId
    ranks: int
    status: AppStatus = AppStatus.REGISTERED
    # checkpoint characteristics used by scheduling policies (paper §II:
    # "available memory, checkpoint frequency and size, and bandwidth usage")
    ckpt_bytes_estimate: int = 0
    ckpt_interval_s: float = 60.0
    replication: int = 1
    # erasure-coded durability: (k, m) stripe geometry, or None for
    # whole-shard replication; EC apps keep replication == 1 (the k data +
    # m parity fragments ARE the redundancy)
    ec: Optional[tuple] = None
    agents: list = dataclasses.field(default_factory=list)    # [AgentId]
    checkpoints: dict = dataclasses.field(default_factory=dict)  # CkptId -> CheckpointMeta
    next_ckpt_id: CkptId = 0
    # resize forewarning from the RM (paper §III-A: "impending resource change")
    pending_resize: Optional[int] = None

    def l1_overhead_factor(self) -> float:
        """L1 bytes per logical byte: (k+m)/k under EC, replication else."""
        if self.ec:
            k, m = self.ec
            return (k + m) / k
        return float(self.replication)

    def demand_bytes_per_s(self) -> float:
        if self.ckpt_interval_s <= 0:
            return 0.0
        return (self.ckpt_bytes_estimate * self.l1_overhead_factor()
                / self.ckpt_interval_s)


@dataclasses.dataclass
class TransferRecord:
    """Accounting for one RDMA-analogue shard transfer."""

    key: ShardKey
    nbytes: int
    agent_id: AgentId
    sim_seconds: float
    ok: bool = True
    retried: bool = False


class ICheckError(RuntimeError):
    pass


class CapacityError(ICheckError):
    pass


class IntegrityError(ICheckError):
    pass


class RestoreError(ICheckError):
    """A checkpoint could not be reconstructed (missing or corrupt delta-chain
    link, truncated frame, ...) — raised instead of decoding garbage."""

