"""The iCheck application library (paper Listing 1).

Maps 1:1 to the paper's API:

    icheck_init            -> ICheckClient.init
    icheck_add_adapt       -> ICheckClient.add_adapt / add_adapt_snapshot
    icheck_commit          -> ICheckClient.commit            (non-blocking)
    icheck_restart         -> ICheckClient.restart
    icheck_redistribute    -> ICheckClient.redistribute
    icheck_probe_agents    -> ICheckClient.probe_agents
    icheck_finalize        -> ICheckClient.finalize

"Since the agents use RDMA, the application does not need to block for data
transfer rather it can continue the execution immediately after notifying
the agents about the checkpoints." — ``commit`` therefore returns a
``CommitHandle`` immediately; a background completer thread drives the
transfers, retries stragglers, and finalises the checkpoint with the
controller.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import events as E
from . import plan as planlib
from .agent import Agent, AgentDead
from .controller import Controller
from .tiers import crc32, decode_payload, encode_payload, resolve_codec
from .types import (AppId, CapacityError, CheckpointMeta, ICheckError,
                    PartitionDesc, PartitionScheme, RegionMeta, ShardInfo,
                    ShardKey)


class CommitHandle:
    """In-flight checkpoint: resolves once every shard is acked in L1."""

    def __init__(self, client: "ICheckClient", meta: CheckpointMeta,
                 puts: List[Tuple[ShardKey, bytes, Agent]], drain: bool):
        self.client = client
        self.meta = meta
        self._puts = puts
        self._drain = drain
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self.sim_duration = 0.0
        self.retries = 0

    # -- introspection ------------------------------------------------------
    @property
    def ckpt_id(self) -> int:
        return self.meta.ckpt_id

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> "CommitHandle":
        if not self._done.wait(timeout):
            raise TimeoutError(f"commit {self.meta.ckpt_id} still in flight")
        if self._error is not None:
            raise self._error
        return self

    # -- executed on the client's completer thread --------------------------
    def _complete(self) -> None:
        ctl = self.client.controller
        per_node_sim: Dict[str, float] = {}
        try:
            inflight = [(key, payload, agent, agent.put(key, payload))
                        for key, payload, agent in self._puts]
            for key, payload, agent, fut in inflight:
                rec = self._await_with_straggler_retry(key, payload, agent, fut)
                # agents on one node share its NIC: serialized-at-full-bw
                # time summed per NODE equals concurrent shared-bw time
                node = rec.agent_id.split("/")[0]
                per_node_sim[node] = per_node_sim.get(node, 0.0) \
                    + rec.sim_seconds
                if key.replica == 0:
                    ctl.record_shard(self.meta, ShardInfo(
                        key=key, nbytes=rec.nbytes, crc32=crc32(payload),
                        agent_id=rec.agent_id))
            # commit duration ≈ busiest NIC's total transfer time
            self.sim_duration = max(per_node_sim.values(), default=0.0)
            ctl.finalize_checkpoint(self.meta, drain=self._drain)
            self.client._last_commit_sim_s = self.sim_duration
            ctl.bus.publish(E.COMMIT_DONE, app=self.meta.app_id,
                            ckpt=self.meta.ckpt_id, step=self.meta.step,
                            bytes=sum(len(p) for k, p, _ in self._puts
                                      if k.replica == 0),
                            sim_s=self.sim_duration, retries=self.retries)
        except BaseException as e:  # noqa: BLE001
            self._error = e
        finally:
            self._done.set()

    def _await_with_straggler_retry(self, key: ShardKey, payload: bytes,
                                    agent: Agent, fut: Future):
        """First-completion-wins re-issue of laggard transfers.

        Deadline comes from the controller's bandwidth prediction; on expiry
        (or agent death) the shard is re-put to the next healthy agent.
        Puts are idempotent, so a late original completing twice is harmless.
        """
        ctl = self.client.controller
        scale = max(ctl.clock.time_scale, 0.0)
        tried = {agent.agent_id}
        for _ in range(8):
            sim_deadline = ctl.transfer_deadline(len(payload), agent)
            wall_timeout = sim_deadline * scale + 2.0 if scale > 0 else 10.0
            try:
                return fut.result(timeout=wall_timeout)
            except AgentDead:
                pass
            except TimeoutError:
                self.retries += 1
            except ConnectionError:
                pass
            except CapacityError:
                # node full: controller asks the RM for another iCheck node
                # (paper SSIII-A), then we re-put to the grown agent set
                ctl.handle_capacity_pressure(key.app_id)
                tried.clear()
                tried.add(agent.agent_id)
            # pick a replacement agent
            candidates = [a for a in ctl.agents_for(key.app_id)
                          if a.agent_id not in tried] or ctl.agents_for(key.app_id)
            if not candidates:
                raise ICheckError(f"no live agents for {key}")
            agent = candidates[0]
            tried.add(agent.agent_id)
            fut = agent.put(key, payload)
        raise ICheckError(f"shard {key} could not be stored after retries")


class ICheckClient:
    def __init__(self, app_id: AppId, controller: Controller, ranks: int = 1,
                 replication: int = 1, codec: str = "raw",
                 ckpt_interval_s: float = 60.0):
        self.app_id = app_id
        self.controller = controller
        self.ranks = ranks
        self.replication = max(1, replication)
        # codec resolution is part of the tier pipeline now: a requested
        # codec this process can't run (e.g. zstd without zstandard) degrades
        # to "none" with an audit event instead of mis-labelling shards
        self.codec = resolve_codec(codec, on_degrade=lambda req, actual:
                                   controller.bus.publish(
                                       E.CODEC_DEGRADED, app=app_id,
                                       requested=req, actual=actual))
        self.ckpt_interval_s = ckpt_interval_s
        # adaptive loop: the IntervalController re-solves our cadence from
        # observed commit cost + failure rate; track its announcements so
        # application-side pacing (`ckpt_interval_s`) follows the solution
        self._unsub_interval = controller.bus.subscribe(
            self._on_interval_changed, events=(E.INTERVAL_CHANGED,))
        self.agents: List[Agent] = []
        self.regions: Dict[str, RegionMeta] = {}
        self._rr = 0
        self._last_commit_sim_s: Optional[float] = None
        self._commit_q: "queue.Queue[Optional[CommitHandle]]" = queue.Queue()
        self._completer = threading.Thread(target=self._completer_loop,
                                           daemon=True,
                                           name=f"icheck-client-{app_id}")
        self._completer.start()
        self._initialized = False

    # ------------------------------------------------------------- lifecycle
    def init(self, ckpt_bytes_estimate: int = 0) -> "ICheckClient":
        """icheck_init(): register with the controller, connect to agents."""
        self.agents = self.controller.register_app(
            self.app_id, self.ranks, ckpt_bytes_estimate=ckpt_bytes_estimate,
            ckpt_interval_s=self.ckpt_interval_s, replication=self.replication)
        self._initialized = True
        return self

    def _on_interval_changed(self, ev: E.Event) -> None:
        if ev.payload.get("app") == self.app_id:
            self.ckpt_interval_s = float(ev.payload["interval_s"])

    def finalize(self) -> None:
        """icheck_finalize()."""
        self._commit_q.put(None)
        self._completer.join(timeout=10)
        self._unsub_interval()
        self.controller.notify_finished(self.app_id)

    # ----------------------------------------------------------- add_adapt
    def add_adapt(self, name: str, shape: Sequence[int], dtype: str,
                  scheme: PartitionScheme = PartitionScheme.BLOCK,
                  axis: int = 0, num_parts: Optional[int] = None,
                  block: int = 1,
                  bounds: Optional[tuple] = None) -> RegionMeta:
        """icheck_add_adapt(): register a checkpointable array + its
        distribution mapping (used later for redistribution)."""
        shape = tuple(int(s) for s in shape)
        desc = PartitionDesc(scheme=scheme, axis=axis,
                             num_parts=num_parts or self.ranks, block=block,
                             bounds=bounds)
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize if shape else \
            np.dtype(dtype).itemsize
        meta = RegionMeta(name=name, shape=shape, dtype=str(np.dtype(dtype)),
                          partition=desc, nbytes=nbytes, codec=self.codec)
        self.regions[name] = meta
        self.controller.register_region(self.app_id, meta)
        return meta

    def add_adapt_snapshot(self, snap) -> None:
        """Register every region of a ``HostSnapshot`` (JAX pytree path)."""
        for name, sr in snap.regions.items():
            meta = sr.meta
            meta.codec = self.codec
            self.regions[name] = meta
            self.controller.register_region(self.app_id, meta)

    # ---------------------------------------------------------------- commit
    def commit(self, step: int,
               parts_by_region: Dict[str, Dict[int, np.ndarray]],
               userdata: bytes = b"", blocking: bool = False,
               drain: bool = True) -> CommitHandle:
        """icheck_commit(): notify agents, return immediately.

        ``parts_by_region[name][part]`` is the local array of that part
        (what each application rank holds).
        """
        if not self._initialized:
            raise ICheckError("call init() first")
        metas = {}
        for name, parts in parts_by_region.items():
            if name not in self.regions:
                raise ICheckError(f"region {name!r} was not add_adapt()ed")
            meta = self.regions[name]
            if len(parts) != meta.partition.num_parts:
                raise ICheckError(
                    f"region {name!r}: got {len(parts)} parts, expected "
                    f"{meta.partition.num_parts}")
            metas[name] = meta
        ckpt = self.controller.new_checkpoint(self.app_id, step, metas,
                                              userdata=userdata)
        agents = self.controller.agents_for(self.app_id)
        if not agents:
            raise ICheckError("no agents assigned")
        puts: List[Tuple[ShardKey, bytes, Agent]] = []
        for name, parts in parts_by_region.items():
            # a region restored from a manifest may carry a codec this
            # process can't run (e.g. zstd without zstandard): degrade it
            # here so the new shards and manifest stay self-consistent
            metas[name].codec = resolve_codec(
                metas[name].codec, on_degrade=lambda req, actual:
                self.controller.bus.publish(E.CODEC_DEGRADED, app=self.app_id,
                                            region=name, requested=req,
                                            actual=actual))
            for part, arr in parts.items():
                payload = encode_payload(np.ascontiguousarray(arr).tobytes(),
                                         metas[name].codec, metas[name].dtype)
                for rep in range(self.replication):
                    key = ShardKey(self.app_id, ckpt.ckpt_id, name, part, rep)
                    agent = agents[(self._rr + rep) % len(agents)]
                    puts.append((key, payload, agent))
                self._rr += 1
        handle = CommitHandle(self, ckpt, puts, drain=drain)
        self._commit_q.put(handle)
        if blocking:
            handle.wait(timeout=120)
        return handle

    def _completer_loop(self) -> None:
        while True:
            handle = self._commit_q.get()
            if handle is None:
                return
            handle._complete()

    # --------------------------------------------------------------- restart
    def restart(self) -> Optional[Tuple[CheckpointMeta, Dict[str, Dict[int, np.ndarray]], str]]:
        """icheck_restart(): newest usable checkpoint → (meta, parts, level).

        Returns None when no checkpoint exists (fresh start, paper line 7-9).
        """
        found = self.controller.latest_restartable(self.app_id)
        if found is None:
            return None
        meta, level = found
        out: Dict[str, Dict[int, np.ndarray]] = {}
        for name, region in meta.regions.items():
            parts: Dict[int, np.ndarray] = {}
            for part in range(region.partition.num_parts):
                payload = decode_payload(
                    self.controller.fetch_shard(self.app_id, meta.ckpt_id,
                                                name, part),
                    region.codec, region.dtype)
                arr = np.frombuffer(bytearray(payload),
                                    dtype=np.dtype(region.dtype))
                parts[part] = arr.reshape(self._part_shape(region, part))
            out[name] = parts
            # refresh the client-side region registry from the manifest
            self.regions[name] = region
            self.controller.register_region(self.app_id, region)
        return meta, out, level

    def _part_shape(self, region: RegionMeta, part: int) -> Tuple[int, ...]:
        desc = region.partition
        if desc.scheme == PartitionScheme.MESH:
            return tuple(hi - lo for lo, hi in desc.bounds[part])
        return planlib.local_shape(region.shape, desc, part)

    # ---------------------------------------------------------- redistribute
    def redistribute(self, name: str, new_num_parts: int,
                     ckpt_id: Optional[int] = None,
                     parts_needed: Optional[Sequence[int]] = None
                     ) -> Dict[int, np.ndarray]:
        """icheck_redistribute(): build the *new* distribution's parts from
        the latest checkpoint, moving only the slices each new part needs
        (paper §III-B; BLOCK/CYCLIC preserved, part count changes)."""
        region = self.regions[name]
        old = region.partition
        if old.scheme == PartitionScheme.MESH:
            raise ICheckError("use redistribute_mesh for mesh regions")
        new = old.renumbered(new_num_parts)
        moves = self.controller.plan_for_resize(self.app_id, name, new_num_parts)
        if ckpt_id is None:
            found = self.controller.latest_restartable(self.app_id)
            if found is None:
                raise ICheckError("nothing to redistribute from")
            ckpt_id = found[0].ckpt_id
        wanted = set(parts_needed) if parts_needed is not None \
            else set(range(new_num_parts))
        needed_src = sorted({mv.src for mv in moves if mv.dst in wanted})
        src_parts: Dict[int, np.ndarray] = {}
        for sp in needed_src:
            payload = decode_payload(self.controller.fetch_shard(
                self.app_id, ckpt_id, name, sp), region.codec, region.dtype)
            src_parts[sp] = np.frombuffer(bytearray(payload),
                                          dtype=np.dtype(region.dtype)) \
                .reshape(self._part_shape(region, sp))
        sub_moves = [mv for mv in moves if mv.dst in wanted]
        dst = planlib.apply_moves(src_parts, sub_moves, old, new, region.shape)
        result = {p: dst[p] for p in wanted}
        return result

    def commit_redistribution(self, name: str, new_num_parts: int) -> None:
        """MPI_Comm_adapt_commit side-effect: region now has the new mapping."""
        region = self.regions[name]
        region.partition = region.partition.renumbered(new_num_parts)
        self.controller.register_region(self.app_id, region)

    def redistribute_mesh(self, name: str, new_boxes: Sequence[planlib.Box],
                          ckpt_id: Optional[int] = None
                          ) -> Dict[int, np.ndarray]:
        """Mesh-sharded (JAX) variant: old boxes from the region registry,
        new boxes from the target sharding."""
        region = self.regions[name]
        if region.partition.scheme != PartitionScheme.MESH:
            raise ICheckError(f"{name} is not a mesh region")
        old_boxes = region.partition.bounds
        moves = planlib.mesh_moves(old_boxes, tuple(new_boxes))
        if ckpt_id is None:
            found = self.controller.latest_restartable(self.app_id)
            if found is None:
                raise ICheckError("nothing to redistribute from")
            ckpt_id = found[0].ckpt_id
        needed_src = sorted({mv.src for mv in moves})
        src_parts: Dict[int, np.ndarray] = {}
        for sp in needed_src:
            payload = decode_payload(self.controller.fetch_shard(
                self.app_id, ckpt_id, name, sp), region.codec, region.dtype)
            src_parts[sp] = np.frombuffer(bytearray(payload),
                                          dtype=np.dtype(region.dtype)) \
                .reshape(self._part_shape(region, sp))
        return planlib.apply_mesh_moves(src_parts, moves, tuple(new_boxes),
                                        np.dtype(region.dtype))

    # ---------------------------------------------------------- probe_agents
    def probe_agents(self) -> List[Agent]:
        """icheck_probe_agents(): let the controller re-tune our agent set."""
        self.agents = self.controller.probe_agents(self.app_id,
                                                   self._last_commit_sim_s)
        return self.agents
