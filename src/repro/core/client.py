"""The iCheck application library (paper Listing 1).

Maps 1:1 to the paper's API:

    icheck_init            -> ICheckClient.init
    icheck_add_adapt       -> ICheckClient.add_adapt / add_adapt_snapshot
    icheck_commit          -> ICheckClient.commit            (non-blocking)
    icheck_restart         -> ICheckClient.restart
    icheck_redistribute    -> ICheckClient.redistribute
    icheck_probe_agents    -> ICheckClient.probe_agents
    icheck_finalize        -> ICheckClient.finalize

"Since the agents use RDMA, the application does not need to block for data
transfer rather it can continue the execution immediately after notifying
the agents about the checkpoints." — ``commit`` therefore returns a
``CommitHandle`` immediately; a background completer thread drives the
transfers, retries stragglers, and finalises the checkpoint with the
controller.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import events as E
from . import plan as planlib
from ..obs import trace_id_for
from .agent import Agent, AgentDead
from .controller import Controller
from .tiers import (EncodedRegion, crc32, decode_payload, ec_encode_shard,
                    encode_delta_region, encode_payload, q8_chain_decode,
                    q8_repack_key, resolve_codec)
from .types import (AppId, CapacityError, CheckpointMeta, ICheckError,
                    PartitionDesc, PartitionScheme, RegionMeta, RestoreError,
                    ShardInfo, ShardKey)


class CommitHandle:
    """In-flight checkpoint: resolves once every shard is acked in L1."""

    def __init__(self, client: "ICheckClient", meta: CheckpointMeta,
                 puts: List[Tuple[ShardKey, bytes, Agent]], drain: bool,
                 trace=None, logical=None):
        self.client = client
        self.meta = meta
        self._puts = puts
        self._drain = drain
        # erasure-coded commits: base ShardKey -> (payload nbytes, crc32) of
        # the *logical* shard each fragment stripe encodes — recorded with
        # the catalog once every fragment is acked (fragments themselves
        # never appear in meta.shards; completeness stays base-key counted)
        self._logical = logical or {}
        # root TraceContext of this checkpoint's trace tree, captured on the
        # application thread and reinstated on the completer thread so the
        # agent puts / finalize / COMMIT_DONE all attach to the commit root
        self.trace = trace
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self.sim_duration = 0.0
        self.retries = 0

    # -- introspection ------------------------------------------------------
    @property
    def ckpt_id(self) -> int:
        return self.meta.ckpt_id

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> "CommitHandle":
        if not self._done.wait(timeout):
            raise TimeoutError(f"commit {self.meta.ckpt_id} still in flight")
        if self._error is not None:
            raise self._error
        return self

    # -- executed on the client's completer thread --------------------------
    def _complete(self) -> None:
        ctl = self.client.controller
        with ctl.tracer.use(self.trace):
            self._complete_traced(ctl)

    def _complete_traced(self, ctl) -> None:
        t0 = ctl.clock.now()
        per_node_sim: Dict[str, float] = {}
        try:
            frag_agent: Dict[ShardKey, str] = {}
            inflight = [(key, payload, agent, agent.put(key, payload))
                        for key, payload, agent in self._puts]
            for key, payload, agent, fut in inflight:
                rec = self._await_with_straggler_retry(key, payload, agent, fut)
                # agents on one node share its NIC: serialized-at-full-bw
                # time summed per NODE equals concurrent shared-bw time
                node = rec.agent_id.split("/")[0]
                per_node_sim[node] = per_node_sim.get(node, 0.0) \
                    + rec.sim_seconds
                if key.replica == 0:
                    ctl.record_shard(self.meta, ShardInfo(
                        key=key, nbytes=rec.nbytes, crc32=crc32(payload),
                        agent_id=rec.agent_id))
                elif key.base() in self._logical:
                    frag_agent.setdefault(key.base(), rec.agent_id)
            # one base-key ShardInfo per erasure stripe, carrying the
            # *logical* payload's size and crc (restores verify against it)
            for base, (nbytes, crc) in self._logical.items():
                ctl.record_shard(self.meta, ShardInfo(
                    key=base, nbytes=nbytes, crc32=crc,
                    agent_id=frag_agent.get(base, "")))
            # commit duration ≈ busiest NIC's total transfer time
            self.sim_duration = max(per_node_sim.values(), default=0.0)
            ctl.tracer.record(
                "l1_store", trace_id_for(self.meta.app_id, self.meta.ckpt_id),
                f"client/{self.meta.app_id}", t0=t0,
                dur_s=self.sim_duration, retries=self.retries)
            ctl.finalize_checkpoint(self.meta, drain=self._drain)
            self.client._last_commit_sim_s = self.sim_duration
            logical_bytes = (
                sum(n for n, _ in self._logical.values())
                + sum(len(p) for k, p, _ in self._puts if k.replica == 0))
            ctl.bus.publish(E.COMMIT_DONE, app=self.meta.app_id,
                            ckpt=self.meta.ckpt_id, step=self.meta.step,
                            bytes=logical_bytes,
                            sim_s=self.sim_duration, retries=self.retries)
        except BaseException as e:  # noqa: BLE001
            self._error = e
            # the catalog may hold delta-chain state referencing this
            # checkpoint's frames; marking it failed publishes CKPT_FAILED,
            # which resets the app's chains (next commit = keyframe)
            try:
                ctl.catalog.mark_failed(self.meta.app_id, self.meta.ckpt_id)
            except Exception:   # noqa: BLE001 - never mask the commit error
                pass
        finally:
            self._done.set()

    def _await_with_straggler_retry(self, key: ShardKey, payload: bytes,
                                    agent: Agent, fut: Future):
        """First-completion-wins re-issue of laggard transfers.

        Deadline comes from the controller's bandwidth prediction; on expiry
        (or agent death) the shard is re-put to the next healthy agent.
        Puts are idempotent, so a late original completing twice is harmless.
        """
        ctl = self.client.controller
        scale = max(ctl.clock.time_scale, 0.0)
        tried = {agent.agent_id}
        for _ in range(8):
            sim_deadline = ctl.transfer_deadline(len(payload), agent)
            wall_timeout = sim_deadline * scale + 2.0 if scale > 0 else 10.0
            try:
                return fut.result(timeout=wall_timeout)
            except AgentDead:
                pass
            except TimeoutError:
                self.retries += 1
            except ConnectionError:
                pass
            except CapacityError:
                # node full: controller asks the RM for another iCheck node
                # (paper SSIII-A), then we re-put to the grown agent set
                ctl.handle_capacity_pressure(key.app_id)
                tried.clear()
                tried.add(agent.agent_id)
            # pick a replacement agent
            candidates = [a for a in ctl.agents_for(key.app_id)
                          if a.agent_id not in tried] or ctl.agents_for(key.app_id)
            if not candidates:
                raise ICheckError(f"no live agents for {key}")
            agent = candidates[0]
            tried.add(agent.agent_id)
            fut = agent.put(key, payload)
        raise ICheckError(f"shard {key} could not be stored after retries")


class ResizeCutoverHandle:
    """Phase-1 handle of a zero-stall redistribution
    (``redistribute(..., overlap=True)``).

    While the handle is held, the application keeps stepping — and keeps
    committing — as the base checkpoint streams to the new partition in the
    background.  ``ready()`` flips once the stream landed and prefetches this
    client's wanted *base* parts (still overlap, not stall); ``cutover()``
    quiesces the window: the tail delta frames that accumulated meanwhile
    are replayed agent-side and only the changed value spans travel to the
    client, so the visible stall is bounded by one delta frame rather than
    the whole stream.

    Every failure shape degrades to the client funnel from the catalog head
    — bit-identical to a stop-the-world redistribution, just slower.
    """

    _FALLBACK_ERRORS = (ICheckError, ConnectionError, TimeoutError, KeyError)

    def __init__(self, client: "ICheckClient", name: str, window,
                 wanted: set, new_parts: int, part_shape, fallback,
                 trace_id: Optional[str] = None):
        self.client = client
        self.name = name
        self.window = window              # None = funnel-only degenerate
        self.trace_id = trace_id          # base checkpoint's trace tree
        self.wanted = set(wanted)
        self.new_parts = new_parts
        self._part_shape = part_shape
        self._fallback = fallback
        self._base: Optional[Dict[int, np.ndarray]] = None
        self._prefetch_s = 0.0
        self._prefetch_bytes = 0
        self._result: Optional[Dict[int, np.ndarray]] = None

    # -- phase 1 ------------------------------------------------------------
    def ready(self) -> bool:
        """True once the background stream resolved (the app may keep
        stepping until then — and after, right up to ``cutover()``)."""
        if self.window is None:
            return True
        if not self.window.ready():
            return False
        self._maybe_prefetch()
        return True

    def wait(self, timeout: Optional[float] = None) -> bool:
        if self.window is None:
            return True
        ok = self.window.wait(timeout)
        if ok:
            self._maybe_prefetch()
        return ok

    def _maybe_prefetch(self) -> None:
        """Pull the wanted parts' base payloads while still overlapped: at
        cutover only the replayed spans need to travel through the client."""
        if self._base is not None or self.window is None:
            return
        try:
            base: Dict[int, np.ndarray] = {}
            lane: Dict[str, float] = {}
            dtype = np.dtype(self.window.region.dtype)
            for dp, agent, out_key, fut, _ in self.window.jobs:
                if dp not in self.wanted:
                    continue
                if fut.exception() is not None:
                    return    # cutover will surface it as a funnel fallback
                payload = agent.get(out_key)
                self._prefetch_bytes += len(payload)
                lane[agent.node_id] = lane.get(agent.node_id, 0.0) \
                    + len(payload) / agent.nic.bandwidth + agent.nic.latency
                base[dp] = np.frombuffer(bytearray(payload), dtype=dtype)
            self._prefetch_s = max(lane.values(), default=0.0)
            self._base = base
        except Exception:   # noqa: BLE001 - prefetch is an optimisation only
            self._base = None

    # -- phase 2 ------------------------------------------------------------
    def cutover(self) -> Dict[int, np.ndarray]:
        """Quiesce-and-switch: returns the wanted parts at the catalog head.
        Idempotent; call after the last pre-switch commit has been acked."""
        if self._result is not None:
            return self._result
        client = self.client
        ctl = client.controller
        if self.window is None:
            self._result = self._fallback()
            return self._result
        try:
            results, stats, patches = ctl.cutover_redistribution(self.window)
        except self._FALLBACK_ERRORS as e:
            ctl.bus.publish(E.REDISTRIBUTION_FALLBACK, app=client.app_id,
                            region=self.name, reason=repr(e))
            ctl.abort_overlap_redistribution(self.window)
            self._result = self._fallback()
            return self._result
        try:
            out, stall_fetch_s, bytes_client = self._apply(results, stats,
                                                           patches)
        except self._FALLBACK_ERRORS as e:
            ctl.release_redistribution(results)
            ctl.bus.publish(E.REDISTRIBUTION_FALLBACK, app=client.app_id,
                            region=self.name, reason=repr(e))
            self._result = self._fallback()
            return self._result
        ctl.release_redistribution(results)
        overlap_s = stats["overlap_sim_s"] + self._prefetch_s
        stall_s = stats["stall_sim_s"] + stall_fetch_s
        if self.trace_id is not None:
            ctl.tracer.record("cutover", self.trace_id,
                              f"client/{client.app_id}", dur_s=stall_s,
                              region=self.name, overlap_s=overlap_s,
                              tail_frames=stats["tail_frames"],
                              rehydrated=stats["rehydrated"])
        client._publish_redistribution_done(
            self.name, self.new_parts, "peer", overlap_s + stall_s,
            bytes_client + self._prefetch_bytes, stats,
            overlap_sim_s=overlap_s, stall_s=stall_s,
            overlap_commits=stats["overlap_commits"],
            tail_frames=stats["tail_frames"],
            rehydrated=stats["rehydrated"],
            wall_sim_s=stats["wall_sim_s"],
            window_skew=stats["window_skew"])
        self._result = out
        return out

    def _apply(self, results, stats, patches
               ) -> Tuple[Dict[int, np.ndarray], float, int]:
        """Turn the caught-up scratch parts into the wanted arrays.  With a
        prefetched base and a tail replay, only the patch spans travel (the
        stall); a re-hydration — or a cutover without a prior ``ready()`` —
        fetches the parts whole."""
        dtype = np.dtype(self.window.region.dtype)
        fetch_lane: Dict[str, float] = {}
        bytes_client = 0
        out: Dict[int, np.ndarray] = {}
        if self._base is not None and not stats["rehydrated"]:
            for p in sorted(self.wanted):
                arr = self._base[p]
                agent, _, _ = results[p]
                for off, valbytes in (patches or {}).get(p, []):
                    vals = np.frombuffer(valbytes, dtype=dtype)
                    arr[off:off + vals.size] = vals
                    bytes_client += len(valbytes)
                    fetch_lane[agent.node_id] = \
                        fetch_lane.get(agent.node_id, 0.0) \
                        + len(valbytes) / agent.nic.bandwidth \
                        + agent.nic.latency
                out[p] = arr.reshape(self._part_shape(p))
        else:
            for p in sorted(self.wanted):
                agent, key, _ = results[p]
                payload = agent.get(key)
                bytes_client += len(payload)
                fetch_lane[agent.node_id] = \
                    fetch_lane.get(agent.node_id, 0.0) \
                    + len(payload) / agent.nic.bandwidth + agent.nic.latency
                out[p] = np.frombuffer(bytearray(payload), dtype=dtype) \
                    .reshape(self._part_shape(p))
        return out, max(fetch_lane.values(), default=0.0), bytes_client

    def cancel(self) -> None:
        """Abandon the window without switching (scratch is released; the
        app stays on its old partition)."""
        if self.window is not None and self._result is None:
            self.client.controller.abort_overlap_redistribution(self.window)


class ICheckClient:
    def __init__(self, app_id: AppId, controller: Controller, ranks: int = 1,
                 replication: int = 1, codec: str = "raw",
                 ckpt_interval_s: float = 60.0,
                 keyframe_every: Optional[int] = None,
                 durability: str = "replicate", ec_k: int = 4, ec_m: int = 1):
        if durability not in ("replicate", "ec"):
            raise ICheckError(
                f"durability must be 'replicate' or 'ec', got {durability!r}")
        self.app_id = app_id
        self.controller = controller
        self.ranks = ranks
        self.replication = max(1, replication)
        # erasure-coded L1 durability: each committed shard is scattered as
        # k data + m parity fragments with node anti-affinity instead of
        # whole-shard copies — any m losses survive at (k+m)/k memory.
        # Replication is forced to 1: the stripe IS the redundancy.
        self.ec: Optional[Tuple[int, int]] = None
        if durability == "ec":
            if ec_k < 1 or ec_m < 1:
                raise ICheckError(f"ec needs k >= 1 and m >= 1, got "
                                  f"k={ec_k} m={ec_m}")
            self.ec = (int(ec_k), int(ec_m))
            self.replication = 1
        # q8-delta keyframe cadence override (None = controller default):
        # a full q8 keyframe every K commits bounds restart replay length
        self.keyframe_every = keyframe_every
        # codec resolution is part of the tier pipeline now: a requested
        # codec this process can't run (e.g. zstd without zstandard) degrades
        # to "none" with an audit event instead of mis-labelling shards
        self.codec = resolve_codec(codec, on_degrade=lambda req, actual:
                                   controller.bus.publish(
                                       E.CODEC_DEGRADED, app=app_id,
                                       requested=req, actual=actual))
        self.ckpt_interval_s = ckpt_interval_s
        # adaptive loop: the IntervalController re-solves our cadence from
        # observed commit cost + failure rate; track its announcements so
        # application-side pacing (`ckpt_interval_s`) follows the solution
        self._unsub_interval = controller.bus.subscribe(
            self._on_interval_changed, events=(E.INTERVAL_CHANGED,))
        self.agents: List[Agent] = []
        self.regions: Dict[str, RegionMeta] = {}
        self._rr = 0
        self._last_commit_sim_s: Optional[float] = None
        self._commit_q: "queue.Queue[Optional[CommitHandle]]" = queue.Queue()
        self._completer = threading.Thread(target=self._completer_loop,
                                           daemon=True,
                                           name=f"icheck-client-{app_id}")
        self._completer.start()
        self._initialized = False

    # ------------------------------------------------------------- lifecycle
    def init(self, ckpt_bytes_estimate: int = 0) -> "ICheckClient":
        """icheck_init(): register with the controller, connect to agents."""
        self.agents = self.controller.register_app(
            self.app_id, self.ranks, ckpt_bytes_estimate=ckpt_bytes_estimate,
            ckpt_interval_s=self.ckpt_interval_s, replication=self.replication,
            ec=self.ec)
        if self.keyframe_every is not None:
            self.controller.set_delta_keyframe_every(self.app_id,
                                                     self.keyframe_every)
        self._initialized = True
        return self

    def _on_interval_changed(self, ev: E.Event) -> None:
        if ev.payload.get("app") == self.app_id:
            self.ckpt_interval_s = float(ev.payload["interval_s"])

    def finalize(self) -> None:
        """icheck_finalize()."""
        self._commit_q.put(None)
        self._completer.join(timeout=10)
        self._unsub_interval()
        self.controller.notify_finished(self.app_id)

    # ----------------------------------------------------------- add_adapt
    def add_adapt(self, name: str, shape: Sequence[int], dtype: str,
                  scheme: PartitionScheme = PartitionScheme.BLOCK,
                  axis: int = 0, num_parts: Optional[int] = None,
                  block: int = 1,
                  bounds: Optional[tuple] = None) -> RegionMeta:
        """icheck_add_adapt(): register a checkpointable array + its
        distribution mapping (used later for redistribution)."""
        shape = tuple(int(s) for s in shape)
        desc = PartitionDesc(scheme=scheme, axis=axis,
                             num_parts=num_parts or self.ranks, block=block,
                             bounds=bounds)
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize if shape else \
            np.dtype(dtype).itemsize
        meta = RegionMeta(name=name, shape=shape, dtype=str(np.dtype(dtype)),
                          partition=desc, nbytes=nbytes, codec=self.codec)
        self.regions[name] = meta
        self.controller.register_region(self.app_id, meta)
        return meta

    def add_adapt_snapshot(self, snap) -> None:
        """Register every region of a ``HostSnapshot`` (JAX pytree path)."""
        for name, sr in snap.regions.items():
            meta = sr.meta
            meta.codec = self.codec
            self.regions[name] = meta
            self.controller.register_region(self.app_id, meta)

    # ---------------------------------------------------------------- commit
    def commit(self, step: int,
               parts_by_region: Dict[str, Dict[int, np.ndarray]],
               userdata: bytes = b"", blocking: bool = False,
               drain: bool = True,
               encoded: Optional[Dict[str, EncodedRegion]] = None
               ) -> CommitHandle:
        """icheck_commit(): notify agents, return immediately.

        ``parts_by_region[name][part]`` is the local array of that part
        (what each application rank holds).  ``encoded`` carries regions
        whose wire frames were already produced device-side
        (:func:`repro.core.snapshot.snapshot_pytree` with a q8 codec) — the
        commit path then only threads chain bookkeeping, no re-encode.

        With ``codec="q8-delta"`` each float region travels as a sparse
        XOR-delta frame against the catalog's previous-codes state (full
        keyframe every K commits, after a chain reset, or when churn makes
        the delta no smaller than a keyframe).
        """
        if not self._initialized:
            raise ICheckError("call init() first")
        encoded = dict(encoded or {})
        overlap = set(encoded) & set(parts_by_region)
        if overlap:
            raise ICheckError(f"regions {sorted(overlap)} passed both raw "
                              f"and pre-encoded")
        ctl = self.controller
        metas: Dict[str, RegionMeta] = {}
        for name in (*parts_by_region, *encoded):
            if name not in self.regions:
                raise ICheckError(f"region {name!r} was not add_adapt()ed")
            meta = self.regions[name]
            n_given = len(parts_by_region[name]) if name in parts_by_region \
                else len(encoded[name].blobs)
            if n_given != meta.partition.num_parts:
                raise ICheckError(
                    f"region {name!r}: got {n_given} parts, expected "
                    f"{meta.partition.num_parts}")
            # a region restored from a manifest may carry a codec this
            # process can't run (e.g. zstd without zstandard): degrade it
            # here so the new shards and manifest stay self-consistent
            meta.codec = resolve_codec(
                meta.codec, on_degrade=lambda req, actual, name=name:
                ctl.bus.publish(E.CODEC_DEGRADED, app=self.app_id,
                                region=name, requested=req, actual=actual))
            if meta.codec == "q8-delta" or name in encoded:
                # per-commit copy: frame/chain bookkeeping belongs to this
                # checkpoint's manifest, not the shared registry meta
                metas[name] = dataclasses.replace(meta, frame=None,
                                                  chain=None)
            else:
                metas[name] = meta
        ckpt = ctl.new_checkpoint(self.app_id, step, metas, userdata=userdata)
        agents = ctl.agents_for(self.app_id)
        if not agents:
            raise ICheckError("no agents assigned")

        # root of this checkpoint's trace tree: every later phase (agent
        # puts, L2 drain, L3 trickle, a restore hours later) attaches here
        trace_id = trace_id_for(self.app_id, ckpt.ckpt_id)
        root_ctx = ctl.tracer.record("commit", trace_id,
                                     f"client/{self.app_id}", root=True,
                                     step=step, drain=drain)

        t_enc = time.monotonic()
        stats = {"raw": 0, "enc": 0, "key": 0, "delta": 0,
                 "encode_s": 0.0, "publish": False}
        payloads: Dict[str, Dict[int, bytes]] = {}
        try:
            for name, parts in parts_by_region.items():
                meta = metas[name]
                raw = {part: np.ascontiguousarray(arr).tobytes()
                       for part, arr in parts.items()}
                if meta.codec == "q8-delta":
                    payloads[name] = self._encode_delta_host(
                        ckpt.ckpt_id, meta, raw, stats)
                else:
                    blobs = {
                        part: encode_payload(data, meta.codec, meta.dtype)
                        for part, data in raw.items()}
                    if meta.codec == "q8":
                        # plain q8 feeds the same codec gauges (its ~4x
                        # ratio must not read as "codec did nothing")
                        stats["raw"] += sum(len(b) for b in raw.values())
                        stats["enc"] += sum(len(b) for b in blobs.values())
                        stats["publish"] = True
                    payloads[name] = blobs
            for name, enc in encoded.items():
                payloads[name] = self._adopt_encoded(ckpt.ckpt_id,
                                                     metas[name], enc, stats)
        except BaseException:
            # some chains may already reference this checkpoint's frames,
            # which will never be stored — reset so the next commit keyframes
            ctl.reset_delta_chains(self.app_id, reason="commit_encode_failed")
            raise
        stats["encode_s"] += time.monotonic() - t_enc
        ctl.tracer.record("encode", trace_id, f"client/{self.app_id}",
                          dur_s=stats["encode_s"], parent=root_ctx,
                          raw_bytes=stats["raw"],
                          encoded_bytes=stats["enc"])

        puts: List[Tuple[ShardKey, bytes, Agent]] = []
        logical: Dict[ShardKey, Tuple[int, int]] = {}
        if self.ec:
            k, m = self.ec
            ec_raw = 0
            ec_wire = 0
            for name, blobs in payloads.items():
                for part, payload in blobs.items():
                    frags = ec_encode_shard(payload, k, m)
                    # failure-domain anti-affinity: fragments of one stripe
                    # interleave across nodes, so any m agent/node losses
                    # leave >= k fragments standing
                    spread = ctl.placement.stripe_agents(
                        self.app_id, len(frags), rotation=self._rr)
                    for (rep, blob), agent in zip(frags, spread):
                        key = ShardKey(self.app_id, ckpt.ckpt_id, name,
                                       part, rep)
                        puts.append((key, blob, agent))
                    base = ShardKey(self.app_id, ckpt.ckpt_id, name, part)
                    logical[base] = (len(payload), crc32(payload))
                    ec_raw += len(payload)
                    ec_wire += sum(len(b) for _, b in frags)
                    self._rr += 1
            ctl.bus.publish(E.EC_STRIPE_COMMITTED, app=self.app_id,
                            ckpt=ckpt.ckpt_id, k=k, m=m, stripes=len(logical),
                            logical_bytes=ec_raw, fragment_bytes=ec_wire)
        else:
            for name, blobs in payloads.items():
                for part, payload in blobs.items():
                    for rep in range(self.replication):
                        key = ShardKey(self.app_id, ckpt.ckpt_id, name, part,
                                       rep)
                        agent = agents[(self._rr + rep) % len(agents)]
                        puts.append((key, payload, agent))
                    self._rr += 1
        if stats["publish"]:
            ctl.bus.publish(E.CKPT_DELTA_COMMITTED, app=self.app_id,
                            ckpt=ckpt.ckpt_id, raw_bytes=stats["raw"],
                            encoded_bytes=stats["enc"],
                            key_frames=stats["key"],
                            delta_frames=stats["delta"],
                            encode_s=stats["encode_s"])
        handle = CommitHandle(self, ckpt, puts, drain=drain, trace=root_ctx,
                              logical=logical)
        self._commit_q.put(handle)
        if blocking:
            handle.wait(timeout=120)
        return handle

    def _encode_delta_host(self, ckpt_id: int, meta: RegionMeta,
                           raw: Dict[int, bytes], stats: dict
                           ) -> Dict[int, bytes]:
        """Host-side q8-delta encode of one region + chain advance."""
        ctl = self.controller
        rc = ctl.delta_chain(self.app_id, meta.name,
                             meta.partition.num_parts)
        blobs, states, frame = encode_delta_region(
            raw, meta.dtype, rc.parts if rc is not None else None)
        blobs, meta.frame, meta.chain = self._advance_or_keyframe(
            ckpt_id, meta.name, blobs, states, frame)
        stats["raw"] += sum(len(b) for b in raw.values())
        stats["enc"] += sum(len(b) for b in blobs.values())
        stats[meta.frame] += 1
        stats["publish"] = True
        return blobs

    def _advance_or_keyframe(self, ckpt_id: int, name: str,
                             blobs: Dict[int, bytes], states, frame: str):
        """Advance the catalog chain; if a background reset (demotion,
        failure, resize) raced the encode and the chain is gone, re-frame
        the carried codes as a self-contained keyframe instead of failing
        the commit."""
        ctl = self.controller
        if frame == "delta":
            try:
                chain = ctl.advance_delta_chain(self.app_id, ckpt_id, name,
                                                states, "delta")
                return blobs, "delta", chain
            except ICheckError:
                blobs = q8_repack_key(states)
                frame = "key"
        chain = ctl.advance_delta_chain(self.app_id, ckpt_id, name, states,
                                        frame)
        return blobs, frame, chain

    def _adopt_encoded(self, ckpt_id: int, meta: RegionMeta,
                       enc: EncodedRegion, stats: dict) -> Dict[int, bytes]:
        """Thread a device-encoded region's frames into this commit."""
        ctl = self.controller
        if enc.codec != meta.codec:
            raise ICheckError(
                f"region {meta.name!r}: encoded as {enc.codec!r} but "
                f"registered codec is {meta.codec!r}")
        if enc.codec == "q8-delta":
            blobs, frame = enc.blobs, enc.frame
            if frame == "delta":
                rc = ctl.delta_chain(self.app_id, meta.name,
                                     meta.partition.num_parts)
                if rc is None or (enc.parent_chain is not None
                                  and rc.chain != enc.parent_chain):
                    # the chain moved or reset between snapshot-encode and
                    # commit (e.g. a resize registered new boxes): the delta
                    # frames are useless, but the carried states hold the
                    # full codes — re-frame as a self-contained keyframe
                    blobs, frame = q8_repack_key(enc.states), "key"
            blobs, meta.frame, meta.chain = self._advance_or_keyframe(
                ckpt_id, meta.name, blobs, enc.states, frame)
            stats[meta.frame] += 1
            enc = dataclasses.replace(enc, blobs=blobs, frame=meta.frame)
        # q8 and q8-delta both feed the codec gauges (device path included)
        stats["publish"] = True
        stats["raw"] += enc.raw_nbytes
        stats["enc"] += sum(len(b) for b in enc.blobs.values())
        stats["encode_s"] += enc.encode_s
        return enc.blobs

    def commit_snapshot(self, snap, extra_parts: Optional[Dict] = None,
                        userdata: bytes = b"", blocking: bool = False,
                        drain: bool = True) -> CommitHandle:
        """Commit a :class:`~repro.core.snapshot.HostSnapshot` whose regions
        were encoded *on device* (``snapshot_pytree(codec=...)``): the
        client→agent fabric and every storage tier move the int8 frames the
        D2H copy already produced.  ``extra_parts`` adds plain host-side
        regions (e.g. a data-iterator cursor)."""
        self.add_adapt_snapshot(snap)
        encoded = {name: sr.encoded for name, sr in snap.regions.items()
                   if sr.encoded is not None}
        parts = {name: sr.parts for name, sr in snap.regions.items()
                 if sr.encoded is None}
        parts.update(extra_parts or {})
        return self.commit(snap.step, parts, userdata=userdata,
                           blocking=blocking, drain=drain, encoded=encoded)

    def delta_chain_lookup(self, name: str, num_parts: int):
        """Previous-codes state for a device-side delta encode (or None when
        the next frame of ``name`` must be a keyframe)."""
        return self.controller.delta_chain(self.app_id, name, num_parts)

    def _completer_loop(self) -> None:
        while True:
            handle = self._commit_q.get()
            if handle is None:
                return
            handle._complete()

    # --------------------------------------------------------------- restart
    def _fetch_decoded(self, region: RegionMeta, ckpt_id: int, part: int,
                       stats: Optional[dict] = None) -> bytes:
        """Fetch + decode one region part, replaying the delta chain
        (keyframe → deltas) for ``q8-delta`` regions.  ``stats`` (when
        given) accumulates the wire bytes that flowed through this client —
        the redistribution funnel's bytes-through-client accounting."""
        if region.codec != "q8-delta":
            blob = self.controller.fetch_shard(self.app_id, ckpt_id,
                                               region.name, part)
            if stats is not None:
                stats["wire_bytes"] += len(blob)
            return decode_payload(blob, region.codec, region.dtype)
        chain = region.chain or (ckpt_id,)
        blobs = []
        for cid in chain:
            try:
                blobs.append(self.controller.fetch_shard(
                    self.app_id, cid, region.name, part))
            except KeyError as e:
                raise RestoreError(
                    f"delta chain of {region.name!r} part {part} is broken: "
                    f"frame ckpt={cid} is gone (chain {chain})") from e
            if stats is not None:
                stats["wire_bytes"] += len(blobs[-1])
        return q8_chain_decode(blobs, region.dtype)

    def _ckpt_region(self, ckpt_id: int, name: str) -> RegionMeta:
        """The per-checkpoint RegionMeta (carries frame/chain) when known;
        falls back to the registry meta."""
        try:
            app = self.controller.app(self.app_id)
            meta = app.checkpoints.get(ckpt_id)
            if meta is not None and name in meta.regions:
                return meta.regions[name]
        except KeyError:
            pass
        return self.regions[name]

    def restart(self) -> Optional[Tuple[CheckpointMeta, Dict[str, Dict[int, np.ndarray]], str]]:
        """icheck_restart(): newest usable checkpoint → (meta, parts, level).

        Returns None when no checkpoint exists (fresh start, paper line 7-9).
        ``q8-delta`` checkpoints replay keyframe + deltas — bit-identical to
        restoring a full q8 frame of the same commit; a missing or corrupt
        chain link raises :class:`RestoreError` instead of decoding garbage.
        """
        found = self.controller.latest_restartable(self.app_id)
        if found is None:
            return None
        meta, level = found
        ctl = self.controller
        t0 = ctl.clock.now()
        out: Dict[str, Dict[int, np.ndarray]] = {}
        # the restore span re-joins the checkpoint's trace tree by id alone
        # (the commit may be hours old; no context survived to here)
        with ctl.tracer.span("restore",
                             trace_id_for(self.app_id, meta.ckpt_id),
                             f"client/{self.app_id}", tier=level):
            for name, region in meta.regions.items():
                parts: Dict[int, np.ndarray] = {}
                for part in range(region.partition.num_parts):
                    payload = self._fetch_decoded(region, meta.ckpt_id, part)
                    arr = np.frombuffer(bytearray(payload),
                                        dtype=np.dtype(region.dtype))
                    parts[part] = arr.reshape(self._part_shape(region, part))
                out[name] = parts
                # refresh the client-side region registry from the manifest
                # (scrubbed of this checkpoint's frame/chain bookkeeping)
                registry = dataclasses.replace(region, frame=None, chain=None)
                self.regions[name] = registry
                self.controller.register_region(self.app_id, registry)
            ctl.bus.publish(E.RESTORE_DONE, app=self.app_id,
                            ckpt=meta.ckpt_id, tier=level,
                            sim_s=max(ctl.clock.now() - t0, 0.0))
        return meta, out, level

    def _part_shape(self, region: RegionMeta, part: int) -> Tuple[int, ...]:
        desc = region.partition
        if desc.scheme == PartitionScheme.MESH:
            return tuple(hi - lo for lo, hi in desc.bounds[part])
        return planlib.local_shape(region.shape, desc, part)

    # ---------------------------------------------------------- redistribute
    def _resolve_redistribution_ckpt(self, ckpt_id: Optional[int]) -> int:
        if ckpt_id is not None:
            return ckpt_id
        found = self.controller.latest_restartable(self.app_id)
        if found is None:
            raise ICheckError("nothing to redistribute from")
        return found[0].ckpt_id

    def _fetch_source_parts(self, name: str, ckpt_id: int,
                            parts: Sequence[int],
                            stats: Optional[dict] = None
                            ) -> Dict[int, np.ndarray]:
        """Shared fetch+decode+reshape block of the client-funnel paths
        (1-d and mesh): pull whole source shards through this client."""
        region = self.regions[name]
        ckpt_region = self._ckpt_region(ckpt_id, name)
        src_parts: Dict[int, np.ndarray] = {}
        for sp in parts:
            payload = self._fetch_decoded(ckpt_region, ckpt_id, sp, stats)
            src_parts[sp] = np.frombuffer(bytearray(payload),
                                          dtype=np.dtype(region.dtype)) \
                .reshape(self._part_shape(region, sp))
        return src_parts

    def _publish_redistribution_done(self, name: str, new_parts: int,
                                     via: str, sim_s: float,
                                     bytes_through_client: int,
                                     stats: Optional[dict] = None,
                                     **extra) -> None:
        """``extra`` carries the zero-stall payload (overlap_sim_s, stall_s,
        overlap_commits, tail_frames, rehydrated, wall/skew) when the
        window ran two-phase."""
        stats = stats or {}
        self.controller.bus.publish(
            E.REDISTRIBUTION_DONE, app=self.app_id, region=name,
            new_parts=new_parts, via=via, sim_s=sim_s,
            bytes_moved=stats.get("bytes_moved", bytes_through_client),
            bytes_through_client=bytes_through_client,
            peer_hops=stats.get("peer_hops", 0),
            cross_reads=stats.get("cross_reads", 0),
            intra_reads=stats.get("intra_reads", 0),
            tier_reads=stats.get("tier_reads", 0), **extra)

    def _try_peer(self, name: str, ckpt_id: int, programs_fn, wanted: set,
                  new_parts: int, part_shape
                  ) -> Optional[Dict[int, np.ndarray]]:
        """Shared peer attempt of both redistribution flavours: compile (or
        look up) the programs and run them agent→agent.  Returns None —
        after publishing ``redistribution_fallback`` — when the client
        funnel must take over (unsupported layout, agent death
        mid-transfer, lost source shard)."""
        ctl = self.controller
        try:
            programs = programs_fn()
            if programs is None or len(programs) <= 1:
                # a single destination part (e.g. gathering onto one
                # replicated box) has no peer concurrency to exploit —
                # assembling it on an agent and re-fetching it would only
                # add a round trip on top of the funnel
                ctl.bus.publish(E.REDISTRIBUTION_FALLBACK, app=self.app_id,
                                region=name,
                                reason="unsupported_layout"
                                if programs is None
                                else "single_destination")
                return None
            return self._peer_redistribute(name, ckpt_id, programs, wanted,
                                           new_parts, part_shape)
        except (ICheckError, ConnectionError, TimeoutError, KeyError) as e:
            ctl.bus.publish(E.REDISTRIBUTION_FALLBACK, app=self.app_id,
                            region=name, reason=repr(e))
            return None

    def _peer_redistribute(self, name: str, ckpt_id: int, programs,
                           wanted: set, new_parts: int,
                           part_shape) -> Dict[int, np.ndarray]:
        """Peer path: agents execute the pre-staged transfer programs among
        themselves; this client only dispatches, then fetches the parts its
        local new ranks own.  The adapt-window time is the engine's analytic
        transfer window plus the (concurrent-across-ranks, so max-per-node)
        fetch of the wanted parts."""
        ctl = self.controller
        region = self._ckpt_region(ckpt_id, name)
        t0 = ctl.clock.now()
        results, stats = ctl.execute_redistribution(self.app_id, region,
                                                    ckpt_id, programs)
        try:
            out: Dict[int, np.ndarray] = {}
            fetch_lane: Dict[str, float] = {}
            bytes_client = 0
            for p in sorted(wanted):
                agent, key, _ = results[p]
                payload = agent.get(key)
                bytes_client += len(payload)
                fetch_lane[agent.node_id] = fetch_lane.get(agent.node_id, 0.0) \
                    + len(payload) / agent.nic.bandwidth + agent.nic.latency
                out[p] = np.frombuffer(bytearray(payload),
                                       dtype=np.dtype(region.dtype)) \
                    .reshape(part_shape(p))
        finally:
            ctl.release_redistribution(results)
        sim_s = stats["sim_s"] + max(fetch_lane.values(), default=0.0)
        ctl.tracer.record("redistribute_peer",
                          trace_id_for(self.app_id, ckpt_id),
                          f"client/{self.app_id}", t0=t0, dur_s=sim_s,
                          region=name, new_parts=new_parts)
        self._publish_redistribution_done(
            name, new_parts, "peer", sim_s, bytes_client, stats,
            wall_sim_s=stats.get("wall_sim_s", 0.0),
            window_skew=stats.get("window_skew", 1.0))
        return out

    def _funnel_1d(self, name: str, new_num_parts: int, wanted: set,
                   ckpt_id: Optional[int] = None) -> Dict[int, np.ndarray]:
        """The legacy gather-through-the-client funnel for 1-d (BLOCK/
        CYCLIC) regions.  ``ckpt_id=None`` resolves the catalog head at call
        time — the overlap fallback path relies on that, because by cutover
        time the head has moved past the base the window streamed."""
        ctl = self.controller
        region = self.regions[name]
        old = region.partition
        new = old.renumbered(new_num_parts)
        moves = ctl.plan_for_resize(self.app_id, name, new_num_parts)
        ckpt_id = self._resolve_redistribution_ckpt(ckpt_id)
        t0 = ctl.clock.now()
        stats = {"wire_bytes": 0}
        sub_moves = [mv for mv in moves if mv.dst in wanted]
        needed_src = sorted({mv.src for mv in sub_moves})
        src_parts = self._fetch_source_parts(name, ckpt_id, needed_src,
                                             stats)
        dst = planlib.apply_moves(src_parts, sub_moves, old, new,
                                  region.shape)
        result = {p: dst[p] for p in wanted}
        ctl.tracer.record("redistribute_funnel",
                          trace_id_for(self.app_id, ckpt_id),
                          f"client/{self.app_id}", t0=t0,
                          dur_s=ctl.clock.now() - t0, region=name,
                          new_parts=new_num_parts)
        self._publish_redistribution_done(name, new_num_parts, "client",
                                          ctl.clock.now() - t0,
                                          stats["wire_bytes"])
        return result

    def _begin_overlap(self, name: str, ckpt_id: int, programs_fn,
                       wanted: set, new_parts: int, part_shape,
                       fallback) -> ResizeCutoverHandle:
        """Open phase 1 of a zero-stall redistribution and wrap it in a
        :class:`ResizeCutoverHandle`.  Unlike the stop-the-world peer path,
        a single-destination program is still worth overlapping — its extra
        round trip hides inside the window instead of stretching it."""
        ctl = self.controller
        region = self._ckpt_region(ckpt_id, name)
        trace_id = trace_id_for(self.app_id, ckpt_id)
        window = None
        try:
            programs = programs_fn()
            if programs is None:
                ctl.bus.publish(E.REDISTRIBUTION_FALLBACK, app=self.app_id,
                                region=name, reason="unsupported_layout")
            else:
                window = ctl.begin_overlap_redistribution(
                    self.app_id, region, ckpt_id, programs)
                ctl.tracer.record("overlap_open", trace_id,
                                  f"client/{self.app_id}", region=name,
                                  new_parts=new_parts)
        except ResizeCutoverHandle._FALLBACK_ERRORS as e:
            ctl.bus.publish(E.REDISTRIBUTION_FALLBACK, app=self.app_id,
                            region=name, reason=repr(e))
        return ResizeCutoverHandle(self, name, window, wanted, new_parts,
                                   part_shape, fallback, trace_id=trace_id)

    def redistribute(self, name: str, new_num_parts: int,
                     ckpt_id: Optional[int] = None,
                     parts_needed: Optional[Sequence[int]] = None,
                     via: str = "peer", overlap: bool = False):
        """icheck_redistribute(): build the *new* distribution's parts from
        the latest checkpoint, moving only the slices each new part needs
        (paper §III-B; BLOCK/CYCLIC preserved, part count changes).

        ``via="peer"`` (default) executes the pre-staged transfer programs
        agent→agent — only the parts in ``parts_needed`` (the local new
        ranks') flow through this client.  ``via="client"`` forces the
        legacy gather-through-the-client funnel, which is also the automatic
        fallback when the peer engine cannot run (unsupported layout, agent
        death mid-transfer, lost source shard).

        ``overlap=True`` (peer only) returns a :class:`ResizeCutoverHandle`
        immediately instead of blocking for the adapt window: the base
        checkpoint streams in the background while the caller keeps
        stepping/committing, and ``handle.cutover()`` later returns the
        wanted parts caught up to the catalog head.
        """
        if via not in ("peer", "client"):
            raise ICheckError(f"unknown redistribution path via={via!r}")
        if overlap and via != "peer":
            raise ICheckError("overlap resize requires via='peer'")
        region = self.regions[name]
        old = region.partition
        if old.scheme == PartitionScheme.MESH:
            raise ICheckError("use redistribute_mesh for mesh regions")
        new = old.renumbered(new_num_parts)
        self.controller.plan_for_resize(self.app_id, name, new_num_parts)
        ckpt_id = self._resolve_redistribution_ckpt(ckpt_id)
        wanted = set(parts_needed) if parts_needed is not None \
            else set(range(new_num_parts))
        ctl = self.controller
        ctl.bus.publish(E.REDISTRIBUTION_STARTED, app=self.app_id,
                        region=name, new_parts=new_num_parts, ckpt=ckpt_id,
                        via=via, overlap=overlap)
        part_shape = lambda p: planlib.local_shape(region.shape, new, p)  # noqa: E731
        programs_fn = lambda: ctl.transfer_programs(self.app_id, name,  # noqa: E731
                                                    new_num_parts)
        if overlap:
            return self._begin_overlap(
                name, ckpt_id, programs_fn, wanted, new_num_parts,
                part_shape,
                fallback=lambda: self._funnel_1d(name, new_num_parts,
                                                 wanted))
        if via == "peer":
            out = self._try_peer(name, ckpt_id, programs_fn, wanted,
                                 new_num_parts, part_shape)
            if out is not None:
                return out
        # client funnel (forced, unsupported layout, or peer failure)
        return self._funnel_1d(name, new_num_parts, wanted, ckpt_id)

    def commit_redistribution(self, name: str, new_num_parts: int) -> None:
        """MPI_Comm_adapt_commit side-effect: region now has the new mapping.

        Registers a *new* RegionMeta (the registry may alias the
        controller's copy — mutating in place would hide the partition
        change from the catalog's mandatory delta-chain reset and from the
        resize planner's plan/program cache invalidation)."""
        old = self.regions[name]
        region = dataclasses.replace(
            old, partition=old.partition.renumbered(new_num_parts))
        self.regions[name] = region
        self.controller.register_region(self.app_id, region)

    def _funnel_mesh(self, name: str, new_boxes: tuple, wanted: set,
                     ckpt_id: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Client funnel for mesh regions (``ckpt_id=None`` = catalog head
        at call time, see :meth:`_funnel_1d`)."""
        ctl = self.controller
        region = self.regions[name]
        moves = planlib.mesh_moves(region.partition.bounds, new_boxes)
        ckpt_id = self._resolve_redistribution_ckpt(ckpt_id)
        t0 = ctl.clock.now()
        stats = {"wire_bytes": 0}
        sub_moves = [mv for mv in moves if mv.dst in wanted]
        needed_src = sorted({mv.src for mv in sub_moves})
        src_parts = self._fetch_source_parts(name, ckpt_id, needed_src,
                                             stats)
        dst = planlib.apply_mesh_moves(src_parts, sub_moves, new_boxes,
                                       np.dtype(region.dtype))
        result = {p: dst[p] for p in wanted}
        ctl.tracer.record("redistribute_funnel",
                          trace_id_for(self.app_id, ckpt_id),
                          f"client/{self.app_id}", t0=t0,
                          dur_s=ctl.clock.now() - t0, region=name,
                          new_parts=len(new_boxes))
        self._publish_redistribution_done(name, len(new_boxes), "client",
                                          ctl.clock.now() - t0,
                                          stats["wire_bytes"])
        return result

    def redistribute_mesh(self, name: str, new_boxes: Sequence[planlib.Box],
                          ckpt_id: Optional[int] = None,
                          parts_needed: Optional[Sequence[int]] = None,
                          via: str = "peer", overlap: bool = False):
        """Mesh-sharded (JAX) variant: old boxes from the region registry,
        new boxes from the target sharding.  Same peer-first execution as
        :meth:`redistribute` — pass ``parts_needed`` (the local new ranks'
        shard indices) so only those parts flow through this client; mesh
        programs are compiled at adapt time because only the application
        knows the new mesh's boxes.  ``overlap=True`` returns a
        :class:`ResizeCutoverHandle` (see :meth:`redistribute`)."""
        if via not in ("peer", "client"):
            raise ICheckError(f"unknown redistribution path via={via!r}")
        if overlap and via != "peer":
            raise ICheckError("overlap resize requires via='peer'")
        region = self.regions[name]
        if region.partition.scheme != PartitionScheme.MESH:
            raise ICheckError(f"{name} is not a mesh region")
        old_boxes = region.partition.bounds
        new_boxes = tuple(new_boxes)
        ckpt_id = self._resolve_redistribution_ckpt(ckpt_id)
        wanted = set(parts_needed) if parts_needed is not None \
            else set(range(len(new_boxes)))
        ctl = self.controller
        ctl.bus.publish(E.REDISTRIBUTION_STARTED, app=self.app_id,
                        region=name, new_parts=len(new_boxes), ckpt=ckpt_id,
                        via=via, overlap=overlap)
        part_shape = lambda p: tuple(hi - lo for lo, hi in new_boxes[p])  # noqa: E731
        programs_fn = lambda: planlib.compile_mesh_transfer_programs(  # noqa: E731
            old_boxes, new_boxes)
        if overlap:
            return self._begin_overlap(
                name, ckpt_id, programs_fn, wanted, len(new_boxes),
                part_shape,
                fallback=lambda: self._funnel_mesh(name, new_boxes, wanted))
        if via == "peer":
            out = self._try_peer(name, ckpt_id, programs_fn, wanted,
                                 len(new_boxes), part_shape)
            if out is not None:
                return out
        return self._funnel_mesh(name, new_boxes, wanted, ckpt_id)

    # ---------------------------------------------------------- probe_agents
    def probe_agents(self) -> List[Agent]:
        """icheck_probe_agents(): let the controller re-tune our agent set."""
        self.agents = self.controller.probe_agents(self.app_id,
                                                   self._last_commit_sim_s)
        return self.agents
