"""iCheck Managers.

"The manager manages the node-level activities of the software, such as
launching the agents and monitoring and predicting the node usage parameters
(e.g., memory usage, bandwidth usage)." (§II)

One Manager per iCheck node.  It owns the node's storage tiers — a
``TierPipeline`` of checkpoint RAM (``MemoryTier``, L1) plus an optional
node-local disk spill (``LocalDiskTier``, L0.5) — and NIC (``SimNIC``),
launches/stops agents on request from the controller, and keeps EWMA
predictors of memory and bandwidth usage that the controller's scheduling
policies consume.
"""
from __future__ import annotations

import itertools
import tempfile
import threading
from typing import Dict, List, Optional

from . import events as E
from .agent import Agent
from .simnet import EWMA, FaultInjector, MemBus, SimClock, SimNIC
from .tiers import LocalDiskTier, MemoryTier, TierPipeline
from .types import AgentId, AppId, NodeSpec


class Manager:
    def __init__(self, spec: NodeSpec, clock: Optional[SimClock] = None,
                 fault: Optional[FaultInjector] = None, bus=None,
                 spill_bytes: int = 0, spill_dir: Optional[str] = None,
                 fence=None):
        self.spec = spec
        self.node_id = spec.node_id
        self.clock = clock or SimClock()
        self.fault = fault or FaultInjector()
        self.bus = bus
        # controller epoch fence: agents launched here stamp + validate ops
        self.fence = fence
        tiers = [MemoryTier(spec.memory_bytes)]
        if spill_bytes > 0:
            root = spill_dir or tempfile.mkdtemp(
                prefix=f"icheck-spill-{spec.node_id}-")
            tiers.append(LocalDiskTier(root, spill_bytes))
        self.store = TierPipeline(tiers, bus=bus, node_id=spec.node_id)
        self.nic = SimNIC(f"nic-{spec.node_id}", spec.nic_bandwidth,
                          spec.nic_latency, clock=self.clock)
        # intra-node peer-redistribution copies bypass the NIC on this bus
        self.membus = MemBus(f"mem-{spec.node_id}", spec.mem_bandwidth,
                             clock=self.clock)
        # node death must sever transport, not just liveness: the injector
        # downs both links when kill_node() fires
        self.fault.register_transport(self.node_id, self.nic, self.membus)
        self._agents: Dict[AgentId, Agent] = {}
        self._agent_apps: Dict[AgentId, AppId] = {}
        self._lock = threading.Lock()
        self._agent_seq = itertools.count()
        self.mem_ewma = EWMA(alpha=0.3)
        self.bw_ewma = EWMA(alpha=0.3)
        # adaptive loop: per-app checkpoint duty cycle (commit cost over the
        # solved interval) announced by the IntervalController; the manager
        # folds the duty of the apps *it serves* into its bandwidth
        # prediction so placement steers new agents away from NICs that the
        # retuned cadence is about to keep busy
        self._app_duty: Dict[AppId, float] = {}
        self._unsub_interval = bus.subscribe(
            self._on_interval_changed, events=(E.INTERVAL_CHANGED,)) \
            if bus is not None else None

    # ----------------------------------------------------------------- agents
    def launch_agent(self, app_id: AppId) -> Agent:
        """Paper §II step 4: managers launch agents and notify the controller."""
        with self._lock:
            if len(self._agents) >= self.spec.max_agents:
                raise RuntimeError(f"node {self.node_id} at max_agents")
            agent_id = f"{self.node_id}/a{next(self._agent_seq)}"
            # the bus's TraceCollector (when wired) rides into every agent
            # so inbox ops carry and reinstate the submitter's context
            agent = Agent(agent_id, self.node_id, self.store, self.nic,
                          self.fault, membus=self.membus,
                          tracer=getattr(self.bus, "tracer", None),
                          fence=self.fence, bus=self.bus)
            self._agents[agent_id] = agent
            self._agent_apps[agent_id] = app_id
        return agent

    def stop_agent(self, agent_id: AgentId) -> None:
        with self._lock:
            agent = self._agents.pop(agent_id, None)
            self._agent_apps.pop(agent_id, None)
        if agent is not None:
            agent.stop()

    def agents(self) -> List[Agent]:
        with self._lock:
            return list(self._agents.values())

    def agent(self, agent_id: AgentId) -> Optional[Agent]:
        with self._lock:
            return self._agents.get(agent_id)

    def agent_ids_for(self, app_id: AppId) -> List[AgentId]:
        """Agents on this node currently serving ``app_id`` (recovery uses
        this to rebuild app→agent assignments from live managers)."""
        with self._lock:
            return [aid for aid, app in self._agent_apps.items()
                    if app == app_id]

    # ----------------------------------------------------------------- health
    def alive(self) -> bool:
        return not self.fault.node_dead(self.node_id)

    def heartbeat(self) -> Optional[dict]:
        """Metrics snapshot, or None if the node is dead (missed heartbeat)."""
        if not self.alive():
            return None
        used = self.store.used_bytes
        self.mem_ewma.update(used)
        busy = self.nic.stats()["busy_sim_seconds"]
        self.bw_ewma.update(self.nic.active_streams)
        return {
            "node_id": self.node_id,
            "mem_used": used,
            "mem_free": self.store.free_bytes,
            "mem_pred": self.mem_ewma.predict(),
            "nic_active": self.nic.active_streams,
            "nic_busy_sim_s": busy,
            "n_agents": len(self._agents),
            "ckpt_duty_pred": self.ckpt_duty_pred(),
            "tiers": self.tier_occupancy(),
        }

    def tier_occupancy(self) -> List[dict]:
        """Per-tier fill levels — the watermark policy's per-node signal."""
        rows = []
        for tier in self.store.tiers:
            cap = tier.capacity
            used = tier.used_bytes
            bounded = cap not in (None, 0) and cap != float("inf")
            rows.append({
                "tier": tier.name,
                "used_bytes": used,
                "capacity_bytes": cap if bounded else 0,
                "occupancy": used / cap if bounded else 0.0,
            })
        return rows

    # ------------------------------------------------------- adaptive hints
    def _on_interval_changed(self, ev) -> None:
        p = ev.payload
        interval = max(float(p.get("interval_s", 0.0)), 1e-9)
        with self._lock:
            self._app_duty[p["app"]] = \
                float(p.get("commit_cost_s", 0.0)) / interval

    def ckpt_duty_pred(self) -> float:
        """Predicted NIC duty from the solved cadences of apps served here."""
        with self._lock:
            served = set(self._agent_apps.values())
            return sum(self._app_duty.get(a, 0.0) for a in served)

    # predicted headroom used by policies
    def predicted_free_memory(self) -> float:
        return self.spec.memory_bytes - max(self.store.used_bytes,
                                            self.mem_ewma.predict())

    def predicted_bw_load(self) -> float:
        return self.bw_ewma.predict() + self.ckpt_duty_pred()

    def close(self) -> None:
        if self._unsub_interval is not None:
            self._unsub_interval()
        for a in self.agents():
            a.stop()
        self.store.close()
