"""Agent-scheduling policies.

"The controller ... performs the agent and node selection for connected
applications based on the iCheck agent scheduling policies.  These policies
consider various system metrics (available memory, checkpoint frequency and
size, and bandwidth usage) and can impact the overall checkpointing
performance." (§II)

A policy maps (node states, application requirements) → placement: a list of
(node_id, n_agents).  ``StaticPolicy`` is the non-adaptive baseline the paper
positions itself against (fixed resources, as in SCR/CRAFT-class libraries).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from .manager import Manager
from .types import AppRecord

Placement = List[Tuple[str, int]]           # [(node_id, n_agents)]


@dataclasses.dataclass
class NodeView:
    """What policies are allowed to see about a node."""

    node_id: str
    free_memory: float
    nic_bandwidth: float
    bw_load: float          # predicted concurrent streams
    n_agents: int
    max_agents: int

    @classmethod
    def of(cls, m: Manager) -> "NodeView":
        return cls(node_id=m.node_id,
                   free_memory=m.predicted_free_memory(),
                   nic_bandwidth=m.nic.bandwidth,
                   bw_load=m.predicted_bw_load(),
                   n_agents=len(m.agents()),
                   max_agents=m.spec.max_agents)


class SchedulingPolicy:
    name = "base"

    def place(self, nodes: Sequence[NodeView], app: AppRecord) -> Placement:
        raise NotImplementedError

    # how many agents an app *should* have given its checkpoint demand:
    # enough aggregate NIC bandwidth that a full commit (size × replication)
    # finishes well inside the checkpoint interval.
    @staticmethod
    def target_agent_count(app: AppRecord, nic_bw: float, max_agents: int = 8,
                           headroom: float = 4.0) -> int:
        demand = app.demand_bytes_per_s() * headroom
        if demand <= 0 or nic_bw <= 0:
            return 1
        return max(1, min(max_agents, math.ceil(demand / nic_bw)))


class StaticPolicy(SchedulingPolicy):
    """Non-adaptive baseline: always n agents on the first usable node."""

    name = "static"

    def __init__(self, n_agents: int = 1):
        self.n_agents = n_agents

    def place(self, nodes: Sequence[NodeView], app: AppRecord) -> Placement:
        for nv in nodes:
            if nv.n_agents + self.n_agents <= nv.max_agents:
                return [(nv.node_id, self.n_agents)]
        raise RuntimeError("no node can host agents")


class MemoryAwarePolicy(SchedulingPolicy):
    """Prefer nodes with the most predicted free memory; one node."""

    name = "memory"

    def place(self, nodes: Sequence[NodeView], app: AppRecord) -> Placement:
        need = app.ckpt_bytes_estimate * app.replication
        ranked = sorted(nodes, key=lambda nv: -nv.free_memory)
        n = self.target_agent_count(app, ranked[0].nic_bandwidth)
        placement: Placement = []
        remaining = need if need > 0 else 1
        for nv in ranked:
            if nv.n_agents >= nv.max_agents:
                continue
            k = min(n - sum(c for _, c in placement), nv.max_agents - nv.n_agents)
            if k <= 0:
                break
            placement.append((nv.node_id, k))
            remaining -= nv.free_memory
            if sum(c for _, c in placement) >= n and remaining <= 0:
                break
        if not placement:
            raise RuntimeError("no capacity for app placement")
        return placement


class BandwidthBalancedPolicy(SchedulingPolicy):
    """Spread agents over the least bandwidth-loaded nodes.

    Agents on distinct nodes add NIC capacity (the knee benchmark B1); agents
    sharing a node share its NIC — so spreading maximises aggregate rate.
    """

    name = "bandwidth"

    def place(self, nodes: Sequence[NodeView], app: AppRecord) -> Placement:
        usable = [nv for nv in nodes if nv.n_agents < nv.max_agents]
        if not usable:
            raise RuntimeError("no capacity for app placement")
        n = self.target_agent_count(app, usable[0].nic_bandwidth,
                                    max_agents=2 * len(usable))
        ranked = sorted(usable, key=lambda nv: (nv.bw_load, nv.n_agents))
        placement: Dict[str, int] = {}
        i = 0
        for _ in range(n):
            nv = ranked[i % len(ranked)]
            if placement.get(nv.node_id, 0) + nv.n_agents < nv.max_agents:
                placement[nv.node_id] = placement.get(nv.node_id, 0) + 1
            i += 1
        return list(placement.items()) or [(ranked[0].node_id, 1)]


class AdaptivePolicy(SchedulingPolicy):
    """The composite default: weighs memory fit, bandwidth load and the app's
    checkpoint frequency×size demand (all three metric families from §II)."""

    name = "adaptive"

    def __init__(self, mem_weight: float = 1.0, bw_weight: float = 1.0):
        self.mem_weight = mem_weight
        self.bw_weight = bw_weight

    def place(self, nodes: Sequence[NodeView], app: AppRecord) -> Placement:
        usable = [nv for nv in nodes if nv.n_agents < nv.max_agents]
        if not usable:
            raise RuntimeError("no capacity for app placement")
        need = max(1, app.ckpt_bytes_estimate * app.replication)

        def score(nv: NodeView) -> float:
            mem_fit = min(1.0, nv.free_memory / need)
            bw_fit = 1.0 / (1.0 + nv.bw_load)
            return self.mem_weight * mem_fit + self.bw_weight * bw_fit

        ranked = sorted(usable, key=score, reverse=True)
        n = self.target_agent_count(app, ranked[0].nic_bandwidth,
                                    max_agents=2 * len(usable))
        placement: Dict[str, int] = {}
        # fill best nodes first, at most 2 agents per node before spilling
        per_node_cap = 2
        for nv in ranked:
            while (placement.get(nv.node_id, 0) < per_node_cap
                   and nv.n_agents + placement.get(nv.node_id, 0) < nv.max_agents
                   and sum(placement.values()) < n):
                placement[nv.node_id] = placement.get(nv.node_id, 0) + 1
            if sum(placement.values()) >= n:
                break
        if not placement:
            placement[ranked[0].node_id] = 1
        return list(placement.items())


POLICIES = {p.name: p for p in
            (StaticPolicy(), MemoryAwarePolicy(), BandwidthBalancedPolicy(),
             AdaptivePolicy())}


def get_policy(name: str) -> SchedulingPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name]
