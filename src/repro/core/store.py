"""Checkpoint storage levels.

L1 — ``MemoryStore``: the iCheck-node RAM agents put RDMA'd shards into.
L2 — ``PFSStore``: the parallel-file-system container format the controller
orchestrates drains into (paper §II: "later written into the Parallel File
System").  Every shard is crc32-protected; the PFS layout is one file per
shard so that thousands of hosts can restore in parallel, plus a JSON
manifest per checkpoint.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Dict, Iterable, List, Optional

import numpy as np

from .simnet import SimNIC
from .types import (CapacityError, CheckpointMeta, CkptStatus, IntegrityError,
                    PartitionDesc, PartitionScheme, RegionMeta, ShardInfo,
                    ShardKey)

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover - zstandard is installed in this env
    _zstd = None


def crc32(buf) -> int:
    return zlib.crc32(memoryview(buf).cast("B")) & 0xFFFFFFFF


def _tupled(x):
    """JSON round-trips tuples as lists; restore nested tuples."""
    if isinstance(x, list):
        return tuple(_tupled(v) for v in x)
    return x


# --------------------------------------------------------------------------
# L1: in-memory shard store with capacity accounting
# --------------------------------------------------------------------------
class MemoryStore:
    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self._lock = threading.Lock()
        self._data: Dict[ShardKey, bytes] = {}
        self._crc: Dict[ShardKey, int] = {}
        self._used = 0

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    @property
    def free_bytes(self) -> int:
        with self._lock:
            return self.capacity - self._used

    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> None:
        payload = bytes(payload)
        with self._lock:
            old = len(self._data.get(key, b""))
            if self._used - old + len(payload) > self.capacity:
                raise CapacityError(
                    f"store over capacity: used={self._used} cap={self.capacity} "
                    f"put={len(payload)}")
            self._data[key] = payload
            self._crc[key] = crc32(payload) if crc is None else crc
            self._used += len(payload) - old

    def get(self, key: ShardKey, verify: bool = True) -> bytes:
        with self._lock:
            if key not in self._data:
                raise KeyError(key)
            payload = self._data[key]
            crc = self._crc[key]
        if verify and crc32(payload) != crc:
            raise IntegrityError(f"crc mismatch for {key}")
        return payload

    def has(self, key: ShardKey) -> bool:
        with self._lock:
            return key in self._data

    def drop(self, key: ShardKey) -> None:
        with self._lock:
            payload = self._data.pop(key, None)
            self._crc.pop(key, None)
            if payload is not None:
                self._used -= len(payload)

    def keys(self) -> List[ShardKey]:
        with self._lock:
            return list(self._data.keys())

    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int:
        """Evict all shards of one checkpoint; returns bytes freed."""
        freed = 0
        for k in self.keys():
            if k.app_id == app_id and k.ckpt_id == ckpt_id:
                with self._lock:
                    payload = self._data.pop(k, None)
                    self._crc.pop(k, None)
                    if payload is not None:
                        self._used -= len(payload)
                        freed += len(payload)
        return freed


# --------------------------------------------------------------------------
# L2: PFS container
# --------------------------------------------------------------------------
_SHARD_MAGIC = b"ICK1"


def _shard_path(root: str, key: ShardKey) -> str:
    return os.path.join(root, key.app_id, f"ckpt_{key.ckpt_id:08d}",
                        key.region.replace("/", "__"), f"part_{key.part:05d}.bin")


def _manifest_path(root: str, app_id: str, ckpt_id: int) -> str:
    return os.path.join(root, app_id, f"ckpt_{ckpt_id:08d}", "MANIFEST.json")


class PFSStore:
    """Bandwidth-limited parallel-file-system model.

    ``ingest`` is the aggregate PFS bandwidth all concurrent drains share —
    the resource the controller's flush orchestration rations (paper §II:
    "orchestrate the writing of the checkpoint data into PFS by minimizing
    the effect on running applications").
    """

    def __init__(self, root: str, bandwidth: float = 40e9, compress: bool = False,
                 clock=None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.ingest = SimNIC("pfs", bandwidth, latency=1e-4, clock=clock)
        self.compress = bool(compress and _zstd is not None)
        self._lock = threading.Lock()

    # -- shard IO ----------------------------------------------------------
    def write_shard(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> float:
        raw_len = len(payload)
        if self.compress:
            payload = _zstd.ZstdCompressor(level=3).compress(bytes(payload))
        crc = crc32(payload)
        # simulate PFS ingest time on the *written* bytes
        dur = self.ingest.transfer(len(payload))
        path = _shard_path(self.root, key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        header = _SHARD_MAGIC + crc.to_bytes(4, "little") + raw_len.to_bytes(8, "little") \
            + (b"Z" if self.compress else b"R")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(header)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)       # atomic publish
        return dur

    def read_shard(self, key: ShardKey) -> bytes:
        path = _shard_path(self.root, key)
        with open(path, "rb") as f:
            blob = f.read()
        if blob[:4] != _SHARD_MAGIC:
            raise IntegrityError(f"bad magic in {path}")
        crc = int.from_bytes(blob[4:8], "little")
        raw_len = int.from_bytes(blob[8:16], "little")
        mode = blob[16:17]
        payload = blob[17:]
        if crc32(payload) != crc:
            raise IntegrityError(f"crc mismatch in {path}")
        self.ingest.transfer(len(payload))
        if mode == b"Z":
            payload = _zstd.ZstdDecompressor().decompress(payload, max_output_size=raw_len)
        return payload

    def has_shard(self, key: ShardKey) -> bool:
        return os.path.exists(_shard_path(self.root, key))

    # -- manifests -----------------------------------------------------------
    def write_manifest(self, meta: CheckpointMeta) -> None:
        doc = {
            "app_id": meta.app_id,
            "ckpt_id": meta.ckpt_id,
            "step": meta.step,
            "status": meta.status.value,
            "userdata_hex": meta.userdata.hex(),
            "regions": {
                name: {
                    "shape": list(r.shape),
                    "dtype": r.dtype,
                    "nbytes": r.nbytes,
                    "codec": r.codec,
                    "partition": {
                        "scheme": r.partition.scheme.value,
                        "axis": r.partition.axis,
                        "num_parts": r.partition.num_parts,
                        "block": r.partition.block,
                        "bounds": r.partition.bounds,
                    },
                }
                for name, r in meta.regions.items()
            },
        }
        path = _manifest_path(self.root, meta.app_id, meta.ckpt_id)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)

    def read_manifest(self, app_id: str, ckpt_id: int) -> Optional[CheckpointMeta]:
        path = _manifest_path(self.root, app_id, ckpt_id)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            doc = json.load(f)
        meta = CheckpointMeta(app_id=doc["app_id"], ckpt_id=doc["ckpt_id"],
                              step=doc["step"], status=CkptStatus(doc["status"]),
                              userdata=bytes.fromhex(doc.get("userdata_hex", "")))
        for name, r in doc["regions"].items():
            meta.regions[name] = RegionMeta(
                name=name, shape=tuple(r["shape"]), dtype=r["dtype"],
                nbytes=r["nbytes"], codec=r.get("codec", "raw"),
                partition=PartitionDesc(
                    scheme=PartitionScheme(r["partition"]["scheme"]),
                    axis=r["partition"]["axis"],
                    num_parts=r["partition"]["num_parts"],
                    block=r["partition"]["block"],
                    bounds=_tupled(r["partition"].get("bounds"))))
        return meta

    def list_checkpoints(self, app_id: str) -> List[int]:
        base = os.path.join(self.root, app_id)
        if not os.path.isdir(base):
            return []
        out = []
        for d in os.listdir(base):
            if d.startswith("ckpt_") and os.path.exists(os.path.join(base, d, "MANIFEST.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def checkpoint_complete(self, meta: CheckpointMeta) -> bool:
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                if not self.has_shard(ShardKey(meta.app_id, meta.ckpt_id, name, part)):
                    return False
        return True
