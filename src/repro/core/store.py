"""Backwards-compat shim: checkpoint storage moved to ``repro.core.tiers``.

The old two-level layout (L1 ``MemoryStore`` → L2 ``PFSStore``) is now the
pluggable :class:`~repro.core.tiers.StorageTier` pipeline — see
``tiers.py`` and ARCHITECTURE.md.  The historical names remain importable:

    MemoryStore  -> tiers.MemoryTier      (L1)
    PFSStore     -> tiers.PFSTier         (L2)
"""
from __future__ import annotations

from .tiers import (LocalDiskTier, MemoryTier, PFSTier,  # noqa: F401
                    RemoteObjectTier, StorageTier, TierPipeline, crc32,
                    decode_payload, encode_payload, resolve_codec)

MemoryStore = MemoryTier
PFSStore = PFSTier

__all__ = [
    "MemoryStore", "PFSStore", "MemoryTier", "PFSTier", "LocalDiskTier",
    "RemoteObjectTier", "StorageTier", "TierPipeline", "crc32",
    "encode_payload", "decode_payload", "resolve_codec",
]
