"""Pluggable checkpoint storage tiers.

The paper's two-level hierarchy — agent RAM (L1) drained into the parallel
file system (L2, §II) — is generalised into a :class:`StorageTier` protocol
so new levels can be added without touching the controller:

  * :class:`MemoryTier`       — L1, iCheck-node RAM agents RDMA shards into
  * :class:`LocalDiskTier`    — L0.5, node-local spill (NVMe burst-buffer
    analogue) that absorbs capacity pressure before the RM must grow us
  * :class:`PFSTier`          — L2, the bandwidth-limited PFS container format
  * :class:`RemoteObjectTier` — L3, S3/GCS-style remote object store: per-
    request latency floor, multipart parallel throughput, effectively
    unbounded capacity, per-byte/per-request cost accounting

Every tier does crc32 + capacity accounting.  A per-node
:class:`TierPipeline` owns shard placement across its tiers (spill on
capacity pressure, promotion back to RAM on read) and is a drop-in for the
old ``MemoryStore`` mapping interface.

The pipeline also owns the *codec path*: ``encode_payload`` /
``decode_payload`` thread the ``zstd``, ``q8`` and ``q8-delta`` codecs
uniformly through puts, degrading gracefully to ``"none"`` when
``zstandard`` is not installed instead of raising.  The blockwise int8
math is imported from ``kernels/ckpt_codec`` (one shared reference — the
host wire codec and the device kernels cannot drift); ``q8-delta`` adds
sparse XOR-delta *frames* (only blocks whose codes or scale changed travel)
whose chain state lives in the CheckpointCatalog.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import struct
import threading
import zlib
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from ..obs import trace_id_for
from . import events as _events
from ..kernels.ckpt_codec.blocks import (BLOCK as _Q8_BLOCK, dequantize_np,
                                         quantize_np, to_blocks_np)
from ..kernels.ckpt_codec.rs import (join_rows, rs_decode_np, rs_encode_np,
                                     split_rows)
from .retry import with_backoff
from .simnet import SimNIC
from .types import (CapacityError, CheckpointMeta, CkptStatus, ICheckError,
                    IntegrityError, PartitionDesc, PartitionScheme,
                    RegionMeta, RestoreError, ShardKey)

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover - optional dependency
    _zstd = None


def crc32(buf) -> int:
    return zlib.crc32(memoryview(buf).cast("B")) & 0xFFFFFFFF


def _tupled(x):
    """JSON round-trips tuples as lists; restore nested tuples."""
    if isinstance(x, list):
        return tuple(_tupled(v) for v in x)
    return x


# ==========================================================================
# codecs — applied on the transfer path, uniformly for every put
# ==========================================================================
# _Q8_BLOCK is imported from kernels/ckpt_codec/blocks: one definition of the
# blockwise layout for the device kernels and this host wire codec.
#
# q8 frame wire modes (first payload byte):
#   b"R"  raw passthrough        R + data                    (non-float dtype)
#   b"Q"  plain q8 frame         Q + n u64le + scales f32[nb] + codes i8[nb*B]
#   b"K"  q8-delta keyframe      same layout as Q, tagged as a chain root
#   b"D"  q8-delta sparse frame  D + n u64le + nnz u32le + idx u32le[nnz]
#                                  + scales f32[nnz] + deltas i8[nnz*B]
# A delta frame carries only the blocks whose codes or scale changed since
# the previous frame (XOR codes, absolute scales); unchanged blocks cost
# zero wire bytes — the steady-state win of incremental checkpointing.
_Q8_QUANT = b"Q"
_Q8_RAW = b"R"
_Q8_KEY = b"K"
_Q8_DELTA = b"D"
# a delta frame of a part with zero changed blocks is exactly the header:
# D + n u64le + nnz u32le (nnz=0) — knowing this lets consumers prune reads
# of unchanged parts from shard *sizes* alone (already in every manifest)
Q8_EMPTY_DELTA_NBYTES = 1 + 8 + 4


@dataclasses.dataclass
class DeltaState:
    """Previous-codes handle for one region part (owned by the catalog)."""

    n: int                    # unpadded element count
    codes: np.ndarray         # (nb, BLOCK) int8
    scales: np.ndarray        # (nb, 1) f32
    # device-resident copy of ``codes`` (a jax.Array), attached by the
    # device-encode path so the next ``quantize_delta`` reads the previous
    # codes in place instead of re-uploading them H2D every commit; costs
    # 1/4 of the region's f32 bytes in device memory, dropped on chain
    # reset.  None on the pure-host path.
    codes_dev: object = None


def zstd_available() -> bool:
    return _zstd is not None


def is_float_dtype(dtype) -> bool:
    """True for dtypes the q8 codecs quantize (f32/f16/... and bfloat16,
    whose numpy dtype reports kind 'V')."""
    try:
        dt = np.dtype(dtype)
    except TypeError:
        return False
    return dt.kind == "f" or dt.name == "bfloat16"


def resolve_codec(codec: str,
                  on_degrade: Optional[Callable[[str, str], None]] = None) -> str:
    """Map a requested codec to one this process can actually run.

    ``zstd`` without the ``zstandard`` module degrades to ``"none"``;
    ``on_degrade(requested, actual)`` is invoked so the caller can log an
    event instead of the old behaviour of silently mis-labelling (or, worse,
    raising mid-commit).
    """
    if codec in ("zstd",) and _zstd is None:
        if on_degrade is not None:
            on_degrade(codec, "none")
        return "none"
    if codec not in ("raw", "none", "zstd", "q8", "q8-delta"):
        raise ICheckError(f"unknown codec {codec!r}")
    return codec


def q8_pack_full(n: int, codes: np.ndarray, scales: np.ndarray,
                 mode: bytes = _Q8_QUANT) -> bytes:
    """Pack a full q8 frame (plain ``Q`` or chain keyframe ``K``)."""
    return (mode + int(n).to_bytes(8, "little")
            + np.ascontiguousarray(scales, np.float32).tobytes()
            + np.ascontiguousarray(codes, np.int8).tobytes())


def _q8_full_size(nb: int) -> int:
    return 9 + 4 * nb + _Q8_BLOCK * nb


def q8_pack_delta(n: int, codes: np.ndarray, scales: np.ndarray,
                  prev: DeltaState,
                  delta: Optional[np.ndarray] = None) -> Optional[bytes]:
    """Sparse XOR-delta frame against ``prev``; None when shapes mismatch
    (the caller must fall back to a keyframe).  ``delta`` short-circuits
    the XOR when the caller already holds it (the device kernel's output).
    """
    if prev.n != n or prev.codes.shape != codes.shape:
        return None
    if delta is None:
        delta = np.bitwise_xor(codes, prev.codes)
    changed = np.logical_or((delta != 0).any(axis=1),
                            (scales != prev.scales).any(axis=1))
    idx = np.flatnonzero(changed).astype(np.uint32)
    return (_Q8_DELTA + int(n).to_bytes(8, "little")
            + len(idx).to_bytes(4, "little") + idx.tobytes()
            + np.ascontiguousarray(scales[idx], np.float32).tobytes()
            + np.ascontiguousarray(delta[idx], np.int8).tobytes())


def _q8_unpack_full(blob: bytes) -> Tuple[int, np.ndarray, np.ndarray]:
    n = int.from_bytes(blob[1:9], "little")
    nb = -(-max(n, 1) // _Q8_BLOCK)
    if len(blob) != _q8_full_size(nb):
        raise RestoreError(
            f"truncated q8 frame: {len(blob)} bytes for n={n}")
    scales = np.frombuffer(blob[9:9 + 4 * nb], np.float32).reshape(nb, 1)
    codes = np.frombuffer(blob[9 + 4 * nb:], np.int8).reshape(nb, _Q8_BLOCK)
    return n, codes, scales


def _q8_unpack_delta(blob: bytes) -> Tuple[int, np.ndarray, np.ndarray,
                                           np.ndarray]:
    n = int.from_bytes(blob[1:9], "little")
    nnz = int.from_bytes(blob[9:13], "little")
    if len(blob) != 13 + nnz * (4 + 4 + _Q8_BLOCK):
        raise RestoreError(
            f"truncated q8-delta frame: {len(blob)} bytes for nnz={nnz}")
    off = 13
    idx = np.frombuffer(blob[off:off + 4 * nnz], np.uint32)
    off += 4 * nnz
    scales = np.frombuffer(blob[off:off + 4 * nnz], np.float32).reshape(-1, 1)
    off += 4 * nnz
    deltas = np.frombuffer(blob[off:], np.int8).reshape(-1, _Q8_BLOCK)
    return n, idx, scales, deltas


def q8_delta_apply(blob: bytes, state: Optional[DeltaState]) -> DeltaState:
    """Advance the replay state by one frame (keyframe or sparse delta)."""
    mode = blob[:1]
    if mode in (_Q8_QUANT, _Q8_KEY):
        n, codes, scales = _q8_unpack_full(blob)
        return DeltaState(n=n, codes=codes.copy(), scales=scales.copy())
    if mode != _Q8_DELTA:
        raise RestoreError(f"bad q8 frame mode {mode!r}")
    if state is None:
        raise RestoreError("delta frame without a preceding keyframe")
    n, idx, scales, deltas = _q8_unpack_delta(blob)
    if n != state.n:
        raise RestoreError(
            f"delta frame size mismatch: chain n={state.n}, frame n={n}")
    if len(idx) and int(idx.max()) >= state.codes.shape[0]:
        raise RestoreError("delta frame block index out of range")
    codes = state.codes.copy()
    new_scales = state.scales.copy()
    codes[idx] = np.bitwise_xor(codes[idx], deltas)
    new_scales[idx] = scales
    return DeltaState(n=n, codes=codes, scales=new_scales)


def q8_chain_decode(blobs: Sequence[bytes], dtype: str) -> bytes:
    """Replay keyframe + deltas back to raw bytes.

    Bit-identical to decoding a full q8 frame of the final commit: the chain
    reconstructs that frame's exact (codes, scales) and the dequantize math
    is the same f32 path the device kernels use.
    """
    if not blobs:
        raise RestoreError("empty delta chain")
    if blobs[-1][:1] == _Q8_RAW:
        # non-float passthrough: every frame is full, only the last matters
        return bytes(blobs[-1][1:])
    state: Optional[DeltaState] = None
    for blob in blobs:
        state = q8_delta_apply(blob, state)
    return dequantize_np(state.codes, state.scales, state.n, dtype).tobytes()


def q8_quantize_part(data: bytes, dtype: str) -> Tuple[int, np.ndarray,
                                                       np.ndarray]:
    """Host-side quantize of one region part: raw bytes -> (n, codes, scales)
    via the shared blockwise reference (kernels/ckpt_codec/blocks)."""
    x = np.frombuffer(data, dtype=np.dtype(dtype))
    blocks, n = to_blocks_np(x)
    codes, scales = quantize_np(blocks)
    return n, codes, scales


def pack_q8_region(parts: Dict[int, Tuple[int, np.ndarray, np.ndarray]],
                   prev: Optional[Dict[int, DeltaState]],
                   deltas: Optional[Dict[int, np.ndarray]] = None
                   ) -> Tuple[Dict[int, bytes], Dict[int, DeltaState], str]:
    """Frame one region's quantized parts as deltas or keyframes.

    ``parts[part] = (n, codes, scales)`` — produced host-side by
    :func:`q8_quantize_part` or device-side by the ``kernels/ckpt_codec``
    Pallas ops (both paths share this packer, so framing policy cannot
    drift).  Emits sparse deltas against ``prev`` when the whole region has
    matching previous-codes state **and** the delta frames are actually
    smaller than keyframes (high-churn commits fall back to a keyframe, so
    q8-delta never loses to plain q8); returns ``(blobs, new_states,
    frame)`` with frame ``"key"`` or ``"delta"``.
    """
    states = {p: DeltaState(n=n, codes=codes, scales=scales)
              for p, (n, codes, scales) in parts.items()}
    if prev is not None and set(prev) == set(parts):
        delta_blobs: Dict[int, bytes] = {}
        for p, (n, codes, scales) in parts.items():
            blob = q8_pack_delta(n, codes, scales, prev[p],
                                 delta=(deltas or {}).get(p))
            if blob is None:
                break
            delta_blobs[p] = blob
        if len(delta_blobs) == len(parts):
            key_total = sum(_q8_full_size(codes.shape[0])
                            for _, codes, _ in parts.values())
            if sum(len(b) for b in delta_blobs.values()) < key_total:
                return delta_blobs, states, "delta"
    keys = {p: q8_pack_full(n, codes, scales, _Q8_KEY)
            for p, (n, codes, scales) in parts.items()}
    return keys, states, "key"


# --------------------------------------------------------------------------
# slice frames — the peer-to-peer redistribution wire format
# --------------------------------------------------------------------------
# An agent serving a ``peer_read`` ships only the bytes another agent's
# transfer program asked for (flattened element range [vlo, vhi) of one
# stored shard), never the whole payload.  Three slice modes:
#
#   b"W"  raw value slice      W + exact [vlo*itemsize, vhi*itemsize) bytes
#   b"S"  q8 block slice       S + vlo u64 + vhi u64 + scales f32[nb]
#                                + codes i8[nb*BLOCK]   (blocks covering the
#                                range, cut from a Q/K frame — no decode)
#   b"T"  q8-delta block slice T + vlo u64 + vhi u64 + nnz u32
#                                + idx u32[nnz] (absolute block indices)
#                                + scales f32[nnz] + deltas i8[nnz*BLOCK]
#
# q8 frames are sliced at the 256-value block granularity of
# ``kernels/ckpt_codec/blocks.py`` so encoded payloads move without decode
# and are re-framed, not re-quantized; the destination replays S (+T chain)
# slices and dequantizes only the needed blocks — bit-identical to slicing a
# full-shard decode.
_SL_RAW = b"W"
_SL_FULL = b"S"
_SL_DELTA = b"T"


@dataclasses.dataclass
class SliceState:
    """Retained q8 decode state of one assembled slice range [vlo, vhi):
    the (codes, scales) of the covering blocks after replaying the base
    chain.  A zero-stall cutover advances this state with the tail delta
    frames committed during the overlap window instead of re-streaming the
    keyframe — the decoded scratch bytes alone could not absorb a ``T``
    frame (XOR needs the codes, not the dequantized values)."""

    vlo: int
    vhi: int
    codes: np.ndarray         # (nb, BLOCK) int8, blocks [vlo//B, ceil(vhi/B))
    scales: np.ndarray        # (nb, 1) f32


def _apply_slice_frame(blob: bytes, codes, scales, vlo: int, vhi: int):
    """Apply one S/T slice frame to (codes, scales); returns the new
    ``(codes, scales, changed_rel)`` where ``changed_rel`` is the array of
    relative block indices the frame touched (None = every block)."""
    blo, bhi = vlo // _Q8_BLOCK, -(-vhi // _Q8_BLOCK)
    nb = bhi - blo
    mode = blob[:1]
    flo = int.from_bytes(blob[1:9], "little")
    fhi = int.from_bytes(blob[9:17], "little")
    if (flo, fhi) != (vlo, vhi):
        raise RestoreError(
            f"slice range mismatch: frame [{flo},{fhi}) vs [{vlo},{vhi})")
    if mode == _SL_FULL:
        if len(blob) != 17 + nb * (4 + _Q8_BLOCK):
            raise RestoreError(f"truncated q8 slice: {len(blob)} bytes")
        scales = np.frombuffer(blob[17:17 + 4 * nb],
                               np.float32).reshape(nb, 1).copy()
        codes = np.frombuffer(blob[17 + 4 * nb:],
                              np.int8).reshape(nb, _Q8_BLOCK).copy()
        return codes, scales, None
    if mode == _SL_DELTA:
        if codes is None or scales is None:
            raise RestoreError("delta slice without a keyframe slice")
        nnz = int.from_bytes(blob[17:21], "little")
        if len(blob) != 21 + nnz * (4 + 4 + _Q8_BLOCK):
            raise RestoreError(
                f"truncated q8-delta slice: {len(blob)} bytes")
        off = 21
        idx = np.frombuffer(blob[off:off + 4 * nnz], np.uint32)
        off += 4 * nnz
        dsc = np.frombuffer(blob[off:off + 4 * nnz],
                            np.float32).reshape(-1, 1)
        off += 4 * nnz
        dl = np.frombuffer(blob[off:], np.int8).reshape(-1, _Q8_BLOCK)
        rel = idx.astype(np.int64) - blo
        if len(rel) and (rel.min() < 0 or rel.max() >= nb):
            raise RestoreError("delta slice block index out of range")
        codes[rel] = np.bitwise_xor(codes[rel], dl)
        scales[rel] = dsc
        return codes, scales, rel
    raise RestoreError(f"bad slice mode {mode!r}")


def _dequantize_slice(codes: np.ndarray, scales: np.ndarray,
                      dtype: str, vlo: int, vhi: int) -> np.ndarray:
    blo = vlo // _Q8_BLOCK
    vals = (codes.astype(np.float32) * scales).reshape(-1)
    return vals[vlo - blo * _Q8_BLOCK:vhi - blo * _Q8_BLOCK] \
        .astype(np.dtype(dtype))


def replay_slice_frames(state: Optional[SliceState], frames: Sequence[bytes],
                        dtype: str, vlo: int, vhi: int
                        ) -> Tuple[List[Tuple[int, np.ndarray]],
                                   Optional[SliceState]]:
    """Advance a retained :class:`SliceState` by tail frames (the deltas
    committed during an overlap window) and return the *value patches* a
    cutover must splice into the already-assembled scratch payload.

    Returns ``(patches, new_state)`` where each patch is ``(rel_offset,
    values)`` relative to ``vlo``, covering exactly the value spans whose
    blocks changed (adjacent changed blocks coalesce into one patch).  A
    raw (``W``) tail frame replaces the whole range and needs no state.
    """
    if not frames:
        return [], state
    if frames[-1][:1] == _SL_RAW:
        # raw passthrough: every chain frame is full, only the last matters
        arr = np.frombuffer(bytearray(frames[-1][1:]), dtype=np.dtype(dtype))
        if arr.size != vhi - vlo:
            raise RestoreError(
                f"raw slice carries {arr.size} values, wanted {vhi - vlo}")
        return [(0, arr)], state
    if state is not None and (state.vlo, state.vhi) != (vlo, vhi):
        raise RestoreError(
            f"slice state covers [{state.vlo},{state.vhi}), "
            f"tail frames cover [{vlo},{vhi})")
    codes = state.codes if state is not None else None
    scales = state.scales if state is not None else None
    blo, bhi = vlo // _Q8_BLOCK, -(-vhi // _Q8_BLOCK)
    nb = bhi - blo
    touched: Optional[set] = set()
    for blob in frames:
        codes, scales, changed = _apply_slice_frame(blob, codes, scales,
                                                    vlo, vhi)
        if changed is None:           # a full S frame rewrote every block
            touched = None
        elif touched is not None:
            touched.update(int(r) for r in changed)
    new_state = SliceState(vlo=vlo, vhi=vhi, codes=codes, scales=scales)
    if touched is None:
        return [(0, _dequantize_slice(codes, scales, dtype, vlo, vhi))], \
            new_state
    if not touched:
        return [], new_state
    vals = _dequantize_slice(codes, scales, dtype, vlo, vhi)
    patches: List[Tuple[int, np.ndarray]] = []
    run_lo: Optional[int] = None
    prev = None
    for rb in sorted(touched) + [None]:       # sentinel flushes the last run
        if run_lo is not None and (rb is None or rb != prev + 1):
            lo = max(vlo, (blo + run_lo) * _Q8_BLOCK)
            hi = min(vhi, (blo + prev + 1) * _Q8_BLOCK)
            patches.append((lo - vlo, vals[lo - vlo:hi - vlo]))
            run_lo = None
        if rb is not None:
            if run_lo is None:
                run_lo = rb
            prev = rb
    return patches, new_state


def slice_payload(blob: bytes, codec: str, dtype: str,
                  vlo: int, vhi: int) -> bytes:
    """Cut the slice frame for flattened elements [vlo, vhi) of one stored
    shard payload (source-agent side of a ``peer_read``)."""
    it = np.dtype(dtype).itemsize
    if codec in ("raw", "none"):
        return _SL_RAW + bytes(blob[vlo * it:vhi * it])
    if codec == "zstd":
        raw = decode_payload(blob, codec, dtype)
        return _SL_RAW + raw[vlo * it:vhi * it]
    if codec in ("q8", "q8-delta"):
        mode = blob[:1]
        if mode == _Q8_RAW:
            return _SL_RAW + bytes(blob[1 + vlo * it:1 + vhi * it])
        hdr = int(vlo).to_bytes(8, "little") + int(vhi).to_bytes(8, "little")
        blo, bhi = vlo // _Q8_BLOCK, -(-vhi // _Q8_BLOCK)
        if mode in (_Q8_QUANT, _Q8_KEY):
            _, codes, scales = _q8_unpack_full(blob)
            if bhi > codes.shape[0]:
                raise RestoreError(
                    f"slice [{vlo},{vhi}) beyond frame of {codes.shape[0]} "
                    f"blocks")
            return (_SL_FULL + hdr
                    + np.ascontiguousarray(scales[blo:bhi], np.float32).tobytes()
                    + np.ascontiguousarray(codes[blo:bhi], np.int8).tobytes())
        if mode == _Q8_DELTA:
            _, idx, scales, deltas = _q8_unpack_delta(blob)
            sel = (idx >= blo) & (idx < bhi)
            idx2 = idx[sel].astype(np.uint32)
            return (_SL_DELTA + hdr + len(idx2).to_bytes(4, "little")
                    + idx2.tobytes()
                    + np.ascontiguousarray(scales[sel], np.float32).tobytes()
                    + np.ascontiguousarray(deltas[sel], np.int8).tobytes())
        raise RestoreError(f"bad q8 frame mode {mode!r}")
    raise ICheckError(f"unknown codec {codec!r}")


def decode_slice_frames(frames: Sequence[bytes], dtype: str,
                        vlo: int, vhi: int, return_state: bool = False):
    """Replay slice frames back to values (destination-agent assembly).

    ``frames`` is chain-ordered (keyframe slice first, delta slices after)
    for ``q8-delta``; a single frame otherwise.  Returns a 1-d array of
    exactly ``vhi - vlo`` elements, bit-identical to decoding the full
    shards and slicing.  With ``return_state=True`` returns ``(values,
    SliceState | None)`` so an overlap-window cutover can later advance the
    decode with tail delta frames (:func:`replay_slice_frames`); raw slices
    have no q8 state and yield None.
    """
    if not frames:
        raise RestoreError("empty slice chain")
    if frames[-1][:1] == _SL_RAW:
        # raw passthrough: every chain frame is full, only the last matters
        arr = np.frombuffer(bytearray(frames[-1][1:]), dtype=np.dtype(dtype))
        if arr.size != vhi - vlo:
            raise RestoreError(
                f"raw slice carries {arr.size} values, wanted {vhi - vlo}")
        return (arr, None) if return_state else arr
    codes: Optional[np.ndarray] = None
    scales: Optional[np.ndarray] = None
    for blob in frames:
        codes, scales, _ = _apply_slice_frame(blob, codes, scales, vlo, vhi)
    if codes is None or scales is None:
        raise RestoreError("q8 slice chain has no keyframe slice")
    vals = _dequantize_slice(codes, scales, dtype, vlo, vhi)
    if return_state:
        return vals, SliceState(vlo=vlo, vhi=vhi, codes=codes, scales=scales)
    return vals


@dataclasses.dataclass
class EncodedRegion:
    """One region already encoded upstream of the client (device-side in
    ``core/snapshot.py`` before the D2H copy) — what ``commit_snapshot``
    hands the commit path instead of raw arrays."""

    codec: str                           # "q8" | "q8-delta"
    blobs: Dict[int, bytes]              # part -> wire frame
    states: Optional[Dict[int, DeltaState]]   # chain handles (q8-delta)
    frame: Optional[str]                 # "key" | "delta" (q8-delta only)
    raw_nbytes: int                      # pre-codec bytes (the f32 payload)
    parent_chain: Optional[tuple] = None  # chain expected live at commit
    encode_s: float = 0.0                # host-clock encode duration


def q8_repack_key(states: Dict[int, DeltaState]) -> Dict[int, bytes]:
    """Re-frame already-quantized parts as self-contained keyframes (used
    when a delta encode went stale: its chain reset between encode and
    commit — the carried codes are still the full current codes)."""
    return {p: q8_pack_full(st.n, st.codes, st.scales, _Q8_KEY)
            for p, st in states.items()}


def encode_delta_region(parts_bytes: Dict[int, bytes], dtype: str,
                        prev: Optional[Dict[int, DeltaState]]
                        ) -> Tuple[Dict[int, bytes],
                                   Optional[Dict[int, DeltaState]], str]:
    """Host-side q8-delta encode of one region (all parts together).

    Non-float regions pass through as full raw frames with no chain state.
    """
    if not is_float_dtype(dtype):
        return ({p: _Q8_RAW + bytes(b) for p, b in parts_bytes.items()},
                None, "key")
    parts = {p: q8_quantize_part(b, dtype) for p, b in parts_bytes.items()}
    return pack_q8_region(parts, prev)


def _q8_encode(data: bytes, dtype: str, mode: bytes = _Q8_QUANT) -> bytes:
    if not is_float_dtype(dtype):
        return _Q8_RAW + bytes(data)
    n, codes, scales = q8_quantize_part(data, dtype)
    return q8_pack_full(n, codes, scales, mode)


def _q8_decode(blob: bytes, dtype: str) -> bytes:
    mode = blob[:1]
    if mode == _Q8_RAW:
        return bytes(blob[1:])
    if mode == _Q8_DELTA:
        raise RestoreError(
            "q8-delta frame needs its chain; replay via q8_chain_decode")
    n, codes, scales = _q8_unpack_full(blob)
    return dequantize_np(codes, scales, n, dtype).tobytes()


def encode_payload(data: bytes, codec: str, dtype: str = "uint8") -> bytes:
    """Codec step of every put (client commit → agent → tier).

    ``q8-delta`` without chain state encodes a standalone keyframe — the
    client threads previous-codes state through :func:`encode_delta_region`
    on the commit hot path instead.
    """
    if codec in ("raw", "none"):
        return bytes(data)
    if codec == "zstd":
        if _zstd is None:
            raise ICheckError("zstandard not installed; resolve_codec() first")
        return _zstd.ZstdCompressor(level=1).compress(bytes(data))
    if codec == "q8":
        return _q8_encode(data, dtype)
    if codec == "q8-delta":
        return _q8_encode(data, dtype, _Q8_KEY)
    raise ICheckError(f"unknown codec {codec!r}")


def decode_payload(blob: bytes, codec: str, dtype: str = "uint8") -> bytes:
    if codec in ("raw", "none"):
        return bytes(blob)
    if codec == "zstd":
        if _zstd is None:
            raise ICheckError(
                "shard was zstd-compressed but zstandard is not installed")
        return _zstd.ZstdDecompressor().decompress(blob)
    if codec in ("q8", "q8-delta"):
        return _q8_decode(blob, dtype)
    raise ICheckError(f"unknown codec {codec!r}")


# ==========================================================================
# erasure-coded fragment framing (k data + m parity per logical shard)
# ==========================================================================
# A fragment rides the existing ShardKey by parking its index in the
# ``replica`` slot well above any replication count: data fragment i lives
# at replica FRAG_DATA0 + i, parity fragment j at replica FRAG_PARITY0 + j.
# Everything keyed on replica keeps working unchanged — LocalDiskTier paths
# stay unique (``_r{replica}``), the catalog's replica-0..3 probe never
# sees fragments, and the lifecycle demoter spots parity by replica alone.
FRAG_DATA0 = 16
FRAG_PARITY0 = 64

_EC_MAGIC = b"ICE1"
# magic, k, m, fragment index (0..k-1 data, k..k+m-1 parity), pad,
# original payload length, crc32 of the original payload
_EC_HEADER = struct.Struct("<4sBBBxQI")


def ec_fragment_replica(idx: int, k: int) -> int:
    """Fragment index (0..k+m-1) -> the ShardKey.replica it rides in."""
    return FRAG_DATA0 + idx if idx < k else FRAG_PARITY0 + (idx - k)


def ec_is_fragment(replica: int) -> bool:
    return replica >= FRAG_DATA0


def ec_is_parity(replica: int) -> bool:
    return replica >= FRAG_PARITY0


def ec_fragment_index(replica: int, k: int) -> int:
    """Inverse of :func:`ec_fragment_replica`."""
    if replica >= FRAG_PARITY0:
        return k + (replica - FRAG_PARITY0)
    return replica - FRAG_DATA0


def ec_encode_shard(payload: bytes, k: int, m: int) -> List[Tuple[int, bytes]]:
    """Payload -> [(replica, framed fragment blob)] for k data + m parity.

    Every fragment is self-describing (stripe geometry, its own index, the
    original length and crc), so any k surviving blobs reconstruct the
    payload with end-to-end integrity checking and no side-channel state.
    """
    data = split_rows(payload, k)
    parity = rs_encode_np(data, m)
    crc = crc32(payload)
    out: List[Tuple[int, bytes]] = []
    for idx in range(k + m):
        row = data[idx] if idx < k else parity[idx - k]
        hdr = _EC_HEADER.pack(_EC_MAGIC, k, m, idx, len(payload), crc)
        out.append((ec_fragment_replica(idx, k), hdr + row.tobytes()))
    return out


def ec_parse_fragment(blob: bytes) -> Tuple[int, int, int, int, int, bytes]:
    """Framed blob -> (k, m, idx, orig_len, crc, row bytes)."""
    if len(blob) < _EC_HEADER.size or blob[:4] != _EC_MAGIC:
        raise IntegrityError("not an erasure-coded fragment")
    magic, k, m, idx, orig_len, crc = _EC_HEADER.unpack_from(blob)
    return k, m, idx, orig_len, crc, blob[_EC_HEADER.size:]


def ec_decode_shard(fragments: Sequence[bytes]) -> bytes:
    """Any k framed fragments -> the original payload (crc-verified).

    Raises :class:`RestoreError` when fewer than k distinct fragments
    survive and :class:`IntegrityError` when the reconstruction does not
    match the payload crc carried in every fragment header.
    """
    rows: Dict[int, np.ndarray] = {}
    geom = None
    for blob in fragments:
        k, m, idx, orig_len, crc, row = ec_parse_fragment(blob)
        if geom is None:
            geom = (k, m, orig_len, crc)
        elif geom != (k, m, orig_len, crc):
            raise IntegrityError("mixed-stripe fragments in one decode")
        rows[idx] = np.frombuffer(row, dtype=np.uint8)
    if geom is None:
        raise RestoreError("ec decode with no fragments")
    k, m, orig_len, crc = geom
    if len(rows) < k:
        raise RestoreError(
            f"stripe lost: {len(rows)} of the {k} required fragments")
    data = rs_decode_np(rows, k, m)
    payload = join_rows(data, orig_len)
    if crc32(payload) != crc:
        raise IntegrityError("erasure reconstruction failed crc check")
    return payload


# ==========================================================================
# the tier protocol
# ==========================================================================
@runtime_checkable
class StorageTier(Protocol):
    """What the pipeline (and the controller's migration paths) rely on."""

    name: str
    level: float                 # 1.0 = RAM, 1.5 = local disk, 2.0 = PFS

    @property
    def capacity(self) -> float: ...
    @property
    def used_bytes(self) -> int: ...
    @property
    def free_bytes(self) -> float: ...

    def put(self, key: ShardKey, payload: bytes,
            crc: Optional[int] = None) -> None: ...
    def get(self, key: ShardKey, verify: bool = True) -> bytes: ...
    def has(self, key: ShardKey) -> bool: ...
    def drop(self, key: ShardKey) -> None: ...
    def keys(self) -> List[ShardKey]: ...
    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int: ...


# --------------------------------------------------------------------------
# L1: in-memory shard tier with capacity accounting
# --------------------------------------------------------------------------
class MemoryTier:
    name = "memory"
    level = 1.0

    def __init__(self, capacity_bytes: int):
        self._capacity = int(capacity_bytes)
        self._lock = threading.Lock()
        self._data: Dict[ShardKey, bytes] = {}
        self._crc: Dict[ShardKey, int] = {}
        self._used = 0

    @property
    def capacity(self) -> float:
        return self._capacity

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    @property
    def free_bytes(self) -> float:
        with self._lock:
            return self._capacity - self._used

    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> None:
        payload = bytes(payload)
        with self._lock:
            old = len(self._data.get(key, b""))
            if self._used - old + len(payload) > self._capacity:
                raise CapacityError(
                    f"{self.name} tier over capacity: used={self._used} "
                    f"cap={self._capacity} put={len(payload)}")
            self._data[key] = payload
            self._crc[key] = crc32(payload) if crc is None else crc
            self._used += len(payload) - old

    def get(self, key: ShardKey, verify: bool = True) -> bytes:
        with self._lock:
            if key not in self._data:
                raise KeyError(key)
            payload = self._data[key]
            crc = self._crc[key]
        if verify and crc32(payload) != crc:
            raise IntegrityError(f"crc mismatch for {key}")
        return payload

    def has(self, key: ShardKey) -> bool:
        with self._lock:
            return key in self._data

    def drop(self, key: ShardKey) -> None:
        with self._lock:
            payload = self._data.pop(key, None)
            self._crc.pop(key, None)
            if payload is not None:
                self._used -= len(payload)

    def keys(self) -> List[ShardKey]:
        with self._lock:
            return list(self._data.keys())

    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int:
        freed = 0
        for k in self.keys():
            if k.app_id == app_id and k.ckpt_id == ckpt_id:
                with self._lock:
                    payload = self._data.pop(k, None)
                    self._crc.pop(k, None)
                    if payload is not None:
                        self._used -= len(payload)
                        freed += len(payload)
        return freed


# --------------------------------------------------------------------------
# L0.5: node-local disk spill (burst-buffer analogue)
# --------------------------------------------------------------------------
_SPILL_MAGIC = b"ICS1"


class LocalDiskTier:
    name = "local_disk"
    level = 1.5

    def __init__(self, root: str, capacity_bytes: int):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._capacity = int(capacity_bytes)
        self._lock = threading.Lock()
        self._index: Dict[ShardKey, int] = {}     # key -> payload nbytes
        self._used = 0

    @property
    def capacity(self) -> float:
        return self._capacity

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    @property
    def free_bytes(self) -> float:
        with self._lock:
            return self._capacity - self._used

    def _path(self, key: ShardKey) -> str:
        return os.path.join(
            self.root, key.app_id, f"ckpt_{key.ckpt_id:08d}",
            key.region.replace("/", "__"),
            f"part_{key.part:05d}_r{key.replica}.bin")

    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> None:
        payload = bytes(payload)
        with self._lock:
            old = self._index.get(key, 0)
            had = key in self._index
            if self._used - old + len(payload) > self._capacity:
                raise CapacityError(
                    f"{self.name} tier over capacity: used={self._used} "
                    f"cap={self._capacity} put={len(payload)}")
            self._index[key] = len(payload)
            self._used += len(payload) - old
        crc = crc32(payload) if crc is None else crc
        path = self._path(key)
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(_SPILL_MAGIC + crc.to_bytes(4, "little"))
                f.write(payload)
            os.replace(tmp, path)
        except OSError:
            # roll back the reservation: the tier must not claim a shard
            # (or capacity) that has no backing file
            with self._lock:
                if had:
                    self._index[key] = old
                    self._used += old - len(payload)
                else:
                    self._index.pop(key, None)
                    self._used -= len(payload)
            raise

    def get(self, key: ShardKey, verify: bool = True) -> bytes:
        with self._lock:
            if key not in self._index:
                raise KeyError(key)
        with open(self._path(key), "rb") as f:
            blob = f.read()
        if blob[:4] != _SPILL_MAGIC:
            raise IntegrityError(f"bad spill magic for {key}")
        crc = int.from_bytes(blob[4:8], "little")
        payload = blob[8:]
        if verify and crc32(payload) != crc:
            raise IntegrityError(f"crc mismatch for spilled {key}")
        return payload

    def has(self, key: ShardKey) -> bool:
        with self._lock:
            return key in self._index

    def drop(self, key: ShardKey) -> None:
        with self._lock:
            nbytes = self._index.pop(key, None)
            if nbytes is not None:
                self._used -= nbytes
        if nbytes is not None:
            try:
                os.remove(self._path(key))
            except OSError:
                pass

    def keys(self) -> List[ShardKey]:
        with self._lock:
            return list(self._index.keys())

    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int:
        freed = 0
        for k in self.keys():
            if k.app_id == app_id and k.ckpt_id == ckpt_id:
                with self._lock:
                    nbytes = self._index.pop(k, None)
                if nbytes is not None:
                    freed += nbytes
                    with self._lock:
                        self._used -= nbytes
                    try:
                        os.remove(self._path(k))
                    except OSError:
                        pass
        return freed

    def close(self) -> None:
        shutil.rmtree(self.root, ignore_errors=True)


# --------------------------------------------------------------------------
# L2: PFS container
# --------------------------------------------------------------------------
_SHARD_MAGIC = b"ICK1"


def _shard_path(root: str, key: ShardKey) -> str:
    return os.path.join(root, key.app_id, f"ckpt_{key.ckpt_id:08d}",
                        key.region.replace("/", "__"), f"part_{key.part:05d}.bin")


def _manifest_path(root: str, app_id: str, ckpt_id: int) -> str:
    return os.path.join(root, app_id, f"ckpt_{ckpt_id:08d}", "MANIFEST.json")


def region_doc(r: RegionMeta) -> dict:
    """JSON-serializable form of one RegionMeta — shared by the tier
    manifests and the control-plane metadata journal."""
    return {
        "shape": list(r.shape),
        "dtype": r.dtype,
        "nbytes": r.nbytes,
        "codec": r.codec,
        "frame": r.frame,
        "chain": list(r.chain) if r.chain is not None else None,
        "partition": {
            "scheme": r.partition.scheme.value,
            "axis": r.partition.axis,
            "num_parts": r.partition.num_parts,
            "block": r.partition.block,
            "bounds": r.partition.bounds,
        },
    }


def region_from_doc(name: str, r: dict) -> RegionMeta:
    chain = r.get("chain")
    return RegionMeta(
        name=name, shape=tuple(r["shape"]), dtype=r["dtype"],
        nbytes=r["nbytes"], codec=r.get("codec", "raw"),
        frame=r.get("frame"),
        chain=tuple(chain) if chain is not None else None,
        partition=PartitionDesc(
            scheme=PartitionScheme(r["partition"]["scheme"]),
            axis=r["partition"]["axis"],
            num_parts=r["partition"]["num_parts"],
            block=r["partition"]["block"],
            bounds=_tupled(r["partition"].get("bounds"))))


def _manifest_doc(meta: CheckpointMeta) -> dict:
    """Serializable manifest document (shared by the PFS and L3 tiers)."""
    return {
        "app_id": meta.app_id,
        "ckpt_id": meta.ckpt_id,
        "step": meta.step,
        "status": meta.status.value,
        "userdata_hex": meta.userdata.hex(),
        "regions": {name: region_doc(r) for name, r in meta.regions.items()},
    }


def _meta_from_manifest(doc: dict) -> CheckpointMeta:
    meta = CheckpointMeta(app_id=doc["app_id"], ckpt_id=doc["ckpt_id"],
                          step=doc["step"], status=CkptStatus(doc["status"]),
                          userdata=bytes.fromhex(doc.get("userdata_hex", "")))
    for name, r in doc["regions"].items():
        meta.regions[name] = region_from_doc(name, r)
    return meta


def _write_manifest_file(root: str, meta: CheckpointMeta) -> None:
    path = _manifest_path(root, meta.app_id, meta.ckpt_id)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(_manifest_doc(meta), f)
    os.replace(tmp, path)


def _read_manifest_file(root: str, app_id: str,
                        ckpt_id: int) -> Optional[CheckpointMeta]:
    path = _manifest_path(root, app_id, ckpt_id)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return _meta_from_manifest(json.load(f))


def _list_manifest_ckpts(root: str, app_id: str) -> List[int]:
    base = os.path.join(root, app_id)
    if not os.path.isdir(base):
        return []
    out = []
    for d in os.listdir(base):
        if d.startswith("ckpt_") and os.path.exists(
                os.path.join(base, d, "MANIFEST.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


class PFSTier:
    """Bandwidth-limited parallel-file-system tier.

    ``ingest`` is the aggregate PFS bandwidth all concurrent drains share —
    the resource the drain orchestrator rations (paper §II: "orchestrate the
    writing of the checkpoint data into PFS by minimizing the effect on
    running applications").  One file per shard so thousands of hosts can
    restore in parallel, plus a JSON manifest per checkpoint.
    """

    name = "pfs"
    level = 2.0

    def __init__(self, root: str, bandwidth: float = 40e9, compress: bool = False,
                 clock=None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.ingest = SimNIC("pfs", bandwidth, latency=1e-4, clock=clock)
        self.compress = bool(compress and _zstd is not None)
        self._lock = threading.Lock()

    # -- StorageTier protocol ---------------------------------------------
    @property
    def capacity(self) -> float:
        return float("inf")

    @property
    def used_bytes(self) -> int:
        total = 0
        for key in self.keys():
            try:
                total += os.path.getsize(_shard_path(self.root, key))
            except OSError:
                pass
        return total

    @property
    def free_bytes(self) -> float:
        return float("inf")

    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> None:
        self.write_shard(key, payload, crc)

    def get(self, key: ShardKey, verify: bool = True) -> bytes:
        return self.read_shard(key)

    def has(self, key: ShardKey) -> bool:
        return self.has_shard(key)

    def drop(self, key: ShardKey) -> None:
        try:
            os.remove(_shard_path(self.root, key))
        except OSError:
            pass

    def keys(self) -> List[ShardKey]:
        out: List[ShardKey] = []
        if not os.path.isdir(self.root):
            return out
        for app_id in os.listdir(self.root):
            base = os.path.join(self.root, app_id)
            if not os.path.isdir(base):
                continue
            for d in os.listdir(base):
                if not d.startswith("ckpt_"):
                    continue
                ckpt_id = int(d.split("_")[1])
                cdir = os.path.join(base, d)
                for region in os.listdir(cdir):
                    rdir = os.path.join(cdir, region)
                    if not os.path.isdir(rdir):
                        continue
                    for fn in os.listdir(rdir):
                        if fn.startswith("part_") and fn.endswith(".bin"):
                            part = int(fn[5:-4])
                            out.append(ShardKey(app_id, ckpt_id,
                                                region.replace("__", "/"), part))
        return out

    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int:
        base = os.path.join(self.root, app_id, f"ckpt_{ckpt_id:08d}")
        freed = 0
        if os.path.isdir(base):
            for dirpath, _, files in os.walk(base):
                for fn in files:
                    try:
                        freed += os.path.getsize(os.path.join(dirpath, fn))
                    except OSError:
                        pass
            shutil.rmtree(base, ignore_errors=True)
        return freed

    # -- shard IO ----------------------------------------------------------
    def write_shard(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> float:
        raw_len = len(payload)
        if self.compress:
            payload = _zstd.ZstdCompressor(level=3).compress(bytes(payload))
        crc = crc32(payload)
        # simulate PFS ingest time on the *written* bytes
        dur = self.ingest.transfer(len(payload))
        path = _shard_path(self.root, key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        header = _SHARD_MAGIC + crc.to_bytes(4, "little") + raw_len.to_bytes(8, "little") \
            + (b"Z" if self.compress else b"R")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(header)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)       # atomic publish
        return dur

    def read_shard(self, key: ShardKey) -> bytes:
        path = _shard_path(self.root, key)
        with open(path, "rb") as f:
            blob = f.read()
        if blob[:4] != _SHARD_MAGIC:
            raise IntegrityError(f"bad magic in {path}")
        crc = int.from_bytes(blob[4:8], "little")
        raw_len = int.from_bytes(blob[8:16], "little")
        mode = blob[16:17]
        payload = blob[17:]
        if crc32(payload) != crc:
            raise IntegrityError(f"crc mismatch in {path}")
        self.ingest.transfer(len(payload))
        if mode == b"Z":
            payload = _zstd.ZstdDecompressor().decompress(payload, max_output_size=raw_len)
        return payload

    def has_shard(self, key: ShardKey) -> bool:
        return os.path.exists(_shard_path(self.root, key))

    # -- manifests -----------------------------------------------------------
    def write_manifest(self, meta: CheckpointMeta) -> None:
        _write_manifest_file(self.root, meta)

    def read_manifest(self, app_id: str, ckpt_id: int) -> Optional[CheckpointMeta]:
        return _read_manifest_file(self.root, app_id, ckpt_id)

    def list_checkpoints(self, app_id: str) -> List[int]:
        return _list_manifest_ckpts(self.root, app_id)

    def checkpoint_complete(self, meta: CheckpointMeta) -> bool:
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                if not self.has_shard(ShardKey(meta.app_id, meta.ckpt_id, name, part)):
                    return False
        return True


# --------------------------------------------------------------------------
# L3: remote object store (S3/GCS analogue)
# --------------------------------------------------------------------------
_OBJECT_MAGIC = b"ICO1"


class RemoteObjectTier:
    """Remote object store behind the PFS — the durability floor (L3).

    What distinguishes an object store from the PFS, and what the lifecycle
    policies have to reason about:

      * every request pays a **latency floor** (``request_latency``, tens of
        milliseconds of HTTP/TLS round-trip) regardless of size — small
        objects are latency-bound, so restart cost is dominated by request
        count, not bytes;
      * a single connection is throughput-limited; large objects move as
        **multipart** transfers of ``part_bytes`` chunks with up to
        ``max_parallel_parts`` concurrent parts (the aggregate ``bandwidth``
        is still shared with every other in-flight operation);
      * capacity is **effectively unbounded** — ``put`` never raises
        :class:`CapacityError`;
      * nothing is free: ingress/egress bytes and every request are billed.
        :meth:`cost_usd` and :meth:`cost_breakdown` expose the running total
        so the retention policy's keep-last-K has a price signal.
    """

    name = "remote_object"
    level = 3.0

    def __init__(self, root: str, bandwidth: float = 5e9,
                 request_latency: float = 0.03, part_bytes: int = 8 << 20,
                 max_parallel_parts: int = 8, clock=None,
                 put_request_usd: float = 5e-6, get_request_usd: float = 4e-7,
                 egress_usd_per_gib: float = 0.09,
                 ingress_usd_per_gib: float = 0.0):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.link = SimNIC("l3-object-store", bandwidth, latency=0.0,
                           clock=clock)
        self.request_latency = float(request_latency)
        self.part_bytes = max(1, int(part_bytes))
        self.max_parallel_parts = max(1, int(max_parallel_parts))
        self.put_request_usd = float(put_request_usd)
        self.get_request_usd = float(get_request_usd)
        self.egress_usd_per_gib = float(egress_usd_per_gib)
        self.ingress_usd_per_gib = float(ingress_usd_per_gib)
        self._lock = threading.Lock()
        self._bytes_in = 0
        self._bytes_out = 0
        self._put_requests = 0
        self._get_requests = 0
        # event bus for retry_exhausted telemetry (wired by the controller)
        self.bus = None
        # fault injection: an unreachable object store (region outage).
        # Transfers raise ConnectionError; existence/listing probes answer
        # as an unreachable endpoint would (nothing visible) so restart
        # ladders fall back to L2/L1 instead of wedging on a read
        self._outage = False
        # payload bytes resident, kept incrementally: used_bytes is read on
        # every telemetry scrape and must not walk the whole object store.
        # One walk at attach time picks up objects from a previous
        # deployment (the cold-restart case).
        self._used = 0
        for key in self.keys():
            self._used += self._object_size(key)

    # -- cost accounting ----------------------------------------------------
    def cost_breakdown(self) -> dict:
        gib = float(1 << 30)
        with self._lock:
            bytes_in, bytes_out = self._bytes_in, self._bytes_out
            puts, gets = self._put_requests, self._get_requests
        return {
            "bytes_in": bytes_in,
            "bytes_out": bytes_out,
            "put_requests": puts,
            "get_requests": gets,
            "ingress_usd": bytes_in / gib * self.ingress_usd_per_gib,
            "egress_usd": bytes_out / gib * self.egress_usd_per_gib,
            "request_usd": puts * self.put_request_usd
            + gets * self.get_request_usd,
        }

    def cost_usd(self) -> float:
        c = self.cost_breakdown()
        return c["ingress_usd"] + c["egress_usd"] + c["request_usd"]

    # -- fault injection ----------------------------------------------------
    def set_outage(self, down: bool) -> None:
        """Make the object store unreachable (or reachable again)."""
        with self._lock:
            self._outage = bool(down)
        self.link.set_down(bool(down))

    @property
    def in_outage(self) -> bool:
        with self._lock:
            return self._outage

    def _check_reachable(self) -> None:
        if self.in_outage:
            raise ConnectionError(f"object store {self.root} unreachable")

    # -- transfer model -----------------------------------------------------
    def _xfer(self, nbytes: int, outbound: bool) -> float:
        """One object transfer, with bounded exponential backoff: a brief
        endpoint blip retries instead of failing the whole tier operation;
        a real outage exhausts the deadline, publishes ``retry_exhausted``
        and surfaces the ConnectionError to the caller."""
        return with_backoff(
            lambda: self._xfer_once(nbytes, outbound), 0.25,
            clock=self.link.clock, retry_on=(ConnectionError,),
            bus=self.bus, what=f"l3_{'get' if outbound else 'put'}")

    def _xfer_once(self, nbytes: int, outbound: bool) -> float:
        """One object transfer: multipart waves of latency + shared bw."""
        self._check_reachable()
        parts = max(1, -(-nbytes // self.part_bytes))
        waves = -(-parts // self.max_parallel_parts)
        lat = self.request_latency * waves
        self.link.clock.sleep(lat)
        dur = lat + self.link.transfer(nbytes)
        with self._lock:
            if outbound:
                self._bytes_out += nbytes
                self._get_requests += parts
            else:
                self._bytes_in += nbytes
                self._put_requests += parts
        return dur

    # -- StorageTier protocol -----------------------------------------------
    @property
    def capacity(self) -> float:
        return float("inf")

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    @property
    def free_bytes(self) -> float:
        return float("inf")

    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> None:
        self.write_shard(key, payload, crc)

    def get(self, key: ShardKey, verify: bool = True) -> bytes:
        return self.read_shard(key)

    def has(self, key: ShardKey) -> bool:
        return self.has_shard(key)

    def _object_size(self, key: ShardKey) -> int:
        """Resident payload bytes of one object (0 if absent)."""
        try:
            return max(os.path.getsize(_shard_path(self.root, key)) - 8, 0)
        except OSError:
            return 0

    def drop(self, key: ShardKey) -> None:
        freed = self._object_size(key)
        try:
            os.remove(_shard_path(self.root, key))
        except OSError:
            return
        with self._lock:
            self._used -= freed

    def keys(self) -> List[ShardKey]:
        out: List[ShardKey] = []
        if not os.path.isdir(self.root):
            return out
        for app_id in os.listdir(self.root):
            base = os.path.join(self.root, app_id)
            if not os.path.isdir(base):
                continue
            for d in os.listdir(base):
                if not d.startswith("ckpt_"):
                    continue
                ckpt_id = int(d.split("_")[1])
                cdir = os.path.join(base, d)
                for region in os.listdir(cdir):
                    rdir = os.path.join(cdir, region)
                    if not os.path.isdir(rdir):
                        continue
                    for fn in os.listdir(rdir):
                        if fn.startswith("part_") and fn.endswith(".bin"):
                            part = int(fn[5:-4])
                            out.append(ShardKey(app_id, ckpt_id,
                                                region.replace("__", "/"),
                                                part))
        return out

    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int:
        base = os.path.join(self.root, app_id, f"ckpt_{ckpt_id:08d}")
        freed = 0
        payload_freed = 0
        if os.path.isdir(base):
            for dirpath, _, files in os.walk(base):
                for fn in files:
                    try:
                        size = os.path.getsize(os.path.join(dirpath, fn))
                    except OSError:
                        continue
                    freed += size
                    if fn.startswith("part_") and fn.endswith(".bin"):
                        payload_freed += max(size - 8, 0)
            shutil.rmtree(base, ignore_errors=True)
            with self._lock:
                self._used -= payload_freed
        return freed

    # -- object IO ----------------------------------------------------------
    def write_shard(self, key: ShardKey, payload: bytes,
                    crc: Optional[int] = None) -> float:
        payload = bytes(payload)
        crc = crc32(payload) if crc is None else crc
        dur = self._xfer(len(payload), outbound=False)
        old = self._object_size(key)
        path = _shard_path(self.root, key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_OBJECT_MAGIC + crc.to_bytes(4, "little"))
            f.write(payload)
        os.replace(tmp, path)       # atomic publish, like a PUT completing
        with self._lock:
            self._used += len(payload) - old
        return dur

    def read_shard(self, key: ShardKey) -> bytes:
        path = _shard_path(self.root, key)
        with open(path, "rb") as f:
            blob = f.read()
        if blob[:4] != _OBJECT_MAGIC:
            raise IntegrityError(f"bad object magic in {path}")
        crc = int.from_bytes(blob[4:8], "little")
        payload = blob[8:]
        if crc32(payload) != crc:
            raise IntegrityError(f"crc mismatch in {path}")
        self._xfer(len(payload), outbound=True)
        return payload

    def has_shard(self, key: ShardKey) -> bool:
        if self.in_outage:
            return False
        return os.path.exists(_shard_path(self.root, key))

    # -- manifests (same container contract as the PFS tier) ---------------
    def write_manifest(self, meta: CheckpointMeta) -> None:
        self._check_reachable()
        with self._lock:
            self._put_requests += 1
        _write_manifest_file(self.root, meta)

    def read_manifest(self, app_id: str, ckpt_id: int) -> Optional[CheckpointMeta]:
        if self.in_outage:
            return None
        # a manifest GET is small but still pays the request round-trip —
        # this is what makes a cold L3 catalog scan expensive in sim time
        self.link.clock.sleep(self.request_latency)
        with self._lock:
            self._get_requests += 1
        return _read_manifest_file(self.root, app_id, ckpt_id)

    def list_checkpoints(self, app_id: str) -> List[int]:
        if self.in_outage:
            return []
        # LIST round-trip, same latency floor as any other request
        self.link.clock.sleep(self.request_latency)
        with self._lock:
            self._get_requests += 1
        return _list_manifest_ckpts(self.root, app_id)

    def checkpoint_complete(self, meta: CheckpointMeta) -> bool:
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                if not self.has_shard(ShardKey(meta.app_id, meta.ckpt_id,
                                               name, part)):
                    return False
        return True


# ==========================================================================
# the per-node pipeline
# ==========================================================================
class TierPipeline:
    """Ordered storage tiers of one iCheck node, fastest first.

    Drop-in for the old single-level ``MemoryStore``: puts land in the
    fastest tier with room (spilling down on :class:`CapacityError`), reads
    search top-down and promote a hit back into the fastest tier when it
    fits.  With a single :class:`MemoryTier` this degenerates to exactly the
    old behaviour, including raising ``CapacityError`` when full — which is
    what lets the controller escalate to the RM for more nodes (§III-A).
    """

    def __init__(self, tiers: Sequence[StorageTier], bus=None,
                 node_id: str = "?"):
        if not tiers:
            raise ICheckError("TierPipeline needs at least one tier")
        self.tiers = list(tiers)
        self.bus = bus
        self.node_id = node_id
        # compound operations (spill on put, promote on get) span tiers;
        # this lock makes them atomic w.r.t. each other, like the single
        # MemoryStore lock they replace (tier-internal locks are not enough:
        # a concurrent reader could observe a shard mid-promotion as absent
        # from both tiers)
        self._lock = threading.RLock()

    # -- capacity accounting (aggregated) ----------------------------------
    @property
    def capacity(self) -> float:
        return sum(t.capacity for t in self.tiers)

    @property
    def used_bytes(self) -> int:
        return sum(t.used_bytes for t in self.tiers)

    @property
    def free_bytes(self) -> float:
        return sum(t.free_bytes for t in self.tiers)

    def _publish(self, name: str, **kw) -> None:
        if self.bus is not None:
            self.bus.publish(name, **kw)

    # -- mapping interface (MemoryStore-compatible) ------------------------
    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> None:
        # events are published only after the pipeline lock is released:
        # a subscriber (e.g. the lifecycle service's watermark check) may
        # synchronously take *other* pipelines' locks, and publishing
        # under our lock would make that an ABBA deadlock
        spilled_into = None
        with self._lock:
            last_err: Optional[CapacityError] = None
            for i, tier in enumerate(self.tiers):
                try:
                    tier.put(key, payload, crc)
                except CapacityError as e:
                    last_err = e
                    continue
                if i > 0:
                    spilled_into = tier.name
                # a put supersedes any stale copy in other tiers
                for j, other in enumerate(self.tiers):
                    if j != i and other.has(key):
                        other.drop(key)
                break
            else:
                raise last_err if last_err is not None \
                    else CapacityError("no tiers")
        if spilled_into is not None:
            # spills happen under the putting agent's span (same thread), so
            # the span lands inside the trace tree; the id re-derivation
            # keeps even bare-pipeline spills attached to their checkpoint
            tracer = getattr(self.bus, "tracer", None)
            if tracer is not None:
                tracer.record("shard_spill",
                              trace_id_for(key.app_id, key.ckpt_id),
                              f"tiers/{self.node_id}", tier=spilled_into,
                              nbytes=len(payload))
            self._publish(_events.SHARD_SPILLED, node=self.node_id,
                          tier=spilled_into, key=str(key),
                          nbytes=len(payload))

    def get(self, key: ShardKey, verify: bool = True,
            promote: bool = True) -> bytes:
        """Top-down read; a lower-tier hit is promoted back into the fastest
        tier unless ``promote=False`` (the drain path reads spilled shards
        in place so it does not undo the watermark policy's demotions)."""
        with self._lock:
            for i, tier in enumerate(self.tiers):
                if not tier.has(key):
                    continue
                payload = tier.get(key, verify=verify)
                if i > 0 and promote:
                    self.promote(key, payload=payload, src=tier)
                return payload
            raise KeyError(key)

    def has(self, key: ShardKey) -> bool:
        with self._lock:
            return any(t.has(key) for t in self.tiers)

    def drop(self, key: ShardKey) -> None:
        with self._lock:
            for tier in self.tiers:
                tier.drop(key)

    def keys(self) -> List[ShardKey]:
        with self._lock:
            seen: Dict[ShardKey, None] = {}
            for tier in self.tiers:
                for k in tier.keys():
                    seen.setdefault(k, None)
            return list(seen.keys())

    def drop_checkpoint(self, app_id: str, ckpt_id: int) -> int:
        with self._lock:
            return sum(t.drop_checkpoint(app_id, ckpt_id) for t in self.tiers)

    # -- promotion / demotion ----------------------------------------------
    def promote(self, key: ShardKey, payload: Optional[bytes] = None,
                src: Optional[StorageTier] = None) -> bool:
        """Move a shard up into the fastest tier (best effort)."""
        with self._lock:
            top = self.tiers[0]
            if top.has(key):
                return False
            if src is None:
                src = next((t for t in self.tiers[1:] if t.has(key)), None)
                if src is None:
                    return False
            if payload is None:
                payload = src.get(key, verify=False)
            try:
                top.put(key, payload)
            except CapacityError:
                return False
            src.drop(key)
        self._publish(_events.SHARD_PROMOTED, node=self.node_id, key=str(key),
                      src=src.name, dst=top.name, nbytes=len(payload))
        return True

    def demote(self, key: ShardKey) -> bool:
        """Push a shard from the fastest tier one level down (free RAM).

        A demotion that cannot happen publishes ``DEMOTE_FAILED`` with the
        reason instead of only returning ``False`` — the lifecycle service's
        watermark decisions have to stay observable.  Events are published
        after the lock is released (see :meth:`put`).
        """
        failure = None
        nbytes = 0
        with self._lock:
            if len(self.tiers) < 2:
                failure = {"reason": "no_lower_tier"}
            elif not self.tiers[0].has(key):
                failure = {"reason": "not_resident"}
            else:
                payload = self.tiers[0].get(key, verify=False)
                nbytes = len(payload)
                try:
                    self.tiers[1].put(key, payload)
                except CapacityError:
                    failure = {"reason": "lower_tier_full",
                               "tier": self.tiers[1].name}
                else:
                    self.tiers[0].drop(key)
        if failure is not None:
            self._publish(_events.DEMOTE_FAILED, node=self.node_id,
                          key=str(key), **failure)
            return False
        # structured app/ckpt/region fields ride along so chain owners (the
        # catalog resets a delta chain whose frames get demoted) don't have
        # to parse the stringified key
        self._publish(_events.SHARD_DEMOTED, node=self.node_id,
                      src=self.tiers[0].name, dst=self.tiers[1].name,
                      key=str(key), nbytes=nbytes, app=key.app_id,
                      ckpt=key.ckpt_id, region=key.region)
        return True

    def close(self) -> None:
        for tier in self.tiers:
            closer = getattr(tier, "close", None)
            if closer is not None:
                closer()
