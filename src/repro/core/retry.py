"""Unified transient-fault retry policy: jittered exponential backoff
under a per-operation deadline.

Every layer that talks to a flaky remote endpoint (object-store transfers,
manifest reads, peer-read provider fallback) used to carry its own ad-hoc
single-retry loop.  :func:`with_backoff` replaces those: it retries the
callable on the listed transient errors, sleeping an exponentially growing,
deterministically jittered interval between attempts, until the per-op
deadline would be exceeded — then it publishes ``retry_exhausted`` (when a
bus is provided) and re-raises the last error, so callers keep their
existing exception contract but the telemetry sees the exhaustion instead
of a bare raise.

The jitter is *deterministic* (a CRC of ``(what, attempt, seed)``), never
``random``: the whole system runs on a simulated clock and chaos campaigns
replay seeded schedules, so retry timing must be a pure function of its
inputs.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Type
from zlib import crc32

from . import events as E

TRANSIENT_ERRORS: Tuple[Type[BaseException], ...] = (
    ConnectionError, TimeoutError, OSError)


def _jitter_frac(what: str, attempt: int, seed: int) -> float:
    """Deterministic pseudo-random fraction in [0, 1)."""
    return crc32(f"{what}|{attempt}|{seed}".encode()) / 2**32


def with_backoff(op: Callable, deadline_s: float, *, clock=None,
                 base_s: float = 0.01, factor: float = 2.0,
                 jitter: float = 0.5,
                 retry_on: Tuple[Type[BaseException], ...] = TRANSIENT_ERRORS,
                 bus=None, what: str = "op", seed: int = 0):
    """Call ``op()`` with jittered exponential backoff under a deadline.

    Returns ``op()``'s result on the first success.  A transient error
    (``retry_on``) schedules a retry after ``base_s * factor**attempt``
    seconds (scaled by up to ``jitter`` deterministic extra); when the next
    sleep would push past ``deadline_s`` total, the policy gives up:
    ``retry_exhausted`` is published on ``bus`` (when given) and the last
    error is re-raised.  Non-transient errors propagate immediately.

    ``clock`` (a SimClock) keeps both the sleeps and the deadline on
    simulated time; without one, wall time is used.
    """
    now = clock.now if clock is not None else time.monotonic
    sleep = clock.sleep if clock is not None else time.sleep
    start = now()
    attempt = 0
    while True:
        try:
            return op()
        except retry_on as err:
            wait = base_s * factor ** attempt
            wait *= 1.0 + jitter * _jitter_frac(what, attempt, seed)
            attempt += 1
            if now() + wait > start + deadline_s:
                if bus is not None:
                    bus.publish(E.RETRY_EXHAUSTED, what=what,
                                attempts=attempt,
                                elapsed_s=now() - start, error=repr(err))
                raise
            sleep(wait)


def retry_deadline(deadline_s: float, **kwargs):
    """Partial-application helper: a reusable policy with fixed options."""
    def call(op: Callable, *, what: str = "op", seed: int = 0):
        return with_backoff(op, deadline_s, what=what, seed=seed, **kwargs)
    return call
