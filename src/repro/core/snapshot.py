"""Device→host snapshots of sharded JAX pytrees.

This is the bridge between a JAX application's ``TrainState`` and iCheck's
byte-oriented agents: every pytree leaf becomes a *region* whose parts are
the distinct device shards (deduplicated across replicas).  The device→host
copy is issued asynchronously for all leaves first (``copy_to_host_async`` —
the TPU DMA analogue of the paper's RDMA source buffers) and only then
gathered, so device compute can proceed underneath.

With ``codec="q8"`` / ``codec="q8-delta"`` the encode runs **on device**
before the D2H copy: each float region part goes through
``kernels/ckpt_codec.quantize`` (or ``quantize_delta`` against the
catalog's previous-codes state from ``chain_lookup``), so the host pulls
int8 codes + 1/256 overhead of f32 scales — ~4x fewer D2H bytes than the
raw f32 leaves — and the resulting :class:`~repro.core.tiers.EncodedRegion`
frames travel the client→agent fabric and the storage tiers as-is
(``ICheckClient.commit_snapshot``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import plan as planlib
from ..kernels.ckpt_codec.blocks import BLOCK
from .tiers import (DeltaState, EncodedRegion, is_float_dtype, pack_q8_region,
                    q8_pack_full)
from .types import PartitionDesc, PartitionScheme, RegionMeta


def _leaf_name(path) -> str:
    import jax

    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts) or "leaf"


@dataclasses.dataclass
class SnapshotRegion:
    meta: RegionMeta
    parts: Dict[int, np.ndarray]          # part index -> host array (local shard)
    boxes: Tuple[planlib.Box, ...]        # global boxes, canonical order
    # device-encoded wire frames (q8 / q8-delta); when set, ``parts`` is
    # empty — the raw f32 payload never crossed the D2H link
    encoded: Optional[EncodedRegion] = None


@dataclasses.dataclass
class HostSnapshot:
    regions: Dict[str, SnapshotRegion]
    step: int = 0

    def total_bytes(self) -> int:
        """Bytes held on the host (raw parts + encoded wire frames)."""
        total = 0
        for r in self.regions.values():
            total += sum(p.nbytes for p in r.parts.values())
            if r.encoded is not None:
                total += sum(len(b) for b in r.encoded.blobs.values())
        return total


def leaf_names(tree) -> List[str]:
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_leaf_name(path) for path, _ in flat]


def _device_parts(leaf) -> Tuple[Tuple[planlib.Box, ...], Dict[int, Any],
                                 PartitionDesc]:
    """Distinct device shards of one leaf (replicas deduplicated), without
    forcing a host copy: part index -> device (or numpy) array."""
    arr = leaf
    if not hasattr(arr, "addressable_shards"):
        arr = np.asarray(arr)
    if isinstance(arr, np.ndarray):
        boxes = (tuple((0, s) for s in arr.shape),)
        parts: Dict[int, Any] = {0: arr}
        desc = PartitionDesc(scheme=PartitionScheme.MESH, num_parts=1,
                             bounds=boxes)
        return boxes, parts, desc
    shape = tuple(arr.shape)
    boxes = planlib.mesh_part_bounds(shape, arr.sharding)
    box_index = {b: i for i, b in enumerate(boxes)}
    parts = {}
    for sh in arr.addressable_shards:
        box = []
        for d, sl in enumerate(sh.index):
            lo = 0 if sl.start is None else int(sl.start)
            hi = shape[d] if sl.stop is None else int(sl.stop)
            box.append((lo, hi))
        idx = box_index[tuple(box)]
        if idx not in parts:                       # skip replicas
            parts[idx] = sh.data
    desc = PartitionDesc(scheme=PartitionScheme.MESH,
                         num_parts=len(boxes), bounds=boxes)
    return boxes, parts, desc


def _chain_states(chain_lookup, name: str, num_parts: int,
                  part_sizes: Dict[int, int]):
    """Previous-codes state usable for a device-side delta encode of this
    region, or (None, None) when the next frame must be a keyframe."""
    if chain_lookup is None:
        return None, None
    rc = chain_lookup(name, num_parts)
    if rc is None:
        return None, None
    prev: Dict[int, DeltaState] = dict(rc.parts)
    for p, n in part_sizes.items():
        st = prev.get(p)
        nb = -(-max(n, 1) // BLOCK)
        if st is None or st.n != n or st.codes.shape[0] != nb:
            return None, None
    return prev, tuple(rc.chain)


def snapshot_pytree(tree, step: int = 0, codec: str = "raw",
                    chain_lookup=None, impl: Optional[str] = None
                    ) -> HostSnapshot:
    """Snapshot a pytree of (possibly sharded) jax.Arrays to host memory.

    ``codec="q8"`` / ``"q8-delta"``: float leaves are quantized on device
    (``kernels/ckpt_codec``) before the D2H copy; ``chain_lookup(name,
    num_parts)`` supplies the catalog's previous-codes state so ``q8-delta``
    regions ship sparse XOR-delta frames (``ICheckClient.delta_chain_lookup``
    is the intended callable).  Non-float leaves always travel raw.
    """
    import jax

    encode = codec in ("q8", "q8-delta")
    if encode:
        from ..kernels.ckpt_codec import quantize, quantize_delta

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    # 1) kick all async D2H copies; for encoded leaves, launch the device
    #    quantize first and async-copy its (int8, f32/256) outputs instead
    #    of the raw leaf
    work: Dict[str, dict] = {}
    for path, leaf in flat:
        name = _leaf_name(path)
        leaf_dtype = getattr(leaf, "dtype", None)
        if leaf_dtype is None:
            leaf_dtype = np.asarray(leaf).dtype
        if encode and is_float_dtype(leaf_dtype):
            boxes, parts, desc = _device_parts(leaf)
            sizes = {p: int(np.prod(np.shape(a)) or 1)
                     for p, a in parts.items()}
            prev = parent_chain = None
            if codec == "q8-delta":
                prev, parent_chain = _chain_states(
                    chain_lookup, name, desc.num_parts, sizes)
            t0 = time.monotonic()
            outs = {}
            for p, a in parts.items():
                if prev is not None:
                    prev_q = prev[p].codes_dev
                    if prev_q is None:
                        prev_q = prev[p].codes
                    d, s, q = quantize_delta(a, prev_q, impl=impl)
                    # the dense int8 XOR delta + scales cross D2H (~1/4 of
                    # the f32 bytes; sparsification happens host-side); the
                    # new full codes q stay device-resident for the next
                    # commit so nothing is uploaded back
                    outs[p] = (d, s, q)
                else:
                    q, s = quantize(a, impl=impl)
                    outs[p] = (q, s, q)
            for d_or_q, s, _ in outs.values():
                for out in (d_or_q, s):
                    if hasattr(out, "copy_to_host_async"):
                        out.copy_to_host_async()
            work[name] = {"boxes": boxes, "desc": desc, "sizes": sizes,
                          "outs": outs, "prev": prev,
                          "parent_chain": parent_chain,
                          "launch_s": time.monotonic() - t0}
        elif hasattr(leaf, "copy_to_host_async"):
            leaf.copy_to_host_async()
    # 2) gather per-shard host arrays / pack the encoded wire frames
    regions: Dict[str, SnapshotRegion] = {}
    for path, leaf in flat:
        name = _leaf_name(path)
        if name in work:
            regions[name] = _gather_encoded(name, leaf, codec, work[name])
            continue
        boxes, dev_parts, desc = _device_parts(leaf)
        parts = {p: np.asarray(a) for p, a in dev_parts.items()}
        np_dtype = parts[0].dtype if parts else np.dtype("float32")
        meta = RegionMeta(name=name, shape=tuple(np.shape(leaf)),
                          dtype=str(np_dtype),
                          partition=desc,
                          nbytes=sum(p.nbytes for p in parts.values()))
        regions[name] = SnapshotRegion(meta=meta, parts=parts, boxes=boxes)
    return HostSnapshot(regions=regions, step=step)


def _gather_encoded(name: str, leaf, codec: str, w: dict) -> SnapshotRegion:
    """Finish one device-encoded region: D2H the codes/scales, reconstruct
    codes from deltas (host XOR), frame via the shared packer."""
    t0 = time.monotonic()
    prev: Optional[Dict[int, DeltaState]] = w["prev"]
    qparts: Dict[int, Tuple[int, np.ndarray, np.ndarray]] = {}
    dev_codes = {}
    dense_deltas: Dict[int, np.ndarray] = {}
    for p, (d_or_q, s, q_dev) in w["outs"].items():
        a = np.asarray(d_or_q)
        scales = np.asarray(s).astype(np.float32, copy=False)
        if prev is not None:
            # the kernel shipped the XOR delta; reconstruct the full codes
            # from the host-side previous codes (one int8 XOR — the packer
            # reuses the dense delta instead of re-deriving it)
            codes = np.bitwise_xor(prev[p].codes, a)
            dense_deltas[p] = a
        else:
            codes = a
        qparts[p] = (w["sizes"][p], codes, scales)
        dev_codes[p] = q_dev
    np_dtype = getattr(leaf, "dtype", None)
    np_dtype = np.dtype(np_dtype) if np_dtype is not None \
        else np.asarray(leaf).dtype
    raw_nbytes = sum(n * np_dtype.itemsize for n, _, _ in qparts.values())
    if codec == "q8-delta":
        blobs, states, frame = pack_q8_region(qparts, prev,
                                              deltas=dense_deltas or None)
        for p, st in states.items():
            st.codes_dev = dev_codes.get(p)
        enc = EncodedRegion(codec=codec, blobs=blobs, states=states,
                            frame=frame, raw_nbytes=raw_nbytes,
                            parent_chain=w["parent_chain"],
                            encode_s=w["launch_s"] + time.monotonic() - t0)
    else:
        blobs = {p: q8_pack_full(n, codes, scales)
                 for p, (n, codes, scales) in qparts.items()}
        enc = EncodedRegion(codec=codec, blobs=blobs, states=None,
                            frame=None, raw_nbytes=raw_nbytes,
                            encode_s=w["launch_s"] + time.monotonic() - t0)
    meta = RegionMeta(name=name, shape=tuple(np.shape(leaf)),
                      dtype=str(np_dtype), partition=w["desc"],
                      nbytes=raw_nbytes, codec=codec)
    return SnapshotRegion(meta=meta, parts={}, boxes=w["boxes"], encoded=enc)


def restore_pytree(template, regions: Dict[str, Dict[int, np.ndarray]],
                   region_meta: Dict[str, RegionMeta],
                   shardings: Optional[Dict[str, Any]] = None):
    """Rebuild a pytree of jax.Arrays from fetched region parts.

    ``template`` provides structure + avals (e.g. from ``jax.eval_shape``);
    ``shardings`` maps leaf name → target Sharding (None → commit to default
    device layout).  Parts may come from a *different* partitioning than the
    target: they are reassembled via their recorded boxes and re-split by
    ``device_put`` — the caller can instead use ``ICheckClient.redistribute``
    to move only the needed slices.
    """
    import jax

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        name = _leaf_name(path)
        meta = region_meta[name]
        parts = regions[name]
        if meta.partition.scheme == PartitionScheme.MESH:
            boxes = meta.partition.bounds
            full = np.empty(meta.shape, dtype=np.dtype(meta.dtype))
            for idx, part in parts.items():
                dsl = tuple(slice(lo, hi) for lo, hi in boxes[idx])
                full[dsl] = part.reshape([hi - lo for lo, hi in boxes[idx]])
        else:
            ordered = [parts[i] for i in range(meta.partition.num_parts)]
            full = planlib.assemble_array(ordered, meta.partition, meta.shape)
        target_dtype = getattr(leaf, "dtype", full.dtype)
        full = full.astype(target_dtype, copy=False)
        sharding = (shardings or {}).get(name)
        if sharding is not None:
            leaves.append(jax.device_put(full, sharding))
        else:
            leaves.append(jax.device_put(full))
    return jax.tree_util.tree_unflatten(treedef, leaves)
