"""Device→host snapshots of sharded JAX pytrees.

This is the bridge between a JAX application's ``TrainState`` and iCheck's
byte-oriented agents: every pytree leaf becomes a *region* whose parts are
the distinct device shards (deduplicated across replicas).  The device→host
copy is issued asynchronously for all leaves first (``copy_to_host_async`` —
the TPU DMA analogue of the paper's RDMA source buffers) and only then
gathered, so device compute can proceed underneath.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import plan as planlib
from .types import PartitionDesc, PartitionScheme, RegionMeta


def _leaf_name(path) -> str:
    import jax

    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts) or "leaf"


@dataclasses.dataclass
class SnapshotRegion:
    meta: RegionMeta
    parts: Dict[int, np.ndarray]          # part index -> host array (local shard)
    boxes: Tuple[planlib.Box, ...]        # global boxes, canonical order


@dataclasses.dataclass
class HostSnapshot:
    regions: Dict[str, SnapshotRegion]
    step: int = 0

    def total_bytes(self) -> int:
        return sum(p.nbytes for r in self.regions.values()
                   for p in r.parts.values())


def leaf_names(tree) -> List[str]:
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_leaf_name(path) for path, _ in flat]


def snapshot_pytree(tree, step: int = 0) -> HostSnapshot:
    """Snapshot a pytree of (possibly sharded) jax.Arrays to host memory."""
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    # 1) kick off all async D2H copies
    for _, leaf in flat:
        if hasattr(leaf, "copy_to_host_async"):
            leaf.copy_to_host_async()
    # 2) gather per-shard host arrays
    regions: Dict[str, SnapshotRegion] = {}
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = leaf
        if not hasattr(arr, "addressable_shards"):
            arr = np.asarray(arr)
        if isinstance(arr, np.ndarray):
            boxes = (tuple((0, s) for s in arr.shape),)
            parts = {0: arr}
            desc = PartitionDesc(scheme=PartitionScheme.MESH, num_parts=1,
                                 bounds=boxes)
        else:
            shape = tuple(arr.shape)
            boxes = planlib.mesh_part_bounds(shape, arr.sharding)
            box_index = {b: i for i, b in enumerate(boxes)}
            parts = {}
            for sh in arr.addressable_shards:
                box = []
                for d, sl in enumerate(sh.index):
                    lo = 0 if sl.start is None else int(sl.start)
                    hi = shape[d] if sl.stop is None else int(sl.stop)
                    box.append((lo, hi))
                idx = box_index[tuple(box)]
                if idx not in parts:                       # skip replicas
                    parts[idx] = np.asarray(sh.data)
            desc = PartitionDesc(scheme=PartitionScheme.MESH,
                                 num_parts=len(boxes), bounds=boxes)
        np_dtype = parts[0].dtype if parts else np.dtype("float32")
        meta = RegionMeta(name=name, shape=tuple(np.shape(leaf)),
                          dtype=str(np_dtype),
                          partition=desc,
                          nbytes=sum(p.nbytes for p in parts.values()))
        regions[name] = SnapshotRegion(meta=meta, parts=parts, boxes=boxes)
    return HostSnapshot(regions=regions, step=step)


def restore_pytree(template, regions: Dict[str, Dict[int, np.ndarray]],
                   region_meta: Dict[str, RegionMeta],
                   shardings: Optional[Dict[str, Any]] = None):
    """Rebuild a pytree of jax.Arrays from fetched region parts.

    ``template`` provides structure + avals (e.g. from ``jax.eval_shape``);
    ``shardings`` maps leaf name → target Sharding (None → commit to default
    device layout).  Parts may come from a *different* partitioning than the
    target: they are reassembled via their recorded boxes and re-split by
    ``device_put`` — the caller can instead use ``ICheckClient.redistribute``
    to move only the needed slices.
    """
    import jax

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        name = _leaf_name(path)
        meta = region_meta[name]
        parts = regions[name]
        if meta.partition.scheme == PartitionScheme.MESH:
            boxes = meta.partition.bounds
            full = np.empty(meta.shape, dtype=np.dtype(meta.dtype))
            for idx, part in parts.items():
                dsl = tuple(slice(lo, hi) for lo, hi in boxes[idx])
                full[dsl] = part.reshape([hi - lo for lo, hi in boxes[idx]])
        else:
            ordered = [parts[i] for i in range(meta.partition.num_parts)]
            full = planlib.assemble_array(ordered, meta.partition, meta.shape)
        target_dtype = getattr(leaf, "dtype", full.dtype)
        full = full.astype(target_dtype, copy=False)
        sharding = (shardings or {}).get(name)
        if sharding is not None:
            leaves.append(jax.device_put(full, sharding))
        else:
            leaves.append(jax.device_put(full))
    return jax.tree_util.tree_unflatten(treedef, leaves)
