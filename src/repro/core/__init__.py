"""iCheck core — the paper's primary contribution.

An adaptive, asynchronous, multi-level, application-level checkpoint
management system with a data-redistribution service for malleable
applications (John & Gerndt, 2022), adapted from MPI clusters to elastic
JAX/TPU training (see DESIGN.md §2).
"""
from .agent import Agent, AgentDead
from .client import CommitHandle, ICheckClient
from .cluster import ICheckCluster
from .controller import Controller
from .events import AuditLog, Event, EventBus
from .malleable import MalleableApp, ProcType
from .manager import Manager
from .plan import (Move, MeshMove, apply_mesh_moves, apply_moves,
                   assemble_array, boxes_to_desc, local_shape, mesh_moves,
                   mesh_part_bounds, partition_intervals,
                   redistribution_moves, split_array)
from .policies import (AdaptivePolicy, BandwidthBalancedPolicy,
                       MemoryAwarePolicy, StaticPolicy, get_policy)
from .rm import ResizeEvent, ResourceManager
from .services import (IntervalController, StorageLifecycleService,
                       TelemetryService, daly_interval, young_interval)
from .simnet import EWMA, FaultInjector, SimClock, SimNIC
from .snapshot import HostSnapshot, restore_pytree, snapshot_pytree
from .tiers import (DeltaState, EncodedRegion, LocalDiskTier, MemoryTier,
                    PFSTier, RemoteObjectTier, StorageTier, TierPipeline,
                    crc32, decode_payload, encode_delta_region,
                    encode_payload, q8_chain_decode, resolve_codec)
from .store import MemoryStore, PFSStore
from .types import (AppRecord, AppStatus, CheckpointMeta, CkptStatus,
                    ICheckError, IntegrityError, CapacityError, NodeSpec,
                    PartitionDesc, PartitionScheme, RegionMeta, RestoreError,
                    ShardInfo, ShardKey)

__all__ = [
    "Agent", "AgentDead", "CommitHandle", "ICheckClient", "ICheckCluster",
    "Controller", "AuditLog", "Event", "EventBus", "MalleableApp",
    "ProcType", "Manager", "Move", "MeshMove",
    "apply_mesh_moves", "apply_moves", "assemble_array", "boxes_to_desc",
    "local_shape", "mesh_moves", "mesh_part_bounds", "partition_intervals",
    "redistribution_moves", "split_array", "AdaptivePolicy",
    "BandwidthBalancedPolicy", "MemoryAwarePolicy", "StaticPolicy",
    "get_policy", "ResizeEvent", "ResourceManager",
    "IntervalController", "StorageLifecycleService", "TelemetryService",
    "daly_interval", "young_interval", "EWMA", "FaultInjector",
    "SimClock", "SimNIC", "HostSnapshot", "restore_pytree", "snapshot_pytree",
    "MemoryStore", "PFSStore", "MemoryTier", "PFSTier", "LocalDiskTier",
    "RemoteObjectTier", "StorageTier", "TierPipeline", "crc32", "encode_payload",
    "decode_payload", "resolve_codec", "DeltaState", "EncodedRegion",
    "encode_delta_region", "q8_chain_decode", "AppRecord", "AppStatus",
    "CheckpointMeta", "CkptStatus", "ICheckError", "IntegrityError",
    "CapacityError", "NodeSpec", "PartitionDesc", "PartitionScheme",
    "RegionMeta", "RestoreError", "ShardInfo", "ShardKey",
]
