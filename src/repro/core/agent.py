"""iCheck Agents.

"The agent performs the functionality of checkpoint read/write (using
libfabric) and data redistribution (for malleable implementations).  Multiple
agents can be assigned to a single application, and iCheck can dynamically
change the agent count to obtain an optimum checkpoint transfer rate." (§II)

An Agent here is a worker thread bound to an iCheck node's storage tiers
(a ``TierPipeline``: L1 RAM + optional L0.5 local-disk spill) and NIC.
Writes (RDMA puts from the application) and L2 drains run through its
queue; reads for restart/redistribution are served concurrently off the
thread-safe tiers with simulated NIC time.  All payloads are real bytes —
and opaque: with the ``q8``/``q8-delta`` codecs the client ships int8
(sparse-delta) wire frames, so agents, drains and every tier move the
already-compressed bytes and never re-encode.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import Callable, List, Optional

from .simnet import EWMA, FaultInjector, SimNIC
from .tiers import PFSTier, TierPipeline
from .types import AgentId, NodeId, ShardKey, TransferRecord


class AgentDead(ConnectionError):
    pass


class _Op:
    __slots__ = ("kind", "key", "payload", "crc", "future", "pfs", "on_done")

    def __init__(self, kind, key=None, payload=None, crc=None, future=None,
                 pfs=None, on_done=None):
        self.kind = kind
        self.key = key
        self.payload = payload
        self.crc = crc
        self.future = future
        self.pfs = pfs
        self.on_done = on_done


class Agent:
    """One checkpoint agent living on an iCheck node."""

    def __init__(self, agent_id: AgentId, node_id: NodeId, store: TierPipeline,
                 nic: SimNIC, fault: Optional[FaultInjector] = None):
        self.agent_id = agent_id
        self.node_id = node_id
        self.store = store
        self.nic = nic
        self.fault = fault or FaultInjector()
        self._inbox: "queue.Queue[_Op]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, name=f"agent-{agent_id}",
                                        daemon=True)
        self._lock = threading.Lock()
        self.transfers: List[TransferRecord] = []
        self.rate_ewma = EWMA(alpha=0.4)      # observed bytes/sim-second
        self.bytes_in = 0
        self._thread.start()

    # ------------------------------------------------------------------ RDMA
    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None) -> Future:
        """Non-blocking RDMA-put analogue.  Returns a Future that resolves to
        a TransferRecord once the shard has landed in L1."""
        fut: Future = Future()
        self._inbox.put(_Op("put", key=key, payload=payload, crc=crc, future=fut))
        return fut

    def get(self, key: ShardKey) -> bytes:
        """Read a shard back (restart / redistribution path)."""
        self._check_alive()
        payload = self.store.get(key)          # crc-verified
        self.nic.transfer(len(payload))
        return payload

    def has(self, key: ShardKey) -> bool:
        return self.store.has(key)

    # ------------------------------------------------------------------ L2
    def drain(self, keys: List[ShardKey], pfs: PFSTier,
              on_done: Optional[Callable] = None) -> Future:
        """Write the given L1 shards to the PFS (asynchronously)."""
        fut: Future = Future()
        self._inbox.put(_Op("drain", key=keys, pfs=pfs, future=fut, on_done=on_done))
        return fut

    # ------------------------------------------------------------------ admin
    def alive(self) -> bool:
        return (not self._stop.is_set()
                and not self.fault.agent_dead(self.agent_id)
                and not self.fault.node_dead(self.node_id))

    def stop(self) -> None:
        self._stop.set()
        self._inbox.put(_Op("stop"))
        self._thread.join(timeout=5)

    def observed_rate(self) -> float:
        """Predicted ingest rate (bytes / simulated second)."""
        r = self.rate_ewma.predict()
        return r if r > 0 else self.nic.bandwidth

    def stats(self) -> dict:
        with self._lock:
            return {
                "agent_id": self.agent_id,
                "node_id": self.node_id,
                "bytes_in": self.bytes_in,
                "transfers": len(self.transfers),
                "rate_ewma": self.rate_ewma.predict(),
            }

    # ------------------------------------------------------------------ guts
    def _check_alive(self) -> None:
        if not self.alive():
            raise AgentDead(f"agent {self.agent_id} on node {self.node_id} is dead")

    def _run(self) -> None:
        while not self._stop.is_set():
            op = self._inbox.get()
            if op.kind == "stop":
                break
            try:
                if op.kind == "put":
                    rec = self._do_put(op)
                    op.future.set_result(rec)
                elif op.kind == "drain":
                    res = self._do_drain(op)
                    op.future.set_result(res)
                    if op.on_done:
                        op.on_done(res)
            except BaseException as e:  # noqa: BLE001 - surface through future
                if op.future is not None and not op.future.done():
                    op.future.set_exception(e)

    def _do_put(self, op: _Op) -> TransferRecord:
        self._check_alive()
        payload = op.payload
        # straggler injection slows this agent's transfers only
        slow = self.fault.agent_slowdown(self.agent_id)
        sim = self.nic.transfer(len(payload))
        if slow > 1.0:
            extra = sim * (slow - 1.0)
            self.nic.clock.sleep(extra)
            sim += extra
        self._check_alive()  # may have died mid-transfer
        self.store.put(op.key, payload, crc=op.crc)
        rec = TransferRecord(key=op.key, nbytes=len(payload),
                             agent_id=self.agent_id, sim_seconds=sim)
        with self._lock:
            self.transfers.append(rec)
            self.bytes_in += len(payload)
            if sim > 0:
                self.rate_ewma.update(len(payload) / sim)
        return rec

    def _do_drain(self, op: _Op) -> dict:
        self._check_alive()
        written = 0
        sim_total = 0.0
        for key in op.key:
            # read in place: draining a demoted/spilled shard must not pull
            # it back into RAM (that would undo the watermark policy)
            payload = self.store.get(key, promote=False)
            sim_total += op.pfs.write_shard(key, payload)
            written += len(payload)
        return {"bytes": written, "sim_seconds": sim_total, "keys": list(op.key)}
