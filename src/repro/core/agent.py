"""iCheck Agents.

"The agent performs the functionality of checkpoint read/write (using
libfabric) and data redistribution (for malleable implementations).  Multiple
agents can be assigned to a single application, and iCheck can dynamically
change the agent count to obtain an optimum checkpoint transfer rate." (§II)

An Agent here is a worker thread bound to an iCheck node's storage tiers
(a ``TierPipeline``: L1 RAM + optional L0.5 local-disk spill) and NIC.
Writes (RDMA puts from the application) and L2 drains run through its
queue; reads for restart/redistribution are served concurrently off the
thread-safe tiers with simulated NIC time.  All payloads are real bytes —
and opaque: with the ``q8``/``q8-delta`` codecs the client ships int8
(sparse-delta) wire frames, so agents, drains and every tier move the
already-compressed bytes and never re-encode.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..obs import trace_id_for
from .retry import with_backoff
from .simnet import EWMA, FaultInjector, MemBus, SimNIC
from .tiers import (PFSTier, SliceState, TierPipeline, decode_payload,
                    decode_slice_frames, ec_decode_shard, ec_encode_shard,
                    ec_parse_fragment, replay_slice_frames, slice_payload)
from .types import (AgentId, ICheckError, IntegrityError, NodeId, RestoreError,
                    ShardKey, TransferRecord)


class AgentDead(ConnectionError):
    pass


@dataclasses.dataclass(frozen=True)
class SliceFetch:
    """One transfer-program op, fully resolved: pull flattened elements
    [vlo, vhi) of a source shard (replaying ``sources`` in chain order for
    ``q8-delta``) and land them at ``dst_lo`` of the assembled buffer.

    Each source is ``(provider, key)`` where the provider is the holding
    :class:`Agent` (peer read over the fabric) or a shared tier with a
    ``read_shard`` method (PFS/L3 fallback, sliced locally after the read).
    """

    vlo: int
    vhi: int
    dst_lo: int
    codec: str
    dtype: str
    sources: Tuple[Tuple[object, ShardKey], ...]


@dataclasses.dataclass(frozen=True)
class AssembleSpec:
    """One destination part of a redistribution: the scratch key the
    assembled payload lands under in this agent's L1, and its slice reads.

    ``keep_state`` retains the per-fetch q8 decode state
    (:class:`~.tiers.SliceState`) after the assembly, so a zero-stall
    cutover can later :meth:`~Agent.replay` tail delta frames onto the
    stored payload instead of re-streaming the keyframe."""

    out_key: ShardKey
    dtype: str
    nvals: int
    fetches: Tuple[SliceFetch, ...]
    keep_state: bool = False


@dataclasses.dataclass(frozen=True)
class RebuildSpec:
    """Peer rebuild of erasure-coded fragments lost with an agent/node.

    This agent gathers any ``k`` surviving fragments of one stripe from the
    ``sources`` (whole-fragment peer reads over MemBus/NIC — a dead or
    partitioned source is skipped, not fatal), GF-decodes the payload,
    re-derives the ``want`` fragments and hosts them in its own L1.  When
    fewer than ``k`` peer fragments survive, the ``fallback`` providers
    (PFS/L3, holding the *full* shard under ``base_key``) supply the payload
    instead, so a rebuild racing further failures degrades to a lower tier
    rather than wedging.
    """

    base_key: ShardKey           # replica-0 identity of the logical shard
    k: int
    m: int
    want: Tuple[int, ...]        # ShardKey.replica values to regenerate here
    sources: Tuple[Tuple["Agent", ShardKey], ...]
    fallback: Tuple[Tuple[object, ShardKey], ...] = ()


@dataclasses.dataclass(frozen=True)
class ReplaySpec:
    """Tail catch-up of one already-assembled destination part: advance the
    retained slice states by the delta frames committed during an overlap
    window and patch the stored scratch payload in place.  ``fetches`` must
    cover the same (vlo, vhi, dst_lo) ranges as the original assemble, with
    ``sources`` listing only the *tail* chain frames."""

    out_key: ShardKey
    dtype: str
    fetches: Tuple[SliceFetch, ...]


class _Op:
    __slots__ = ("kind", "key", "payload", "crc", "future", "pfs", "on_done",
                 "trace", "epoch")

    def __init__(self, kind, key=None, payload=None, crc=None, future=None,
                 pfs=None, on_done=None, trace=None, epoch=None):
        self.kind = kind
        self.key = key
        self.payload = payload
        self.crc = crc
        self.future = future
        self.pfs = pfs
        self.on_done = on_done
        # TraceContext of the submitting thread: the inbox hand-off crosses
        # threads, so causality must ride the op itself
        self.trace = trace
        # controller epoch current when the op was submitted; the dispatch
        # loop refuses ops stamped before a controller recovery
        self.epoch = epoch


class Agent:
    """One checkpoint agent living on an iCheck node."""

    def __init__(self, agent_id: AgentId, node_id: NodeId, store: TierPipeline,
                 nic: SimNIC, fault: Optional[FaultInjector] = None,
                 membus: Optional[MemBus] = None, tracer=None, fence=None,
                 bus=None):
        self.agent_id = agent_id
        self.node_id = node_id
        self.store = store
        self.nic = nic
        self.membus = membus
        self.tracer = tracer
        self.fence = fence          # controller EpochFence (None = unfenced)
        self.bus = bus              # controller EventBus (telemetry only)
        self.fault = fault or FaultInjector()
        self.peer_reads = 0
        self.peer_bytes_out = 0
        # decoded-payload memo (ShardKey → raw bytes): a zstd source shard
        # serves many slice reads during one redistribution — possibly
        # interleaved across shards — so decompress each once per adapt
        # window, not once per TransferOp (other codecs slice the stored
        # bytes directly).  Cleared by the engine when the window ends.
        self._decoded_memo: Dict[ShardKey, bytes] = {}
        # retained q8 decode state of keep_state assemblies (scratch key →
        # per-fetch SliceState), consumed by replay() at zero-stall cutover
        # and dropped with the scratch shard when the window ends
        self._assembly_state: Dict[ShardKey, List[Optional[SliceState]]] = {}
        self._inbox: "queue.Queue[_Op]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, name=f"agent-{agent_id}",
                                        daemon=True)
        self._lock = threading.Lock()
        self.transfers: List[TransferRecord] = []
        self.rate_ewma = EWMA(alpha=0.4)      # observed bytes/sim-second
        self.bytes_in = 0
        self._thread.start()

    # ------------------------------------------------------------------ RDMA
    def put(self, key: ShardKey, payload: bytes, crc: Optional[int] = None,
            *, epoch: Optional[int] = None) -> Future:
        """Non-blocking RDMA-put analogue.  Returns a Future that resolves to
        a TransferRecord once the shard has landed in L1.

        ``epoch`` overrides the fence stamp (tests and the chaos stale-probe
        use it to impersonate a pre-recovery submitter); by default the op
        carries the epoch current *now*, and the dispatch loop refuses it if
        a recovery happens before it runs."""
        fut: Future = Future()
        self._inbox.put(_Op("put", key=key, payload=payload, crc=crc,
                            future=fut, trace=self._cur_trace(),
                            epoch=self._cur_epoch() if epoch is None
                            else epoch))
        return fut

    def get(self, key: ShardKey) -> bytes:
        """Read a shard back (restart / redistribution path)."""
        self._check_alive()
        payload = self.store.get(key)          # crc-verified
        self.nic.transfer(len(payload))
        return payload

    def has(self, key: ShardKey) -> bool:
        return self.store.has(key)

    # -------------------------------------------------------- redistribution
    def peer_read(self, key: ShardKey, codec: str, dtype: str,
                  vlo: int, vhi: int, requester_node: NodeId) -> bytes:
        """Serve a slice frame for another agent's transfer program.

        Like :meth:`get`, served off the caller's thread (concurrent across
        agent pairs).  Only the sliced bytes move: intra-node requests ride
        the node's memory bus, cross-node requests pay this node's NIC once
        — the client is never in the path.
        """
        self._check_alive()
        if self.fault.partitioned(self.node_id, requester_node):
            raise ConnectionError(
                f"partition between {self.node_id} and {requester_node}")
        if codec == "zstd":
            with self._lock:
                raw = self._decoded_memo.get(key)
            if raw is None:
                raw = decode_payload(self.store.get(key, promote=False),
                                     codec, dtype)
                with self._lock:
                    self._decoded_memo[key] = raw
            blob = slice_payload(raw, "none", dtype, vlo, vhi)
        else:
            payload = self.store.get(key, promote=False)
            blob = slice_payload(payload, codec, dtype, vlo, vhi)
        if requester_node == self.node_id and self.membus is not None:
            self.membus.transfer(len(blob))
        else:
            self.nic.transfer(len(blob))
        self._check_alive()                  # may have died mid-transfer
        with self._lock:
            self.peer_reads += 1
            self.peer_bytes_out += len(blob)
        return blob

    def peer_read_raw(self, key: ShardKey, requester_node: NodeId) -> bytes:
        """Serve one stored blob whole (erasure-fragment rebuild path).

        The framed-fragment twin of :meth:`peer_read`: no codec slicing —
        fragments are opaque stripe rows — but the same fabric accounting
        (MemBus intra-node, NIC cross-node) and the same mid-transfer death
        semantics."""
        self._check_alive()
        if self.fault.partitioned(self.node_id, requester_node):
            raise ConnectionError(
                f"partition between {self.node_id} and {requester_node}")
        blob = self.store.get(key, promote=False)
        if requester_node == self.node_id and self.membus is not None:
            self.membus.transfer(len(blob))
        else:
            self.nic.transfer(len(blob))
        self._check_alive()                  # may have died mid-transfer
        with self._lock:
            self.peer_reads += 1
            self.peer_bytes_out += len(blob)
        return blob

    def clear_peer_cache(self) -> None:
        """Release the decoded-payload memo (end of an adapt window) — the
        decoded shards must not outlive the redistribution that needed
        them."""
        with self._lock:
            self._decoded_memo.clear()

    def assemble(self, spec: AssembleSpec) -> Future:
        """Build one destination part from peer slice reads (asynchronous;
        the assembled payload lands in this agent's L1 under
        ``spec.out_key``).  Resolves to ``{nbytes, reads}`` accounting."""
        fut: Future = Future()
        self._inbox.put(_Op("assemble", payload=spec, future=fut,
                            trace=self._cur_trace(),
                            epoch=self._cur_epoch()))
        return fut

    def replay(self, spec: ReplaySpec) -> Future:
        """Catch an assembled part up with the tail delta frames committed
        during an overlap window (asynchronous; requires the original
        assemble to have run with ``keep_state=True``).  Resolves to
        ``{nbytes, reads, patches}`` where ``patches`` lists the
        ``(dst_offset_vals, value_bytes)`` spans that changed — what the
        client splices into parts it already prefetched."""
        fut: Future = Future()
        self._inbox.put(_Op("replay", payload=spec, future=fut,
                            trace=self._cur_trace(),
                            epoch=self._cur_epoch()))
        return fut

    def drop_assembly_state(self, key: ShardKey) -> None:
        with self._lock:
            self._assembly_state.pop(key, None)

    def rebuild(self, spec: RebuildSpec) -> Future:
        """Regenerate lost erasure fragments onto this agent (asynchronous).
        Resolves to ``{restored, nbytes, reads, source, degraded}``
        accounting; raises ``RestoreError`` when neither k peer fragments
        nor a fallback tier can produce the payload."""
        fut: Future = Future()
        self._inbox.put(_Op("rebuild", payload=spec, future=fut,
                            trace=self._cur_trace(),
                            epoch=self._cur_epoch()))
        return fut

    # ------------------------------------------------------------------ L2
    def drain(self, keys: List[ShardKey], pfs: PFSTier,
              on_done: Optional[Callable] = None) -> Future:
        """Write the given L1 shards to the PFS (asynchronously)."""
        fut: Future = Future()
        self._inbox.put(_Op("drain", key=keys, pfs=pfs, future=fut,
                            on_done=on_done, trace=self._cur_trace(),
                            epoch=self._cur_epoch()))
        return fut

    # ------------------------------------------------------------------ admin
    def alive(self) -> bool:
        return (not self._stop.is_set()
                and not self.fault.agent_dead(self.agent_id)
                and not self.fault.node_dead(self.node_id))

    def stop(self) -> None:
        self._stop.set()
        self._inbox.put(_Op("stop"))
        self._thread.join(timeout=5)

    def observed_rate(self) -> float:
        """Predicted ingest rate (bytes / simulated second)."""
        r = self.rate_ewma.predict()
        return r if r > 0 else self.nic.bandwidth

    def stats(self) -> dict:
        with self._lock:
            return {
                "agent_id": self.agent_id,
                "node_id": self.node_id,
                "bytes_in": self.bytes_in,
                "transfers": len(self.transfers),
                "rate_ewma": self.rate_ewma.predict(),
                "peer_reads": self.peer_reads,
                "peer_bytes_out": self.peer_bytes_out,
                # scratch retained for open adapt windows — both must be 0
                # once every window has closed (the chaos leak invariant)
                "assembly_states": len(self._assembly_state),
                "decoded_memo": len(self._decoded_memo),
            }

    # ------------------------------------------------------------------ guts
    def _check_alive(self) -> None:
        if not self.alive():
            raise AgentDead(f"agent {self.agent_id} on node {self.node_id} is dead")

    def _cur_trace(self):
        """The submitting thread's TraceContext, to ride the op across the
        inbox (None when tracing is off)."""
        return self.tracer.current() if self.tracer is not None else None

    def _cur_epoch(self) -> Optional[int]:
        """The controller epoch to stamp an op with at submit time."""
        return self.fence.current if self.fence is not None else None

    def _check_epoch(self, op: _Op) -> None:
        """Refuse ops stamped before a controller recovery (zombie fencing):
        the submitting controller — or work it queued — predates the
        recovered state and must not mutate it."""
        if self.fence is None or op.epoch is None:
            return
        if op.epoch != self.fence.current:
            if self.bus is not None:
                from . import events as E
                self.bus.publish(E.STALE_OP_REJECTED, kind=op.kind,
                                 agent=self.agent_id, epoch=op.epoch,
                                 current=self.fence.current)
            from .services.journal import StaleEpochError
            raise StaleEpochError(
                f"agent {self.agent_id} refused {op.kind}: stamped epoch "
                f"{op.epoch}, fence at {self.fence.current}")

    def _op_trace_id(self, op: _Op) -> Optional[str]:
        """Trace identity of one op: the carried context's, else derived
        from the shard key — a drain retry resubmitted without context
        still re-joins its checkpoint's tree by id."""
        if op.trace is not None:
            return op.trace.trace_id
        key = op.key
        if op.kind in ("assemble", "replay"):
            key = op.payload.out_key
        elif op.kind == "rebuild":
            key = op.payload.base_key
        elif isinstance(key, list):
            key = key[0] if key else None
        if key is None:
            return None
        return trace_id_for(key.app_id, key.ckpt_id)

    def _run(self) -> None:
        while not self._stop.is_set():
            op = self._inbox.get()
            if op.kind == "stop":
                break
            try:
                self._check_epoch(op)
                tracer = self.tracer
                if tracer is not None and tracer.enabled:
                    trace_id = self._op_trace_id(op)
                    with tracer.use(op.trace):
                        if trace_id is not None:
                            with tracer.span(f"agent_{op.kind}", trace_id,
                                             self.agent_id):
                                self._dispatch(op)
                        else:
                            self._dispatch(op)
                else:
                    self._dispatch(op)
            except BaseException as e:  # noqa: BLE001 - surface through future
                if op.future is not None and not op.future.done():
                    op.future.set_exception(e)

    def _dispatch(self, op: _Op) -> None:
        if op.kind == "put":
            rec = self._do_put(op)
            op.future.set_result(rec)
        elif op.kind == "drain":
            res = self._do_drain(op)
            op.future.set_result(res)
            if op.on_done:
                op.on_done(res)
        elif op.kind == "assemble":
            op.future.set_result(self._do_assemble(op.payload))
        elif op.kind == "replay":
            op.future.set_result(self._do_replay(op.payload))
        elif op.kind == "rebuild":
            op.future.set_result(self._do_rebuild(op.payload))

    def _do_put(self, op: _Op) -> TransferRecord:
        self._check_alive()
        payload = op.payload
        # straggler injection slows this agent's transfers only
        slow = self.fault.agent_slowdown(self.agent_id)
        sim = self.nic.transfer(len(payload))
        if slow > 1.0:
            extra = sim * (slow - 1.0)
            self.nic.clock.sleep(extra)
            sim += extra
        self._check_alive()  # may have died mid-transfer
        self.store.put(op.key, payload, crc=op.crc)
        rec = TransferRecord(key=op.key, nbytes=len(payload),
                             agent_id=self.agent_id, sim_seconds=sim)
        with self._lock:
            self.transfers.append(rec)
            self.bytes_in += len(payload)
            if sim > 0:
                self.rate_ewma.update(len(payload) / sim)
        return rec

    def _do_assemble(self, spec: AssembleSpec) -> dict:
        """Execute one destination part's transfer program.

        Runs on this agent's worker thread; peer reads are direct calls into
        the source agents (served off *this* thread), so assemblies on
        different destination agents proceed concurrently and no agent ever
        waits on another agent's worker loop (no deadlock by construction).
        """
        self._check_alive()
        buf = np.zeros(spec.nvals, dtype=np.dtype(spec.dtype))
        reads: List[dict] = []
        tier_cache: dict = {}       # one whole-object read per shard, not per op
        states: List[Optional[SliceState]] = []
        for f in spec.fetches:
            frames = self._gather_frames(f, reads, tier_cache)
            if spec.keep_state:
                vals, st = decode_slice_frames(frames, f.dtype, f.vlo, f.vhi,
                                               return_state=True)
                states.append(st)
            else:
                vals = decode_slice_frames(frames, f.dtype, f.vlo, f.vhi)
            buf[f.dst_lo:f.dst_lo + vals.size] = vals
        self._check_alive()
        payload = buf.tobytes()
        self.store.put(spec.out_key, payload)
        with self._lock:
            self.bytes_in += len(payload)
            if spec.keep_state:
                self._assembly_state[spec.out_key] = states
        return {"key": spec.out_key, "nbytes": len(payload), "reads": reads}

    def _gather_frames(self, f: SliceFetch, reads: List[dict],
                       tier_cache: dict) -> List[bytes]:
        """Pull one fetch's slice frames (chain order) from its sources:
        live peer agents over the fabric, else a shared tier."""
        frames = []
        for provider, key in f.sources:
            if isinstance(provider, Agent):
                blob = provider.peer_read(key, f.codec, f.dtype,
                                          f.vlo, f.vhi, self.node_id)
                reads.append({
                    "node": provider.node_id, "bytes": len(blob),
                    "kind": "intra" if provider.node_id == self.node_id
                    else "cross"})
            else:
                # shared-tier fallback (PFS/L3): whole-object read, then
                # slice locally — rare, but it keeps a partially-drained
                # source from wedging the adapt window.  The cache holds
                # the *decoded* bytes for zstd so k ops on one source
                # cost one read and one decompress, not k
                cached = tier_cache.get(key)
                if cached is None:
                    # a tier mid-outage recovers within sim-milliseconds;
                    # a short backoff keeps one blip from failing the fetch
                    payload = with_backoff(
                        lambda: provider.read_shard(key), 0.1,
                        clock=self.nic.clock, bus=self.bus,
                        what=f"peer_fallback_read:{key.base()}")
                    reads.append({"node": provider.name,
                                  "bytes": len(payload), "kind": "tier"})
                    if f.codec == "zstd":
                        payload = decode_payload(payload, f.codec,
                                                 f.dtype)
                    cached = tier_cache[key] = payload
                blob = slice_payload(
                    cached, "none" if f.codec == "zstd" else f.codec,
                    f.dtype, f.vlo, f.vhi)
            frames.append(blob)
        return frames

    def _do_replay(self, spec: ReplaySpec) -> dict:
        """Advance a retained assembly by its tail frames and patch the
        stored scratch payload in place (zero-stall cutover, phase 2)."""
        self._check_alive()
        with self._lock:
            states = self._assembly_state.get(spec.out_key)
        if states is None or len(states) != len(spec.fetches):
            raise ICheckError(
                f"no retained assembly state for {spec.out_key} "
                f"(assemble must run with keep_state=True)")
        payload = bytearray(self.store.get(spec.out_key, promote=False))
        buf = np.frombuffer(payload, dtype=np.dtype(spec.dtype))
        reads: List[dict] = []
        tier_cache: dict = {}
        patches: List[Tuple[int, bytes]] = []
        patch_bytes = 0
        for i, f in enumerate(spec.fetches):
            frames = self._gather_frames(f, reads, tier_cache)
            spans, states[i] = replay_slice_frames(states[i], frames,
                                                   f.dtype, f.vlo, f.vhi)
            for off, vals in spans:
                buf[f.dst_lo + off:f.dst_lo + off + vals.size] = vals
                patches.append((f.dst_lo + off, vals.tobytes()))
                patch_bytes += vals.nbytes
        self._check_alive()
        self.store.put(spec.out_key, bytes(payload))
        with self._lock:
            self.bytes_in += patch_bytes
            self._assembly_state[spec.out_key] = states
        return {"key": spec.out_key, "nbytes": patch_bytes, "reads": reads,
                "patches": patches}

    def _do_rebuild(self, spec: RebuildSpec) -> dict:
        """Regenerate lost erasure fragments from surviving peers (or a
        lower tier) and host them in this agent's L1.

        Runs on this agent's worker thread like :meth:`_do_assemble`; the
        peer reads are direct calls into the source agents, so a source
        dying mid-gather raises on *its* side and is skipped here — the
        rebuild keeps draining the remaining sources and only falls back to
        L2/L3 when fewer than k fragments survive."""
        self._check_alive()
        frags: Dict[int, bytes] = {}
        reads: List[dict] = []
        for provider, key in spec.sources:
            if len(frags) >= spec.k:
                break
            try:
                blob = provider.peer_read_raw(key, self.node_id)
                _, _, idx, _, _, _ = ec_parse_fragment(blob)
            except (ConnectionError, KeyError, IntegrityError, ICheckError):
                continue        # source died / dropped / corrupt: next one
            frags[idx] = blob
            reads.append({
                "node": provider.node_id, "bytes": len(blob),
                "kind": "intra" if provider.node_id == self.node_id
                else "cross"})
        source = "peer"
        payload = None
        if len(frags) >= spec.k:
            payload = ec_decode_shard(list(frags.values()))
        else:
            for provider, key in spec.fallback:
                try:
                    payload = provider.read_shard(key)
                except (KeyError, ConnectionError, OSError, ICheckError):
                    continue
                source = getattr(provider, "name", "tier")
                reads.append({"node": source, "bytes": len(payload),
                              "kind": "tier"})
                break
        if payload is None:
            raise RestoreError(
                f"stripe {spec.base_key} unrecoverable: {len(frags)} of "
                f"{spec.k} fragments survive and no lower tier has it")
        # degraded = the decode had to do field math (a data row was among
        # the casualties), as opposed to gather-k-and-concat
        degraded = (source != "peer"
                    or sorted(frags)[:spec.k] != list(range(spec.k)))
        stripe = dict(ec_encode_shard(payload, spec.k, spec.m))
        self._check_alive()
        stored = 0
        restored = []
        for rep in spec.want:
            blob = stripe[rep]
            self.store.put(dataclasses.replace(spec.base_key, replica=rep),
                           blob)
            stored += len(blob)
            restored.append(rep)
        with self._lock:
            self.bytes_in += stored
        return {"key": spec.base_key, "restored": restored, "nbytes": stored,
                "reads": reads, "source": source, "degraded": degraded}

    def _do_drain(self, op: _Op) -> dict:
        self._check_alive()
        written = 0
        sim_total = 0.0
        for key in op.key:
            # read in place: draining a demoted/spilled shard must not pull
            # it back into RAM (that would undo the watermark policy)
            payload = self.store.get(key, promote=False)
            sim_total += op.pfs.write_shard(key, payload)
            written += len(payload)
        return {"bytes": written, "sim_seconds": sim_total, "keys": list(op.key)}
