"""Malleable resource manager (the paper's extended-Slurm analogue, §III-A).

Supports the four interactions of the iCheck-aware scheduling plugin:
  1. RM can *give* nodes to iCheck on request ("when iCheck runs out of
     memory in a node, the controller can request more memory and get
     additional nodes").
  2. RM can *retake* nodes from iCheck (priority jobs / power corridors).
  3. RM can ask the controller to *migrate* resources to another iCheck node.
  4. RM can pass *application-specific information* to the controller —
     forewarning of an impending resource change so agents can pre-stage
     data redistribution.

It also drives application malleability itself: ``schedule_resize`` queues a
rank-count change that the application observes via ``probe_adapt`` (the
``MPI_Probe_adapt`` analogue in core/malleable.py).
"""
from __future__ import annotations

import itertools
import threading
from typing import Callable, Dict, List, Optional

from .types import AppId, NodeId, NodeSpec


class ResizeEvent:
    def __init__(self, app_id: AppId, new_ranks: int, reason: str = "rm"):
        self.app_id = app_id
        self.new_ranks = new_ranks
        self.reason = reason

    def __repr__(self) -> str:
        return f"ResizeEvent({self.app_id} -> {self.new_ranks} ranks, {self.reason})"


class ResourceManager:
    def __init__(self, free_nodes: Optional[List[NodeSpec]] = None):
        self._lock = threading.Lock()
        self._free: List[NodeSpec] = list(free_nodes or [])
        self._icheck_nodes: Dict[NodeId, NodeSpec] = {}
        self._app_ranks: Dict[AppId, int] = {}
        self._pending_resize: Dict[AppId, ResizeEvent] = {}
        self._seq = itertools.count()
        # callbacks into the iCheck controller (the "plugin" interface)
        self.on_retake: Optional[Callable[[NodeId], None]] = None
        self.on_migrate: Optional[Callable[[NodeId, NodeId], None]] = None
        self.on_app_info: Optional[Callable[[AppId, dict], None]] = None
        # controller epoch fence (set by the controller).  Mutating calls
        # carry the caller's epoch; a zombie controller from before a
        # recovery must not move nodes or queue resizes.
        self.fence = None

    def _check_epoch(self, epoch, what: str) -> None:
        if self.fence is not None and epoch is not None:
            self.fence.check(epoch, what)

    # ------------------------------------------------------------- node pool
    def add_free_node(self, spec: NodeSpec) -> None:
        with self._lock:
            self._free.append(spec)

    def make_node(self, memory_bytes: int = 64 << 30, nic_bandwidth: float = 25e9) -> NodeSpec:
        with self._lock:
            spec = NodeSpec(node_id=f"icn{next(self._seq)}",
                            memory_bytes=memory_bytes, nic_bandwidth=nic_bandwidth)
            self._free.append(spec)
            return spec

    def free_node_count(self) -> int:
        with self._lock:
            return len(self._free)

    # ---------------------------------------------------- interaction 1: give
    def request_icheck_node(self, *, epoch=None) -> Optional[NodeSpec]:
        """Controller asks for one more iCheck node; None if unavailable."""
        self._check_epoch(epoch, "request_icheck_node")
        with self._lock:
            if not self._free:
                return None
            spec = self._free.pop(0)
            self._icheck_nodes[spec.node_id] = spec
            return spec

    # -------------------------------------------------- interaction 2: retake
    def retake_icheck_node(self, node_id: NodeId, *, epoch=None) -> bool:
        """RM pulls a node back (e.g. priority job).  The controller is told
        first so it can migrate shards off the node."""
        self._check_epoch(epoch, "retake_icheck_node")
        with self._lock:
            spec = self._icheck_nodes.get(node_id)
        if spec is None:
            return False
        if self.on_retake is not None:
            self.on_retake(node_id)           # controller migrates + releases
        with self._lock:
            self._icheck_nodes.pop(node_id, None)
            self._free.append(spec)
        return True

    def release_icheck_node(self, node_id: NodeId, *, epoch=None) -> None:
        """Controller voluntarily returns a node."""
        self._check_epoch(epoch, "release_icheck_node")
        with self._lock:
            spec = self._icheck_nodes.pop(node_id, None)
            if spec is not None:
                self._free.append(spec)

    # ------------------------------------------------- interaction 3: migrate
    def request_migration(self, src: NodeId, dst: NodeId) -> None:
        if self.on_migrate is not None:
            self.on_migrate(src, dst)

    # ------------------------------------------------ interaction 4: app info
    def register_app(self, app_id: AppId, ranks: int, *, epoch=None) -> None:
        self._check_epoch(epoch, "register_app")
        with self._lock:
            self._app_ranks[app_id] = ranks

    def schedule_resize(self, app_id: AppId, new_ranks: int,
                        reason: str = "rm", *, epoch=None) -> None:
        """Queue a malleability event for the app AND forewarn iCheck
        (paper: "inform the controller about an impending resource change of
        an application so that agents can prepare ... ahead of time")."""
        self._check_epoch(epoch, "schedule_resize")
        ev = ResizeEvent(app_id, new_ranks, reason)
        with self._lock:
            self._pending_resize[app_id] = ev
        if self.on_app_info is not None:
            self.on_app_info(app_id, {"event": "impending_resize",
                                      "new_ranks": new_ranks, "reason": reason})

    def probe_resize(self, app_id: AppId) -> Optional[ResizeEvent]:
        """MPI_Probe_adapt analogue: application polls for a resource change."""
        with self._lock:
            return self._pending_resize.get(app_id)

    def complete_resize(self, app_id: AppId, *, epoch=None) -> None:
        """MPI_Comm_adapt_commit analogue: resize finished."""
        self._check_epoch(epoch, "complete_resize")
        with self._lock:
            ev = self._pending_resize.pop(app_id, None)
            if ev is not None:
                self._app_ranks[app_id] = ev.new_ranks

    def app_ranks(self, app_id: AppId) -> int:
        with self._lock:
            return self._app_ranks.get(app_id, 0)
