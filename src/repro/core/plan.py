"""Redistribution planning — the data-management half of iCheck.

The paper (§III-B) supports BLOCK and CYCLIC re-partitioning of registered
arrays when the application's process count changes.  This module computes
*plans*: exact (src_part, src_range) → (dst_part, dst_range) move lists that
agents execute without ever materialising the global array.

Beyond the paper, ``mesh_moves`` generalises the same machinery to N-d
partitions of JAX arrays sharded over a (pod, data, model) device mesh, which
is what elastic mesh changes (grow/shrink) need.

Everything here is pure and deterministic → hypothesis property tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import PartitionDesc, PartitionScheme

Interval = Tuple[int, int]           # [lo, hi)


# --------------------------------------------------------------------------
# 1-d ownership maps (paper-faithful)
# --------------------------------------------------------------------------
def partition_intervals(n: int, desc: PartitionDesc) -> List[List[Interval]]:
    """Global index intervals owned by each part, in local-storage order."""
    p = desc.num_parts
    if p <= 0:
        raise ValueError("num_parts must be positive")
    if desc.scheme == PartitionScheme.REPLICATED:
        return [[(0, n)] for _ in range(p)]
    if desc.scheme == PartitionScheme.BLOCK:
        base, rem = divmod(n, p)
        out, lo = [], 0
        for i in range(p):
            size = base + (1 if i < rem else 0)
            out.append([(lo, lo + size)] if size else [])
            lo += size
        return out
    if desc.scheme == PartitionScheme.CYCLIC:
        b = max(1, desc.block)
        out: List[List[Interval]] = [[] for _ in range(p)]
        nblocks = -(-n // b)
        for j in range(nblocks):
            lo, hi = j * b, min((j + 1) * b, n)
            out[j % p].append((lo, hi))
        return out
    if desc.scheme == PartitionScheme.MESH:
        raise ValueError("MESH partitions use mesh_moves(), not 1-d intervals")
    raise ValueError(f"unknown scheme {desc.scheme}")


def local_size(n: int, desc: PartitionDesc, part: int) -> int:
    return sum(hi - lo for lo, hi in partition_intervals(n, desc)[part])


def local_shape(shape: Sequence[int], desc: PartitionDesc, part: int) -> Tuple[int, ...]:
    shape = tuple(shape)
    if desc.scheme == PartitionScheme.REPLICATED:
        return shape
    ax = desc.axis
    return shape[:ax] + (local_size(shape[ax], desc, part),) + shape[ax + 1:]


def _local_offsets(intervals: List[Interval]) -> List[int]:
    """Local start offset of each owned interval (prefix sums)."""
    offs, acc = [], 0
    for lo, hi in intervals:
        offs.append(acc)
        acc += hi - lo
    return offs


@dataclasses.dataclass(frozen=True)
class Move:
    """Copy global rows [glo, ghi) of the distributed axis:
    src part ``src``, local rows [src_lo, src_lo+len) →
    dst part ``dst``, local rows [dst_lo, dst_lo+len)."""

    src: int
    dst: int
    glo: int
    ghi: int
    src_lo: int
    dst_lo: int

    @property
    def length(self) -> int:
        return self.ghi - self.glo


def redistribution_moves(n: int, old: PartitionDesc, new: PartitionDesc) -> List[Move]:
    """All moves needed to go from distribution ``old`` to ``new``.

    Replicated sources are collapsed to part 0 (any replica is valid).
    """
    old_iv = partition_intervals(n, old)
    new_iv = partition_intervals(n, new)
    if old.scheme == PartitionScheme.REPLICATED:
        old_iv = [old_iv[0]]  # read from a single canonical replica

    # sweep: index every source interval by global range
    src_index = []          # (lo, hi, src_part, src_local_offset)
    for sp, ivs in enumerate(old_iv):
        offs = _local_offsets(ivs)
        for (lo, hi), off in zip(ivs, offs):
            src_index.append((lo, hi, sp, off))
    src_index.sort()

    moves: List[Move] = []
    for dp, ivs in enumerate(new_iv):
        offs = _local_offsets(ivs)
        for (dlo, dhi), doff in zip(ivs, offs):
            # binary search could apply; linear scan is fine at control-plane scale
            for slo, shi, sp, soff in src_index:
                if shi <= dlo:
                    continue
                if slo >= dhi:
                    break
                lo, hi = max(slo, dlo), min(shi, dhi)
                if lo < hi:
                    moves.append(Move(
                        src=sp, dst=dp, glo=lo, ghi=hi,
                        src_lo=soff + (lo - slo),
                        dst_lo=doff + (lo - dlo)))
    return moves


# --------------------------------------------------------------------------
# numpy executors (used by agents and by tests as the oracle)
# --------------------------------------------------------------------------
def split_array(arr: np.ndarray, desc: PartitionDesc) -> List[np.ndarray]:
    """Global array → per-part local arrays (local-storage order)."""
    if desc.scheme == PartitionScheme.REPLICATED:
        return [arr.copy() for _ in range(desc.num_parts)]
    ivs = partition_intervals(arr.shape[desc.axis], desc)
    out = []
    for part_ivs in ivs:
        chunks = [np.take(arr, np.arange(lo, hi), axis=desc.axis) for lo, hi in part_ivs]
        if chunks:
            out.append(np.concatenate(chunks, axis=desc.axis))
        else:
            shp = list(arr.shape)
            shp[desc.axis] = 0
            out.append(np.empty(shp, dtype=arr.dtype))
    return out

def assemble_array(parts: Sequence[np.ndarray], desc: PartitionDesc,
                   shape: Sequence[int]) -> np.ndarray:
    """Per-part local arrays → global array."""
    shape = tuple(shape)
    if desc.scheme == PartitionScheme.REPLICATED:
        return np.asarray(parts[0]).reshape(shape)
    out = np.empty(shape, dtype=np.asarray(parts[0]).dtype)
    ivs = partition_intervals(shape[desc.axis], desc)
    for part, part_ivs in enumerate(ivs):
        offs = _local_offsets(part_ivs)
        for (lo, hi), off in zip(part_ivs, offs):
            sl_g = [slice(None)] * len(shape)
            sl_g[desc.axis] = slice(lo, hi)
            sl_l = [slice(None)] * len(shape)
            sl_l[desc.axis] = slice(off, off + (hi - lo))
            out[tuple(sl_g)] = np.asarray(parts[part])[tuple(sl_l)]
    return out


def apply_moves(src_parts: Dict[int, np.ndarray], moves: Sequence[Move],
                old: PartitionDesc, new: PartitionDesc,
                shape: Sequence[int]) -> Dict[int, np.ndarray]:
    """Execute a move list: build every destination part from source parts.

    This is what agents do during ``icheck_redistribute`` — no global
    materialisation, only slice copies.
    """
    shape = tuple(shape)
    ax = new.axis if new.scheme != PartitionScheme.REPLICATED else old.axis
    dtype = next(iter(src_parts.values())).dtype
    dst_parts: Dict[int, np.ndarray] = {}
    for dp in range(new.num_parts):
        dst_parts[dp] = np.empty(local_shape(shape, new, dp), dtype=dtype)
    for mv in moves:
        src = src_parts[mv.src]
        sl_s = [slice(None)] * len(shape)
        sl_s[ax] = slice(mv.src_lo, mv.src_lo + mv.length)
        sl_d = [slice(None)] * len(shape)
        sl_d[ax] = slice(mv.dst_lo, mv.dst_lo + mv.length)
        dst_parts[mv.dst][tuple(sl_d)] = src[tuple(sl_s)]
    return dst_parts


# --------------------------------------------------------------------------
# N-d mesh partitions (beyond-paper: JAX sharded arrays)
# --------------------------------------------------------------------------
Box = Tuple[Interval, ...]            # one (lo, hi) per dim


@dataclasses.dataclass(frozen=True)
class MeshMove:
    src: int                          # source part index
    dst: int                          # destination part index
    src_box: Box                      # in src-local coordinates
    dst_box: Box                      # in dst-local coordinates

    @property
    def nelems(self) -> int:
        n = 1
        for lo, hi in self.src_box:
            n *= hi - lo
        return n


def mesh_part_bounds(shape: Sequence[int], sharding) -> Tuple[Box, ...]:
    """Distinct shard boxes of a jax NamedSharding, deduplicated across
    replicas, in a canonical (sorted) order.  Pure host math."""
    shape = tuple(shape)
    idx_map = sharding.devices_indices_map(shape)
    boxes = set()
    for idx in idx_map.values():
        box = []
        for d, sl in enumerate(idx):
            lo = 0 if sl.start is None else int(sl.start)
            hi = shape[d] if sl.stop is None else int(sl.stop)
            box.append((lo, hi))
        boxes.add(tuple(box))
    return tuple(sorted(boxes))


def boxes_to_desc(shape: Sequence[int], boxes: Tuple[Box, ...]) -> PartitionDesc:
    return PartitionDesc(scheme=PartitionScheme.MESH, num_parts=len(boxes),
                         bounds=tuple(boxes))


def mesh_moves(old_boxes: Sequence[Box], new_boxes: Sequence[Box]) -> List[MeshMove]:
    """Box-intersection plan between two N-d partitions of the same array."""
    moves: List[MeshMove] = []
    for dp, dbox in enumerate(new_boxes):
        for sp, sbox in enumerate(old_boxes):
            inter = []
            ok = True
            for (slo, shi), (dlo, dhi) in zip(sbox, dbox):
                lo, hi = max(slo, dlo), min(shi, dhi)
                if lo >= hi:
                    ok = False
                    break
                inter.append((lo, hi))
            if not ok:
                continue
            src_box = tuple((lo - sbox[d][0], hi - sbox[d][0])
                            for d, (lo, hi) in enumerate(inter))
            dst_box = tuple((lo - dbox[d][0], hi - dbox[d][0])
                            for d, (lo, hi) in enumerate(inter))
            moves.append(MeshMove(src=sp, dst=dp, src_box=src_box, dst_box=dst_box))
            # first covering source wins for the overlapping cells; later
            # sources would write identical data (replicas), skip them
    return _dedup_mesh_moves(moves)


def _dedup_mesh_moves(moves: List[MeshMove]) -> List[MeshMove]:
    """Drop moves that write a dst cell already fully written by an earlier
    move (replicated sources produce duplicates).  Exact-duplicate boxes only:
    partial overlaps between distinct sources cannot happen for GSPMD
    shardings (shards tile the array)."""
    seen = set()
    out = []
    for mv in moves:
        key = (mv.dst, mv.dst_box)
        if key in seen:
            continue
        seen.add(key)
        out.append(mv)
    return out


def apply_mesh_moves(src_parts: Dict[int, np.ndarray], moves: Sequence[MeshMove],
                     new_boxes: Sequence[Box], dtype) -> Dict[int, np.ndarray]:
    out: Dict[int, np.ndarray] = {}
    for dp, dbox in enumerate(new_boxes):
        shp = tuple(hi - lo for lo, hi in dbox)
        out[dp] = np.empty(shp, dtype=dtype)
    for mv in moves:
        src = src_parts[mv.src]
        ssl = tuple(slice(lo, hi) for lo, hi in mv.src_box)
        dsl = tuple(slice(lo, hi) for lo, hi in mv.dst_box)
        out[mv.dst][dsl] = src[ssl]
    return out


def moves_bytes(moves: Sequence[Move], row_bytes: int) -> int:
    """Total bytes a 1-d plan transfers (for scheduling/benchmarks)."""
    return sum(mv.length for mv in moves) * row_bytes


# --------------------------------------------------------------------------
# transfer programs (peer-to-peer redistribution)
# --------------------------------------------------------------------------
# A *move list* says which global slices change owner; a *transfer program*
# says, per destination part, exactly which flattened element ranges of which
# source shards an agent must pull and where they land in the assembled
# destination buffer.  Programs are what the resize forewarning pre-stages so
# the adapt window only executes: agents serve the ranges straight off their
# stored payloads (codec-aware slicing lives in ``core/tiers.py``) and ship
# only needed bytes, never whole shards.
@dataclasses.dataclass(frozen=True)
class TransferOp:
    """One slice read: flattened elements [src_lo, src_hi) of source part
    ``src`` land at flattened offset ``dst_lo`` of the destination part."""

    src: int
    src_lo: int
    src_hi: int
    dst_lo: int

    @property
    def nvals(self) -> int:
        return self.src_hi - self.src_lo


@dataclasses.dataclass(frozen=True)
class TransferProgram:
    """Everything one destination part needs: its flattened size and the
    ordered slice reads that assemble it."""

    dst: int
    nvals: int
    ops: Tuple[TransferOp, ...]

    @property
    def moved_vals(self) -> int:
        return sum(op.nvals for op in self.ops)


def compile_transfer_programs(n: int, old: PartitionDesc, new: PartitionDesc,
                              shape: Sequence[int]
                              ) -> "Optional[Dict[int, TransferProgram]]":
    """Compile a 1-d re-partitioning into per-destination transfer programs.

    Returns None when the layout cannot be expressed as contiguous flattened
    element ranges (non-leading distributed axis, replicated schemes) — the
    caller must fall back to the client-funnel path.
    """
    if PartitionScheme.REPLICATED in (old.scheme, new.scheme):
        return None
    if old.axis != 0 or new.axis != 0:
        return None
    shape = tuple(shape)
    rowvals = 1
    for s in shape[1:]:
        rowvals *= int(s)
    moves = redistribution_moves(n, old, new)
    ops_by_dst: Dict[int, List[TransferOp]] = {d: [] for d in range(new.num_parts)}
    for mv in moves:
        ops_by_dst[mv.dst].append(TransferOp(
            src=mv.src, src_lo=mv.src_lo * rowvals,
            src_hi=(mv.src_lo + mv.length) * rowvals,
            dst_lo=mv.dst_lo * rowvals))
    return {
        dp: TransferProgram(
            dst=dp, nvals=local_size(n, new, dp) * rowvals,
            ops=tuple(sorted(ops_by_dst[dp], key=lambda o: o.dst_lo)))
        for dp in range(new.num_parts)
    }


def _row_major_strides(shape: Sequence[int]) -> List[int]:
    st = [1] * len(shape)
    for d in range(len(shape) - 2, -1, -1):
        st[d] = st[d + 1] * shape[d + 1]
    return st


def _box_runs(src_shape: Sequence[int], src_box: Box,
              dst_shape: Sequence[int], dst_box: Box
              ) -> List[Tuple[int, int, int]]:
    """Contiguous flattened runs of one mesh move (src part → dst part).

    Rows along the innermost dimension are contiguous in both local layouts;
    adjacent runs that stay contiguous in *both* buffers are merged.
    """
    import itertools as _it

    if not src_box:                       # scalar region
        return [(0, 1, 0)]
    sst = _row_major_strides(src_shape)
    dstst = _row_major_strides(dst_shape)
    extents = [hi - lo for lo, hi in src_box]
    run = extents[-1]
    runs: List[Tuple[int, int, int]] = []
    for idx in _it.product(*(range(e) for e in extents[:-1])):
        soff = src_box[-1][0] + sum(
            (src_box[d][0] + idx[d]) * sst[d] for d in range(len(idx)))
        doff = dst_box[-1][0] + sum(
            (dst_box[d][0] + idx[d]) * dstst[d] for d in range(len(idx)))
        if runs and runs[-1][1] == soff \
                and runs[-1][2] + (runs[-1][1] - runs[-1][0]) == doff:
            runs[-1] = (runs[-1][0], soff + run, runs[-1][2])
        else:
            runs.append((soff, soff + run, doff))
    return runs


def compile_mesh_transfer_programs(old_boxes: Sequence[Box],
                                   new_boxes: Sequence[Box]
                                   ) -> Dict[int, TransferProgram]:
    """N-d mesh variant: box-intersection moves → per-destination programs
    of contiguous flattened runs (src-local → dst-local coordinates)."""
    moves = mesh_moves(tuple(old_boxes), tuple(new_boxes))
    src_shapes = [tuple(hi - lo for lo, hi in b) for b in old_boxes]
    dst_shapes = [tuple(hi - lo for lo, hi in b) for b in new_boxes]
    ops_by_dst: Dict[int, List[TransferOp]] = {d: [] for d in range(len(new_boxes))}
    for mv in moves:
        for slo, shi, dlo in _box_runs(src_shapes[mv.src], mv.src_box,
                                       dst_shapes[mv.dst], mv.dst_box):
            ops_by_dst[mv.dst].append(TransferOp(src=mv.src, src_lo=slo,
                                                 src_hi=shi, dst_lo=dlo))
    out = {}
    for dp, shp in enumerate(dst_shapes):
        nvals = 1
        for s in shp:
            nvals *= s
        out[dp] = TransferProgram(
            dst=dp, nvals=nvals,
            ops=tuple(sorted(ops_by_dst[dp], key=lambda o: o.dst_lo)))
    return out


def apply_transfer_programs(src_flat: Dict[int, np.ndarray],
                            programs: Dict[int, TransferProgram],
                            dtype) -> Dict[int, np.ndarray]:
    """Numpy oracle for program execution: flattened source parts →
    flattened destination parts (tests compare this against both
    ``apply_moves`` and the agents' peer-assembled shards)."""
    out: Dict[int, np.ndarray] = {}
    for dp, prog in programs.items():
        buf = np.zeros(prog.nvals, dtype=np.dtype(dtype))
        for op in prog.ops:
            buf[op.dst_lo:op.dst_lo + op.nvals] = \
                src_flat[op.src][op.src_lo:op.src_hi]
        out[dp] = buf
    return out
