"""Convenience assembly of a full iCheck deployment for tests / examples /
benchmarks: RM + controller + N iCheck nodes + PFS, all on one simulated
fabric clock."""
from __future__ import annotations

import tempfile
from typing import Optional

from .controller import Controller
from .rm import ResourceManager
from .simnet import FaultInjector, SimClock
from .store import PFSStore


class ICheckCluster:
    def __init__(self, n_icheck_nodes: int = 2, n_spare_nodes: int = 2,
                 node_memory: int = 8 << 30, nic_bandwidth: float = 25e9,
                 pfs_bandwidth: float = 40e9, pfs_root: Optional[str] = None,
                 policy: str = "adaptive", time_scale: float = 0.0,
                 keep_l1: int = 2, max_concurrent_drains: int = 2):
        self.clock = SimClock(time_scale)
        self.fault = FaultInjector()
        self.rm = ResourceManager()
        for _ in range(n_icheck_nodes + n_spare_nodes):
            self.rm.make_node(memory_bytes=node_memory,
                              nic_bandwidth=nic_bandwidth)
        self._tmp = None
        if pfs_root is None:
            self._tmp = tempfile.TemporaryDirectory(prefix="icheck-pfs-")
            pfs_root = self._tmp.name
        self.pfs = PFSStore(pfs_root, bandwidth=pfs_bandwidth, clock=self.clock)
        self.controller = Controller(
            self.rm, self.pfs, policy=policy, initial_nodes=n_icheck_nodes,
            clock=self.clock, fault=self.fault, keep_l1=keep_l1,
            max_concurrent_drains=max_concurrent_drains)

    def close(self) -> None:
        self.controller.close()
        if self._tmp is not None:
            self._tmp.cleanup()

    def __enter__(self) -> "ICheckCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
