"""Convenience assembly of a full iCheck deployment for tests / examples /
benchmarks: RM + controller (service core) + N iCheck nodes + PFS, all on
one simulated fabric clock.

``adaptive_interval`` (default True — adaptivity is the system's headline
behavior): the IntervalController treats each app's registered
``ckpt_interval_s`` as a starting hint and re-solves it (Young/Daly) from
observed commit cost and failure rate, announcing changes as
``interval_changed`` events.  Pass ``adaptive_interval=False`` for
experiments that need the registered interval to stay fixed.

``l3=True`` attaches a :class:`~repro.core.tiers.RemoteObjectTier` (S3/GCS
analogue) behind the PFS: sealed checkpoints trickle L2→L3 in the
background, retention trims the faster tiers (``keep_l2``/``keep_l3``), and
cold restarts fall back to the object store when L1 and L2 are gone.
``watermark_high``/``watermark_low`` drive the proactive L1 demotion policy
on nodes that have a spill tier (``spill_bytes > 0``).

``trace=True`` (or any ``trace_path=``) turns on sim-time checkpoint
tracing: one causal span tree per checkpoint, exported as Chrome/Perfetto
``trace_event`` JSON to ``trace_path`` when the cluster closes.  ``obs_dir``
overrides where flight-recorder crash dumps land (default
``artifacts/obs/``)."""
from __future__ import annotations

import tempfile
from typing import Optional

from .controller import Controller
from .rm import ResourceManager
from .simnet import FaultInjector, SimClock
from .tiers import PFSTier, RemoteObjectTier


class ICheckCluster:
    def __init__(self, n_icheck_nodes: int = 2, n_spare_nodes: int = 2,
                 node_memory: int = 8 << 30, nic_bandwidth: float = 25e9,
                 pfs_bandwidth: float = 40e9, pfs_root: Optional[str] = None,
                 policy: str = "adaptive", time_scale: float = 0.0,
                 keep_l1: int = 2, max_concurrent_drains: int = 2,
                 spill_bytes: int = 0, adaptive_interval: bool = True,
                 default_mtbf_s: float = 3600.0,
                 l3: bool = False, l3_root: Optional[str] = None,
                 l3_bandwidth: float = 5e9, l3_request_latency: float = 0.03,
                 watermark_high: float = 0.85, watermark_low: float = 0.60,
                 keep_l2: int = 0, keep_l3: int = 0,
                 delta_keyframe_every: int = 8,
                 trace: bool = False, trace_path: Optional[str] = None,
                 obs_dir: Optional[str] = None, journal: bool = True):
        self.clock = SimClock(time_scale)
        self.fault = FaultInjector()
        self.rm = ResourceManager()
        for _ in range(n_icheck_nodes + n_spare_nodes):
            self.rm.make_node(memory_bytes=node_memory,
                              nic_bandwidth=nic_bandwidth)
        self._tmp = None
        self._tmp_l3 = None
        if pfs_root is None:
            # ignore_cleanup_errors: a drain/agent thread that outlives its
            # join timeout must not turn teardown into an OSError
            self._tmp = tempfile.TemporaryDirectory(
                prefix="icheck-pfs-", ignore_cleanup_errors=True)
            pfs_root = self._tmp.name
        self.pfs = PFSTier(pfs_root, bandwidth=pfs_bandwidth, clock=self.clock)
        self.l3 = None
        if l3 or l3_root is not None:
            if l3_root is None:
                self._tmp_l3 = tempfile.TemporaryDirectory(
                    prefix="icheck-l3-", ignore_cleanup_errors=True)
                l3_root = self._tmp_l3.name
            self.l3 = RemoteObjectTier(l3_root, bandwidth=l3_bandwidth,
                                       request_latency=l3_request_latency,
                                       clock=self.clock)
        self.controller = Controller(
            self.rm, self.pfs, policy=policy, initial_nodes=n_icheck_nodes,
            clock=self.clock, fault=self.fault, keep_l1=keep_l1,
            max_concurrent_drains=max_concurrent_drains,
            spill_bytes=spill_bytes, adaptive_interval=adaptive_interval,
            default_mtbf_s=default_mtbf_s, l3=self.l3,
            watermark_high=watermark_high, watermark_low=watermark_low,
            keep_l2=keep_l2, keep_l3=keep_l3,
            delta_keyframe_every=delta_keyframe_every,
            trace=trace, trace_path=trace_path, obs_dir=obs_dir,
            journal=journal)

    @property
    def telemetry(self):
        """The controller's TelemetryService (structured + Prometheus)."""
        return self.controller.telemetry

    @property
    def bus(self):
        """The controller's event bus (subscribe for telemetry)."""
        return self.controller.bus

    @property
    def lifecycle(self):
        """The controller's StorageLifecycleService (watermarks/trickle/GC)."""
        return self.controller.lifecycle

    @property
    def tracer(self):
        """The controller's TraceCollector (sim-time checkpoint tracing)."""
        return self.controller.tracer

    @property
    def flight(self):
        """The controller's FlightRecorder (crash-dump ring buffer)."""
        return self.controller.flight

    def close(self) -> None:
        self.controller.close()
        if self._tmp is not None:
            self._tmp.cleanup()
        if self._tmp_l3 is not None:
            self._tmp_l3.cleanup()

    def __enter__(self) -> "ICheckCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
