"""Convenience assembly of a full iCheck deployment for tests / examples /
benchmarks: RM + controller (service core) + N iCheck nodes + PFS, all on
one simulated fabric clock.

``adaptive_interval`` (default True — adaptivity is the system's headline
behavior): the IntervalController treats each app's registered
``ckpt_interval_s`` as a starting hint and re-solves it (Young/Daly) from
observed commit cost and failure rate, announcing changes as
``interval_changed`` events.  Pass ``adaptive_interval=False`` for
experiments that need the registered interval to stay fixed."""
from __future__ import annotations

import tempfile
from typing import Optional

from .controller import Controller
from .rm import ResourceManager
from .simnet import FaultInjector, SimClock
from .tiers import PFSTier


class ICheckCluster:
    def __init__(self, n_icheck_nodes: int = 2, n_spare_nodes: int = 2,
                 node_memory: int = 8 << 30, nic_bandwidth: float = 25e9,
                 pfs_bandwidth: float = 40e9, pfs_root: Optional[str] = None,
                 policy: str = "adaptive", time_scale: float = 0.0,
                 keep_l1: int = 2, max_concurrent_drains: int = 2,
                 spill_bytes: int = 0, adaptive_interval: bool = True,
                 default_mtbf_s: float = 3600.0):
        self.clock = SimClock(time_scale)
        self.fault = FaultInjector()
        self.rm = ResourceManager()
        for _ in range(n_icheck_nodes + n_spare_nodes):
            self.rm.make_node(memory_bytes=node_memory,
                              nic_bandwidth=nic_bandwidth)
        self._tmp = None
        if pfs_root is None:
            # ignore_cleanup_errors: a drain/agent thread that outlives its
            # join timeout must not turn teardown into an OSError
            self._tmp = tempfile.TemporaryDirectory(
                prefix="icheck-pfs-", ignore_cleanup_errors=True)
            pfs_root = self._tmp.name
        self.pfs = PFSTier(pfs_root, bandwidth=pfs_bandwidth, clock=self.clock)
        self.controller = Controller(
            self.rm, self.pfs, policy=policy, initial_nodes=n_icheck_nodes,
            clock=self.clock, fault=self.fault, keep_l1=keep_l1,
            max_concurrent_drains=max_concurrent_drains,
            spill_bytes=spill_bytes, adaptive_interval=adaptive_interval,
            default_mtbf_s=default_mtbf_s)

    @property
    def telemetry(self):
        """The controller's TelemetryService (structured + Prometheus)."""
        return self.controller.telemetry

    @property
    def bus(self):
        """The controller's event bus (subscribe for telemetry)."""
        return self.controller.bus

    def close(self) -> None:
        self.controller.close()
        if self._tmp is not None:
            self._tmp.cleanup()

    def __enter__(self) -> "ICheckCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
