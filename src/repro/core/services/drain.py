"""Orchestrated PFS drains (paper §II: "orchestrate the writing of the
checkpoint data into PFS by minimizing the effect on running applications").

The queue and concurrency bound that used to live inside the Controller are
now a worker pool: ``max_concurrent`` drain workers pull finalized
checkpoints off a queue, so at most that many checkpoints contend for the
shared PFS ingest bandwidth at once — *and* that many genuinely proceed in
parallel (the old single flusher thread serialized everything its semaphore
nominally allowed).

Also owns L1 garbage collection (keep the newest ``keep_l1`` durable
checkpoints resident for fast restarts) and bounded drain retry.

The same worker pool runs the **background lane**: callables submitted via
:meth:`submit_background` (the StorageLifecycleService's L2→L3 trickle).
Background work is strictly lower priority — a worker only picks it up when
no live drain is queued or active, so the trickle never contends with the
latency-sensitive L1→L2 path for workers or PFS bandwidth.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Tuple

from ...obs import trace_id_for
from .. import events as E
from ..types import AppId, CheckpointMeta, CkptStatus, ShardKey


class DrainOrchestrator:
    def __init__(self, ctl, max_concurrent: int = 2, keep_l1: int = 2,
                 max_attempts: int = 2):
        self.ctl = ctl
        self.max_concurrent = max(1, int(max_concurrent))
        self.keep_l1 = keep_l1
        self.max_attempts = max(1, int(max_attempts))
        # queue entries carry the submitter's TraceContext: the drain
        # crosses into a worker thread, so causality rides the tuple
        self._q: "queue.Queue[Tuple[CheckpointMeta, int, object, object]]" = \
            queue.Queue()
        self._bg: "queue.Queue[Tuple[Callable[[], None], object]]" = \
            queue.Queue()
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._active = 0
        self._inflight = 0        # submitted but not yet fully processed
        self._bg_inflight = 0     # background jobs submitted, not finished
        self._bg_completed = 0
        self._bg_failed = 0
        self._max_active = 0
        self._completed = 0
        self._failed = 0
        self._stale_dropped = 0   # queue entries fenced off post-recovery
        self._workers: List[threading.Thread] = []

    # ----------------------------------------------------------------- admin
    def start(self) -> None:
        for i in range(self.max_concurrent):
            t = threading.Thread(target=self._loop, daemon=True,
                                 name=f"icheck-drain-{i}")
            self._workers.append(t)
            t.start()

    def close(self) -> None:
        self._stop.set()
        for t in self._workers:
            t.join(timeout=5)

    def stats(self) -> dict:
        with self._lock:
            return {
                "workers": len(self._workers),
                "active": self._active,
                "inflight": self._inflight,
                "max_observed_concurrency": self._max_active,
                "completed": self._completed,
                "failed": self._failed,
                "stale_dropped": self._stale_dropped,
                "queued": self._q.qsize(),
                "background_inflight": self._bg_inflight,
                "background_completed": self._bg_completed,
                "background_failed": self._bg_failed,
            }

    def _epoch(self):
        fence = getattr(self.ctl, "fence", None)
        return fence.current if fence is not None else None

    def _stale(self, epoch) -> bool:
        """True when a queue entry predates a controller recovery — it must
        be dropped, not executed against the post-recovery state."""
        fence = getattr(self.ctl, "fence", None)
        return fence is not None and epoch is not None \
            and epoch != fence.current

    # ------------------------------------------------------------- interface
    def submit(self, meta: CheckpointMeta, attempt: int = 0,
               trace=None) -> None:
        tracer = getattr(self.ctl, "tracer", None)
        if trace is None and tracer is not None:
            trace = tracer.current()
        with self._lock:
            self._inflight += 1
        self._q.put((meta, attempt, trace, self._epoch()))

    def submit_background(self, fn: Callable[[], None]) -> None:
        """Queue low-priority work (L2→L3 trickle) behind all live drains."""
        with self._lock:
            self._bg_inflight += 1
        self._bg.put((fn, self._epoch()))

    def wait_idle(self, timeout: float = 30.0) -> None:
        """Block until the drain queue empties and no drain is in flight."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                inflight = self._inflight
            if inflight == 0:
                return
            time.sleep(0.01)
        raise TimeoutError("drains did not settle")

    def wait_background(self, timeout: float = 30.0) -> None:
        """Block until background work (and the drains gating it) settles."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                pending = self._bg_inflight + self._inflight
            if pending == 0:
                return
            time.sleep(0.01)
        raise TimeoutError("background work did not settle")

    # ------------------------------------------------------------------ guts
    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                meta, attempt, trace, epoch = self._q.get(timeout=0.05)
            except queue.Empty:
                self._run_background_one()
                continue
            if self._stale(epoch):
                # queued by a pre-recovery controller: fenced off
                with self._lock:
                    self._inflight -= 1
                    self._stale_dropped += 1
                self.ctl.bus.publish(E.STALE_OP_REJECTED, kind="drain",
                                     app=meta.app_id, ckpt=meta.ckpt_id,
                                     epoch=epoch, current=self._epoch())
                continue
            with self._lock:
                self._active += 1
                self._max_active = max(self._max_active, self._active)
            try:
                self._drain_one(meta, attempt, trace)
            finally:
                with self._lock:
                    self._active -= 1
                    self._inflight -= 1

    def _run_background_one(self) -> None:
        # strict priority: background work only starts while no drain is
        # queued or running, so the trickle never steals PFS bandwidth or a
        # worker slot from the latency-sensitive L1→L2 path
        with self._lock:
            if self._active > 0:
                return
        if not self._q.empty():
            return
        try:
            fn, epoch = self._bg.get_nowait()
        except queue.Empty:
            return
        if self._stale(epoch):
            with self._lock:
                self._bg_inflight -= 1
                self._stale_dropped += 1
            self.ctl.bus.publish(E.STALE_OP_REJECTED, kind="background",
                                 epoch=epoch, current=self._epoch())
            return
        ok = True
        try:
            fn()
        except Exception:   # noqa: BLE001 - lifecycle jobs own their retries
            ok = False
        finally:
            with self._lock:
                self._bg_inflight -= 1
                if ok:
                    self._bg_completed += 1
                else:
                    self._bg_failed += 1

    def _drain_one(self, meta: CheckpointMeta, attempt: int,
                   trace=None) -> None:
        tracer = getattr(self.ctl, "tracer", None)
        if tracer is not None and tracer.enabled:
            with tracer.use(trace), tracer.span(
                    "l2_drain", trace_id_for(meta.app_id, meta.ckpt_id),
                    f"drain/{threading.current_thread().name}",
                    attempt=attempt):
                self._drain_one_inner(meta, attempt, trace)
        else:
            self._drain_one_inner(meta, attempt, trace)

    def _drain_one_inner(self, meta: CheckpointMeta, attempt: int,
                         trace=None) -> None:
        ctl = self.ctl
        t0 = ctl.clock.now()
        with ctl._lock:
            ctl.catalog.set_status(meta, CkptStatus.DRAINING)
            drained_bytes = sum(s.nbytes for k, s in meta.shards.items()
                                if k.replica == 0)
        if ctl.catalog.ec_geometry(meta.app_id) is not None:
            # erasure-coded app: L1 holds only fragments (no replica-0
            # keys), but the PFS stores *whole* shards so manifests,
            # completeness probes and cold restarts stay format-identical
            # to replicated apps — reconstruct each logical shard from any
            # k fragments and write it down
            ok = self._drain_ec(meta)
        else:
            # each agent drains the shards it holds → parallel PFS writers
            futures = []
            for mgr in ctl.managers():
                if not mgr.alive():
                    continue
                for agent in mgr.agents():
                    keys = [k for k in agent.store.keys()
                            if k.app_id == meta.app_id
                            and k.ckpt_id == meta.ckpt_id
                            and k.replica == 0]
                    if keys:
                        futures.append(agent.drain(keys, ctl.pfs))
            ok = True
            for f in futures:
                try:
                    f.result(timeout=60)
                except Exception:
                    ok = False
        if ok and ctl.pfs.checkpoint_complete(meta):
            ctl.pfs.write_manifest(meta)
            ctl.catalog.set_status(meta, CkptStatus.IN_L2)
            with self._lock:
                self._completed += 1
            ctl.bus.publish(E.CKPT_IN_L2, app=meta.app_id, ckpt=meta.ckpt_id,
                            bytes=drained_bytes,
                            sim_s=max(ctl.clock.now() - t0, 0.0))
            self.gc_l1(meta.app_id)
        elif attempt + 1 < self.max_attempts:
            # transient failure (e.g. an agent died mid-drain): give the
            # health monitor a few heartbeats to re-replicate / replace
            # agents before retrying, or the retry races the recovery
            ctl.catalog.set_status(meta, CkptStatus.IN_L1)
            recovery = 4 * getattr(ctl.health, "interval", 0.05)
            self._stop.wait(recovery)
            # re-carry the original context: the retried drain is still part
            # of the same checkpoint's trace, not an orphan
            self.submit(meta, attempt + 1, trace=trace)
        else:
            # still restartable from L1
            ctl.catalog.set_status(meta, CkptStatus.IN_L1)
            with self._lock:
                self._failed += 1
            ctl.bus.publish(E.DRAIN_FAILED, app=meta.app_id, ckpt=meta.ckpt_id)

    def _drain_ec(self, meta: CheckpointMeta) -> bool:
        """Drain an erasure-coded checkpoint: reconstruct every logical
        shard from its L1 fragments (any k suffice) and write the full
        payload to the PFS under the base key."""
        ctl = self.ctl
        ok = True
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                key = ShardKey(meta.app_id, meta.ckpt_id, name, part)
                if ctl.pfs.has_shard(key):
                    continue          # a retry after a partial first pass
                try:
                    payload = ctl.fetch_shard(meta.app_id, meta.ckpt_id,
                                              name, part)
                    ctl.pfs.write_shard(key, payload)
                except Exception:   # noqa: BLE001 - retried by the caller
                    ok = False
        return ok

    def gc_l1(self, app_id: AppId) -> None:
        """Keep only the newest ``keep_l1`` durable checkpoints in L1."""
        ctl = self.ctl
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None:     # app record gone (e.g. controller crashed)
                return
            durable = sorted((m.ckpt_id for m in app.checkpoints.values()
                              if m.status in (CkptStatus.IN_L2,
                                              CkptStatus.IN_L3)))
        evict = durable[:-self.keep_l1] if self.keep_l1 > 0 else durable
        for ckpt_id in evict:
            for mgr in ctl.managers():
                mgr.store.drop_checkpoint(app_id, ckpt_id)
