"""Event-bus telemetry: the observation half of the adaptive loop.

The paper's controller "monitors the applications and the system state to
adapt the checkpoint strategy at runtime" (§II).  This service is that
observer: it subscribes to the commit / drain / failure / resize events every
subsystem already publishes and maintains per-application estimates —

  * EWMA commit latency and commit size (the Young/Daly commit cost ``C``),
  * EWMA L1→L2 drain throughput,
  * failure inter-arrival times (the MTBF estimate), seeded by a
    configurable prior until real failures are observed,

plus cluster-wide failure counters and on-demand tier occupancy sampled from
the node managers.  Everything is exported two ways: :meth:`snapshot` (a
structured dict for benchmarks / the IntervalController) and
:meth:`prometheus` (Prometheus text exposition format for scraping).

Resize-class events (forewarnings, agent scale-up/down, node add/retake/
migrate) mark the affected apps' commit-cost estimates *stale*: the node set
changed, so the next observed commit replaces the estimate instead of being
blended into it.  That is what lets the IntervalController re-solve quickly
after a reconfiguration.
"""
from __future__ import annotations

import re
import threading
from typing import Dict, List, Optional

from .. import events as E
from ...obs import LogHistogram
from ..simnet import EWMA
from ..types import AppId

# Prometheus exposition-format naming rules
# (https://prometheus.io/docs/concepts/data_model/): every exported metric
# and label name is validated against these at export time, so a typo'd
# gauge fails tests instead of silently producing an unscrapable line.
_METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape_label_value(value) -> str:
    """Escape a label value per the text exposition format: backslash,
    double-quote and newline must be escaped inside the quotes."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))

# events that mean "the node set / agent set serving an app changed, so the
# commit cost C it observes is about to change too"
RESIZE_EVENTS = (E.RESIZE_FOREWARNED, E.AGENTS_SCALED_UP,
                 E.AGENTS_SCALED_DOWN, E.NODE_ADDED, E.NODE_RETAKEN,
                 E.NODE_MIGRATED, E.CAPACITY_GROW)
# cluster-level failures count against every connected app's MTBF
CLUSTER_FAILURE_EVENTS = (E.NODE_FAILED, E.AGENT_FAILED)
# storage-lifecycle events counted cluster-wide (the lifecycle service's
# observable surface: demotions, trickle completions/failures, retention
# expiries)
LIFECYCLE_EVENTS = (E.SHARD_DEMOTED, E.DEMOTE_FAILED, E.WATERMARK_CROSSED,
                    E.CKPT_IN_L3, E.CKPT_EXPIRED, E.L3_UPLOAD_FAILED)
# erasure-coded durability events, counted cluster-wide (stripe commits,
# peer rebuilds after failures, degraded reads) plus the health monitor's
# own error channel
EC_EVENTS = (E.EC_STRIPE_COMMITTED, E.EC_REBUILD_STARTED, E.EC_REBUILD_DONE,
             E.EC_REBUILD_FAILED, E.EC_DEGRADED_READ, E.MONITOR_ERROR)


class AppTelemetry:
    """Per-application aggregates, all updated from bus events."""

    def __init__(self, alpha: float):
        self.commit_latency_s = EWMA(alpha=alpha)
        self.commit_bytes = EWMA(alpha=alpha)
        self.drain_rate_Bps = EWMA(alpha=alpha)
        self.failure_gap_s = EWMA(alpha=alpha)
        self.commit_latency_sum_s = 0.0      # for the unbiased mean
        self.commits = 0
        self.drains = 0
        self.drain_failures = 0
        self.ckpt_failures = 0
        self.failures = 0
        self.retries = 0
        self.last_commit_t: Optional[float] = None
        self.last_failure_t: Optional[float] = None
        self.commit_cost_stale = False
        # incremental commit path (ckpt_delta_committed / delta_chain_reset)
        self.codec_raw_bytes = 0             # pre-codec bytes, cumulative
        self.codec_encoded_bytes = 0         # bytes-on-wire, cumulative
        self.codec_encode_s = EWMA(alpha=alpha)
        self.delta_key_frames = 0
        self.delta_delta_frames = 0
        self.delta_chain_resets = 0
        # adapt-window redistribution (redistribution_done / _fallback)
        self.redistributions_peer = 0
        self.redistributions_client = 0
        self.redist_fallbacks = 0
        self.redist_bytes_moved = 0          # slice wire bytes, cumulative
        self.redist_bytes_through_client = 0
        self.redist_peer_hops = 0            # agent→agent slice reads
        self.redist_window_s = EWMA(alpha=alpha)
        # analytic max-lane model vs serialized sim-clock wall time of the
        # same window — the gauge CI watches to validate the lane model
        self.redist_window_skew = EWMA(alpha=alpha)
        # zero-stall (two-phase) resize: overlap windows opened, cutovers
        # landed, commits absorbed while streaming, re-hydration fallbacks,
        # and the bounded cutover stall
        self.overlap_windows = 0
        self.overlap_cutovers = 0
        self.overlap_commits = 0
        self.overlap_rehydrations = 0
        self.cutover_stall_s = EWMA(alpha=alpha)
        # restore path (restore_done)
        self.restores = 0
        self.restore_s = EWMA(alpha=alpha)
        # distributions beside the EWMAs: fixed log2 buckets, so p50/p95/
        # p99 and Prometheus _bucket exports are stable across runs
        self.commit_latency_hist = LogHistogram()
        self.commit_bytes_hist = LogHistogram.for_bytes()
        self.drain_hist = LogHistogram()
        self.restore_hist = LogHistogram()
        self.stall_hist = LogHistogram()

    def as_dict(self) -> dict:
        return {
            "commits": self.commits,
            "commit_latency_s": self.commit_latency_s.predict(),
            "mean_commit_latency_s": self.commit_latency_sum_s
            / self.commits if self.commits else 0.0,
            "commit_bytes": self.commit_bytes.predict(),
            "drains": self.drains,
            "drain_rate_Bps": self.drain_rate_Bps.predict(),
            "drain_failures": self.drain_failures,
            "ckpt_failures": self.ckpt_failures,
            "failures": self.failures,
            "retries": self.retries,
            "failure_gap_s": self.failure_gap_s.predict(),
            "commit_cost_stale": self.commit_cost_stale,
            "codec_raw_bytes": self.codec_raw_bytes,
            "codec_encoded_bytes": self.codec_encoded_bytes,
            "codec_compression_ratio": self.codec_raw_bytes
            / self.codec_encoded_bytes if self.codec_encoded_bytes else 1.0,
            "codec_encode_s": self.codec_encode_s.predict(),
            "delta_key_frames": self.delta_key_frames,
            "delta_delta_frames": self.delta_delta_frames,
            "delta_chain_resets": self.delta_chain_resets,
            "redistributions_peer": self.redistributions_peer,
            "redistributions_client": self.redistributions_client,
            "redist_fallbacks": self.redist_fallbacks,
            "redist_bytes_moved": self.redist_bytes_moved,
            "redist_bytes_through_client": self.redist_bytes_through_client,
            "redist_peer_hops": self.redist_peer_hops,
            "redist_window_s": self.redist_window_s.predict(),
            "redist_window_skew": self.redist_window_skew.predict(),
            "overlap_windows": self.overlap_windows,
            "overlap_cutovers": self.overlap_cutovers,
            "overlap_commits": self.overlap_commits,
            "overlap_rehydrations": self.overlap_rehydrations,
            "cutover_stall_s": self.cutover_stall_s.predict(),
            "restores": self.restores,
            "restore_s": self.restore_s.predict(),
            "commit_latency_quantiles": self.commit_latency_hist.as_dict(),
            "commit_bytes_quantiles": self.commit_bytes_hist.as_dict(),
            "drain_quantiles": self.drain_hist.as_dict(),
            "restore_quantiles": self.restore_hist.as_dict(),
            "cutover_stall_quantiles": self.stall_hist.as_dict(),
        }


class TelemetryService:
    """Bus subscriber aggregating the signals the adaptive loop runs on."""

    def __init__(self, ctl, alpha: float = 0.3,
                 default_mtbf_s: float = 3600.0):
        self.ctl = ctl
        self.alpha = float(alpha)
        self.default_mtbf_s = float(default_mtbf_s)
        self._lock = threading.Lock()
        self._apps: Dict[AppId, AppTelemetry] = {}
        self._cluster_failures = 0
        self._events_seen = 0
        # cluster-level per-hop transfer distributions, fed by the SimNIC/
        # MemBus ``on_transfer`` observers the controller wires per node
        self._hop_latency_hist = LogHistogram()
        self._hop_bytes_hist = LogHistogram.for_bytes()
        self._lifecycle = {
            "shard_demotions": 0,
            "demote_failures": 0,
            "watermark_crossings_high": 0,
            "ckpts_in_l3": 0,
            "ckpts_expired": 0,
            "l3_trickle_bytes": 0,
            "l3_upload_failures": 0,
        }
        # erasure-coded durability counters (cluster-level: stripes are an
        # L1 property of the whole store, like demotions)
        self._ec = {
            "stripes_committed": 0,
            "logical_bytes": 0,          # pre-codec payload bytes
            "fragment_bytes": 0,         # k+m fragments on the wire
            "rebuilds_started": 0,
            "rebuilds_done": 0,
            "rebuilds_failed": 0,
            "rebuilds_degraded": 0,      # decode needed parity / lower tier
            "rebuild_bytes": 0,          # payload bytes regenerated
            "degraded_reads": 0,         # fetches that GF-decoded via parity
            "monitor_errors": 0,
        }
        self._ec_rebuild_hist = LogHistogram()
        self._unsubscribe = ctl.bus.subscribe(
            self._on_event,
            events=(E.COMMIT_DONE, E.CKPT_IN_L2, E.DRAIN_FAILED,
                    E.CKPT_FAILED, E.APP_RANK_FAILED, E.APP_REGISTERED,
                    E.CKPT_DELTA_COMMITTED, E.DELTA_CHAIN_RESET,
                    E.REDISTRIBUTION_DONE, E.REDISTRIBUTION_FALLBACK,
                    E.RESIZE_OVERLAP_STARTED, E.CUTOVER_DONE,
                    E.RESTORE_DONE)
            + CLUSTER_FAILURE_EVENTS + RESIZE_EVENTS + LIFECYCLE_EVENTS
            + EC_EVENTS)

    def close(self) -> None:
        self._unsubscribe()

    # ----------------------------------------------------------- ingestion
    def _app(self, app_id: AppId) -> AppTelemetry:
        # callers hold self._lock
        tel = self._apps.get(app_id)
        if tel is None:
            tel = self._apps[app_id] = AppTelemetry(self.alpha)
        return tel

    def _on_event(self, ev: E.Event) -> None:
        with self._lock:
            self._events_seen += 1
            name, p = ev.name, ev.payload
            if name == E.APP_REGISTERED:
                self._app(p["app"])
            elif name == E.COMMIT_DONE:
                tel = self._app(p["app"])
                if tel.commit_cost_stale:
                    # first commit on the new node set: replace, don't blend
                    tel.commit_latency_s = EWMA(self.alpha)
                    tel.commit_bytes = EWMA(self.alpha)
                    tel.commit_cost_stale = False
                tel.commits += 1
                tel.retries += int(p.get("retries", 0))
                tel.commit_latency_sum_s += float(p.get("sim_s", 0.0))
                tel.commit_latency_s.update(float(p.get("sim_s", 0.0)))
                tel.commit_bytes.update(float(p.get("bytes", 0)))
                tel.commit_latency_hist.observe(float(p.get("sim_s", 0.0)))
                tel.commit_bytes_hist.observe(float(p.get("bytes", 0)))
                tel.last_commit_t = ev.sim_t
            elif name == E.CKPT_IN_L2:
                tel = self._app(p["app"])
                tel.drains += 1
                nbytes, sim_s = p.get("bytes"), p.get("sim_s")
                if sim_s is not None:
                    tel.drain_hist.observe(float(sim_s))
                if nbytes and sim_s:
                    tel.drain_rate_Bps.update(float(nbytes) / max(
                        float(sim_s), 1e-12))
            elif name == E.CKPT_DELTA_COMMITTED:
                tel = self._app(p["app"])
                tel.codec_raw_bytes += int(p.get("raw_bytes", 0))
                tel.codec_encoded_bytes += int(p.get("encoded_bytes", 0))
                tel.codec_encode_s.update(float(p.get("encode_s", 0.0)))
                tel.delta_key_frames += int(p.get("key_frames", 0))
                tel.delta_delta_frames += int(p.get("delta_frames", 0))
            elif name == E.DELTA_CHAIN_RESET:
                self._app(p["app"]).delta_chain_resets += 1
            elif name == E.REDISTRIBUTION_DONE:
                tel = self._app(p["app"])
                if p.get("via") == "peer":
                    tel.redistributions_peer += 1
                else:
                    tel.redistributions_client += 1
                tel.redist_bytes_moved += int(p.get("bytes_moved", 0))
                tel.redist_bytes_through_client += \
                    int(p.get("bytes_through_client", 0))
                tel.redist_peer_hops += int(p.get("peer_hops", 0))
                tel.redist_window_s.update(float(p.get("sim_s", 0.0)))
                if "window_skew" in p:
                    tel.redist_window_skew.update(float(p["window_skew"]))
            elif name == E.RESIZE_OVERLAP_STARTED:
                self._app(p["app"]).overlap_windows += 1
            elif name == E.CUTOVER_DONE:
                tel = self._app(p["app"])
                tel.overlap_cutovers += 1
                tel.overlap_commits += int(p.get("overlap_commits", 0))
                tel.overlap_rehydrations += int(bool(p.get("rehydrated")))
                tel.cutover_stall_s.update(float(p.get("stall_sim_s", 0.0)))
                tel.stall_hist.observe(float(p.get("stall_sim_s", 0.0)))
            elif name == E.RESTORE_DONE:
                tel = self._app(p["app"])
                tel.restores += 1
                tel.restore_s.update(float(p.get("sim_s", 0.0)))
                tel.restore_hist.observe(float(p.get("sim_s", 0.0)))
            elif name == E.REDISTRIBUTION_FALLBACK:
                self._app(p["app"]).redist_fallbacks += 1
            elif name == E.DRAIN_FAILED:
                self._app(p["app"]).drain_failures += 1
            elif name == E.CKPT_FAILED:
                self._app(p["app"]).ckpt_failures += 1
            elif name == E.APP_RANK_FAILED:
                self._record_failure(self._app(p["app"]), ev.sim_t)
            elif name in CLUSTER_FAILURE_EVENTS:
                self._cluster_failures += 1
                for tel in self._apps.values():
                    self._record_failure(tel, ev.sim_t)
            elif name == E.SHARD_DEMOTED:
                self._lifecycle["shard_demotions"] += 1
            elif name == E.DEMOTE_FAILED:
                self._lifecycle["demote_failures"] += 1
            elif name == E.WATERMARK_CROSSED:
                if p.get("direction") == "high":
                    self._lifecycle["watermark_crossings_high"] += 1
            elif name == E.CKPT_IN_L3:
                self._lifecycle["ckpts_in_l3"] += 1
                self._lifecycle["l3_trickle_bytes"] += int(p.get("bytes", 0))
            elif name == E.CKPT_EXPIRED:
                self._lifecycle["ckpts_expired"] += 1
            elif name == E.L3_UPLOAD_FAILED:
                self._lifecycle["l3_upload_failures"] += 1
            elif name == E.EC_STRIPE_COMMITTED:
                self._ec["stripes_committed"] += int(p.get("stripes", 0))
                self._ec["logical_bytes"] += int(p.get("logical_bytes", 0))
                self._ec["fragment_bytes"] += int(p.get("fragment_bytes", 0))
            elif name == E.EC_REBUILD_STARTED:
                self._ec["rebuilds_started"] += 1
            elif name == E.EC_REBUILD_DONE:
                self._ec["rebuilds_done"] += 1
                self._ec["rebuilds_degraded"] += int(bool(p.get("degraded")))
                self._ec["rebuild_bytes"] += int(p.get("bytes", 0))
                self._ec_rebuild_hist.observe(float(p.get("sim_s", 0.0)))
            elif name == E.EC_REBUILD_FAILED:
                self._ec["rebuilds_failed"] += 1
            elif name == E.EC_DEGRADED_READ:
                self._ec["degraded_reads"] += 1
            elif name == E.MONITOR_ERROR:
                self._ec["monitor_errors"] += 1
            elif name in RESIZE_EVENTS:
                app_id = p.get("app")
                targets = [self._app(app_id)] if app_id \
                    else list(self._apps.values())
                for tel in targets:
                    tel.commit_cost_stale = True

    def observe_transfer(self, link_name: str, nbytes: int,
                         sim_s: float) -> None:
        """SimNIC/MemBus per-transfer observer: feeds the cluster-level
        peer-hop latency/size histograms (no lock needed — the histograms
        are internally synchronized and hot-path cheap)."""
        self._hop_latency_hist.observe(float(sim_s))
        self._hop_bytes_hist.observe(float(nbytes))

    def _record_failure(self, tel: AppTelemetry, t: float) -> None:
        tel.failures += 1
        if tel.last_failure_t is not None and t > tel.last_failure_t:
            tel.failure_gap_s.update(t - tel.last_failure_t)
        tel.last_failure_t = t

    # ------------------------------------------------------------ estimates
    def commit_cost_s(self, app_id: AppId) -> Optional[float]:
        """EWMA commit cost C (sim seconds), or None before any commit."""
        with self._lock:
            tel = self._apps.get(app_id)
            if tel is None or tel.commits == 0:
                return None
            return tel.commit_latency_s.predict()

    def commit_cost_stale(self, app_id: AppId) -> bool:
        with self._lock:
            tel = self._apps.get(app_id)
            return bool(tel and tel.commit_cost_stale)

    def mtbf_s(self, app_id: AppId) -> float:
        """Failure inter-arrival estimate (sim s); prior until ≥2 failures."""
        with self._lock:
            tel = self._apps.get(app_id)
            if tel is None or tel.failures < 2:
                return self.default_mtbf_s
            return max(tel.failure_gap_s.predict(), 1e-9)

    def drain_rate_Bps(self, app_id: AppId) -> Optional[float]:
        with self._lock:
            tel = self._apps.get(app_id)
            if tel is None or tel.drains == 0:
                return None
            return tel.drain_rate_Bps.predict()

    def app_ids(self) -> List[AppId]:
        with self._lock:
            return list(self._apps)

    # -------------------------------------------------------------- export
    def tier_occupancy(self) -> List[dict]:
        """Per-tier occupancy: node tiers from the managers, plus the shared
        cluster tiers (PFS, and the L3 object store when configured).

        Unbounded tiers report ``capacity_bytes=0`` and ``occupancy=0.0``
        (JSON and Prometheus have no portable infinity).
        """
        rows = []
        for mgr in self.ctl.managers():
            # the managers own the per-node view (same rows the heartbeat
            # carries) — one definition of the occupancy convention
            for r in mgr.tier_occupancy():
                rows.append({"node": mgr.node_id, **r})
        for tier in (getattr(self.ctl, "pfs", None),
                     getattr(self.ctl, "l3", None)):
            if tier is None:
                continue
            cap = tier.capacity
            used = tier.used_bytes
            bounded = cap not in (None, 0) and cap != float("inf")
            rows.append({
                "node": "cluster",
                "tier": tier.name,
                "used_bytes": used,
                "capacity_bytes": cap if bounded else 0,
                "occupancy": used / cap if bounded else 0.0,
            })
        return rows

    def snapshot(self) -> dict:
        """Structured telemetry: per-app estimates + cluster + occupancy."""
        with self._lock:
            per_app = {a: t.as_dict() for a, t in self._apps.items()}
            cluster_failures = self._cluster_failures
            events_seen = self._events_seen
            lifecycle = dict(self._lifecycle)
            ec = dict(self._ec)
            ec["rebuild_quantiles"] = self._ec_rebuild_hist.as_dict()
        for app_id, row in per_app.items():
            row["mtbf_s"] = self.mtbf_s(app_id)
        out = {
            "per_app": per_app,
            "cluster": {
                "failures_total": cluster_failures,
                "events_seen": events_seen,
                "default_mtbf_s": self.default_mtbf_s,
                "peer_hop_quantiles": self._hop_latency_hist.as_dict(),
                "peer_hop_bytes_quantiles": self._hop_bytes_hist.as_dict(),
            },
            "tiers": self.tier_occupancy(),
            "lifecycle": lifecycle,
            "ec": ec,
        }
        l3 = getattr(self.ctl, "l3", None)
        if l3 is not None:
            cost = l3.cost_breakdown()
            cost["total_usd"] = l3.cost_usd()
            out["l3"] = cost
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        snap = self.snapshot()
        out: List[str] = []

        def _labels(labels: Dict[str, object]) -> str:
            for k in labels:
                if not _LABEL_NAME_RE.match(k):
                    raise ValueError(f"invalid Prometheus label name: {k!r}")
            lbl = ",".join(f'{k}="{_escape_label_value(v)}"'
                           for k, v in labels.items())
            return "{" + lbl + "}" if lbl else ""

        def metric(name: str, mtype: str, help_: str, rows) -> None:
            if not _METRIC_NAME_RE.match(name):
                raise ValueError(f"invalid Prometheus metric name: {name!r}")
            out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} {mtype}")
            for labels, value in rows:
                out.append(f"{name}{_labels(labels)} {value:.9g}")

        def histogram(name: str, help_: str, rows) -> None:
            """``rows`` is ``[(labels, LogHistogram), ...]``: emit the
            conventional ``_bucket``/``_sum``/``_count`` series."""
            if not _METRIC_NAME_RE.match(name):
                raise ValueError(f"invalid Prometheus metric name: {name!r}")
            out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} histogram")
            for labels, hist in rows:
                for le, cum in hist.prometheus_rows():
                    out.append(f"{name}_bucket"
                               f"{_labels({**labels, 'le': le})} {cum:.9g}")
                out.append(f"{name}_sum{_labels(labels)} {hist.sum:.9g}")
                out.append(f"{name}_count{_labels(labels)} {hist.count}")

        apps = snap["per_app"]
        metric("icheck_commits_total", "counter",
               "Completed checkpoint commits per application",
               [({"app": a}, t["commits"]) for a, t in apps.items()])
        metric("icheck_commit_latency_seconds", "gauge",
               "EWMA commit latency (sim seconds)",
               [({"app": a}, t["commit_latency_s"]) for a, t in apps.items()])
        metric("icheck_commit_bytes", "gauge",
               "EWMA checkpoint size per commit",
               [({"app": a}, t["commit_bytes"]) for a, t in apps.items()])
        metric("icheck_drain_throughput_bytes_per_second", "gauge",
               "EWMA L1->L2 drain throughput",
               [({"app": a}, t["drain_rate_Bps"]) for a, t in apps.items()])
        metric("icheck_codec_compression_ratio", "gauge",
               "Raw/encoded bytes-on-wire ratio of the q8-delta commit path",
               [({"app": a}, t["codec_compression_ratio"])
                for a, t in apps.items()])
        metric("icheck_codec_encode_seconds", "gauge",
               "EWMA host-clock commit encode time (device+pack)",
               [({"app": a}, t["codec_encode_s"]) for a, t in apps.items()])
        metric("icheck_codec_bytes_total", "counter",
               "Commit-path codec bytes (pre-codec raw vs on-wire encoded)",
               [({"app": a, "kind": kind}, t[f"codec_{kind}_bytes"])
                for a, t in apps.items() for kind in ("raw", "encoded")])
        metric("icheck_delta_frames_total", "counter",
               "q8-delta frames committed, by kind",
               [({"app": a, "kind": kind}, t[f"delta_{kind}_frames"])
                for a, t in apps.items() for kind in ("key", "delta")])
        metric("icheck_delta_chain_resets_total", "counter",
               "Delta chains invalidated (resize/failure/demotion/expiry)",
               [({"app": a}, t["delta_chain_resets"])
                for a, t in apps.items()])
        metric("icheck_redistributions_total", "counter",
               "Adapt-window redistributions, by data path",
               [({"app": a, "via": via}, t[f"redistributions_{via}"])
                for a, t in apps.items() for via in ("peer", "client")])
        metric("icheck_redist_fallbacks_total", "counter",
               "Peer redistributions that fell back to the client funnel",
               [({"app": a}, t["redist_fallbacks"]) for a, t in apps.items()])
        metric("icheck_redist_bytes_total", "counter",
               "Redistribution bytes: slice wire bytes moved vs bytes "
               "funnelled through the client",
               [({"app": a, "kind": kind}, t[f"redist_bytes_{kind}"])
                for a, t in apps.items()
                for kind in ("moved", "through_client")])
        metric("icheck_redist_peer_hops_total", "counter",
               "Agent-to-agent slice reads executed during adapt windows",
               [({"app": a}, t["redist_peer_hops"]) for a, t in apps.items()])
        metric("icheck_redist_window_seconds", "gauge",
               "EWMA simulated adapt-window redistribution time",
               [({"app": a}, t["redist_window_s"]) for a, t in apps.items()])
        metric("icheck_redist_window_skew_ratio", "gauge",
               "EWMA analytic-max-lane / sim-clock-wall ratio of the adapt "
               "window (validates the CommitHandle lane model)",
               [({"app": a}, t["redist_window_skew"])
                for a, t in apps.items()])
        metric("icheck_overlap_windows_total", "counter",
               "Zero-stall resize overlap windows opened",
               [({"app": a}, t["overlap_windows"]) for a, t in apps.items()])
        metric("icheck_overlap_cutovers_total", "counter",
               "Zero-stall resize cutovers landed",
               [({"app": a}, t["overlap_cutovers"]) for a, t in apps.items()])
        metric("icheck_overlap_commits_total", "counter",
               "Commits absorbed while overlap windows streamed",
               [({"app": a}, t["overlap_commits"]) for a, t in apps.items()])
        metric("icheck_overlap_rehydrations_total", "counter",
               "Cutovers that re-hydrated from the head instead of "
               "replaying the tail (chain reset raced the window)",
               [({"app": a}, t["overlap_rehydrations"])
                for a, t in apps.items()])
        metric("icheck_cutover_stall_seconds", "gauge",
               "EWMA bounded cutover stall (tail replay + patch fetch)",
               [({"app": a}, t["cutover_stall_s"]) for a, t in apps.items()])
        metric("icheck_failures_total", "counter",
               "Failures charged to each application",
               [({"app": a}, t["failures"]) for a, t in apps.items()])
        metric("icheck_mtbf_seconds", "gauge",
               "Failure inter-arrival estimate (sim seconds)",
               [({"app": a}, t["mtbf_s"]) for a, t in apps.items()])
        metric("icheck_cluster_failures_total", "counter",
               "Cluster-level node/agent failures",
               [({}, snap["cluster"]["failures_total"])])
        metric("icheck_tier_used_bytes", "gauge",
               "Bytes resident per storage tier (node tiers + shared tiers)",
               [({"node": r["node"], "tier": r["tier"]}, r["used_bytes"])
                for r in snap["tiers"]])
        metric("icheck_tier_occupancy_ratio", "gauge",
               "Fill fraction per storage tier (0 for unbounded tiers)",
               [({"node": r["node"], "tier": r["tier"]}, r["occupancy"])
                for r in snap["tiers"]])
        life = snap["lifecycle"]
        metric("icheck_shard_demotions_total", "counter",
               "Shards pushed down a tier by the watermark policy",
               [({}, life["shard_demotions"])])
        metric("icheck_demote_failures_total", "counter",
               "Demotions that could not happen (observable reasons on bus)",
               [({}, life["demote_failures"])])
        metric("icheck_watermark_crossings_total", "counter",
               "High-watermark crossings that triggered demotion",
               [({}, life["watermark_crossings_high"])])
        metric("icheck_ckpts_in_l3_total", "counter",
               "Checkpoints trickled into the remote object store",
               [({}, life["ckpts_in_l3"])])
        metric("icheck_l3_upload_failures_total", "counter",
               "L2->L3 trickles that exhausted their retries",
               [({}, life["l3_upload_failures"])])
        metric("icheck_ckpts_expired_total", "counter",
               "Checkpoint copies dropped by retention/GC",
               [({}, life["ckpts_expired"])])
        ec = snap["ec"]
        metric("icheck_ec_stripes_committed_total", "counter",
               "Erasure stripes committed to L1 (k data + m parity each)",
               [({}, ec["stripes_committed"])])
        metric("icheck_ec_bytes_total", "counter",
               "Erasure-coded bytes: logical payload vs k+m fragments",
               [({"kind": "logical"}, ec["logical_bytes"]),
                ({"kind": "fragment"}, ec["fragment_bytes"])])
        metric("icheck_ec_rebuilds_total", "counter",
               "Peer stripe rebuilds after failures, by outcome",
               [({"outcome": "started"}, ec["rebuilds_started"]),
                ({"outcome": "done"}, ec["rebuilds_done"]),
                ({"outcome": "failed"}, ec["rebuilds_failed"]),
                ({"outcome": "degraded"}, ec["rebuilds_degraded"])])
        metric("icheck_ec_rebuild_bytes_total", "counter",
               "Payload bytes regenerated by stripe rebuilds",
               [({}, ec["rebuild_bytes"])])
        metric("icheck_ec_degraded_reads_total", "counter",
               "Shard fetches that GF-decoded via parity fragments",
               [({}, ec["degraded_reads"])])
        metric("icheck_monitor_errors_total", "counter",
               "Health-monitor poll loops that raised (see flight dumps)",
               [({}, ec["monitor_errors"])])
        l3 = snap.get("l3")
        if l3 is not None:
            metric("icheck_l3_cost_usd", "gauge",
                   "Accumulated object-store bill (requests + bytes)",
                   [({}, l3["total_usd"])])
            metric("icheck_l3_bytes_total", "counter",
                   "Bytes moved to/from the object store",
                   [({"direction": "in"}, l3["bytes_in"]),
                    ({"direction": "out"}, l3["bytes_out"])])
            metric("icheck_l3_requests_total", "counter",
                   "Object-store requests issued",
                   [({"op": "put"}, l3["put_requests"]),
                    ({"op": "get"}, l3["get_requests"])])
        # latency/size distributions: fixed log2 buckets (stable ``le``
        # labels), p50/p95/p99 derivable by any scraper
        with self._lock:
            app_hists = {a: t for a, t in self._apps.items()}
            hop_lat, hop_bytes = self._hop_latency_hist, self._hop_bytes_hist
            ec_rebuild_hist = self._ec_rebuild_hist
        histogram("icheck_commit_seconds",
                  "Commit latency distribution (sim seconds)",
                  [({"app": a}, t.commit_latency_hist)
                   for a, t in app_hists.items()])
        histogram("icheck_commit_size_bytes",
                  "Committed checkpoint size distribution",
                  [({"app": a}, t.commit_bytes_hist)
                   for a, t in app_hists.items()])
        histogram("icheck_drain_seconds",
                  "L1->L2 drain duration distribution (sim seconds)",
                  [({"app": a}, t.drain_hist)
                   for a, t in app_hists.items()])
        histogram("icheck_restore_seconds",
                  "Restore duration distribution (sim seconds)",
                  [({"app": a}, t.restore_hist)
                   for a, t in app_hists.items()])
        histogram("icheck_stall_seconds",
                  "Zero-stall cutover stall distribution (sim seconds)",
                  [({"app": a}, t.stall_hist)
                   for a, t in app_hists.items()])
        histogram("icheck_peer_hop_seconds",
                  "Per-transfer NIC/MemBus hop duration (sim seconds)",
                  [({}, hop_lat)])
        histogram("icheck_peer_hop_bytes",
                  "Per-transfer NIC/MemBus hop size",
                  [({}, hop_bytes)])
        histogram("icheck_ec_rebuild_seconds",
                  "Stripe rebuild duration distribution (sim seconds)",
                  [({}, ec_rebuild_hist)])
        return "\n".join(out) + "\n"
