"""Storage lifecycle: watermark demotion, L2→L3 trickle, retention/GC.

The paper treats checkpoint storage as a *managed* resource: the controller
escalates to the RM when iCheck memory runs out (§III-A interaction 1) and
orchestrates PFS writes to bound interference (§II).  This service closes
the remaining gap — today the system reacts to a ``CapacityError`` *after* a
commit already hit a full node, and a checkpoint's life ends at the PFS.
Three policies, all driven off the telemetry the event bus already carries:

  * **Watermark demotion** — when a node's L1 occupancy crosses
    ``watermark_high``, cold shards (oldest checkpoints first, durable
    before draining) are demoted into the node's lower tier until occupancy
    falls under ``watermark_low`` (classic hysteresis so a single hot
    commit doesn't cause demotion ping-pong).  Commits then keep landing in
    RAM instead of raising ``CapacityError`` and forcing an RM escalation.

  * **Async L2→L3 trickle** — every checkpoint that becomes durable on the
    PFS is queued for background promotion into the
    :class:`~repro.core.tiers.RemoteObjectTier`, through the
    DrainOrchestrator's low-priority background lane so the trickle never
    contends with live L1→L2 drains.  ``CKPT_IN_L3`` announces durability
    in the object store.

  * **Retention / GC** — keep-last-K per tier per application: once a
    checkpoint is safe in L3, its PFS copy beyond ``keep_l2`` is dropped;
    L3 itself keeps ``keep_l3`` objects.  Pinned checkpoints
    (:meth:`pin`) are exempt everywhere.  Every removal publishes
    ``CKPT_EXPIRED`` with the tier it left; expiry from L3 is terminal.
"""
from __future__ import annotations

import threading
from typing import List, Set, Tuple

from ...obs import trace_id_for
from .. import events as E
from ..tiers import ec_is_parity
from ..types import AppId, CkptId, CkptStatus, ICheckError, ShardKey

# statuses whose shards may be demoted out of L1 (durable copies exist, or
# at worst the checkpoint is restartable from its lower-tier copy); an
# in-flight PENDING commit or an actively DRAINING checkpoint is hot
_DEMOTABLE = (CkptStatus.IN_L1, CkptStatus.IN_L2, CkptStatus.IN_L3)
_DURABLE = (CkptStatus.IN_L2, CkptStatus.IN_L3)


class StorageLifecycleService:
    def __init__(self, ctl, l3=None, *, watermark_high: float = 0.85,
                 watermark_low: float = 0.60, keep_l2: int = 0,
                 keep_l3: int = 0, trickle_to_l3: bool = True):
        if not (0.0 < watermark_low <= watermark_high <= 1.0):
            raise ICheckError(
                f"watermarks must satisfy 0 < low <= high <= 1, got "
                f"low={watermark_low} high={watermark_high}")
        self.ctl = ctl
        self.l3 = l3
        self.watermark_high = float(watermark_high)
        self.watermark_low = float(watermark_low)
        self.keep_l2 = max(0, int(keep_l2))      # 0 = unlimited
        self.keep_l3 = max(0, int(keep_l3))      # 0 = unlimited
        self.trickle_to_l3 = bool(trickle_to_l3) and l3 is not None
        self._lock = threading.Lock()
        self._uploading: Set[Tuple[AppId, CkptId]] = set()
        self._unsubscribe = ctl.bus.subscribe(
            self._on_event,
            events=(E.COMMIT_DONE, E.CKPT_IN_L1, E.CKPT_IN_L2,
                    E.SHARD_SPILLED))

    def close(self) -> None:
        self._unsubscribe()

    # -------------------------------------------------------------- pinning
    def pin(self, app_id: AppId, ckpt_id: CkptId, pinned: bool = True) -> bool:
        """Exempt (or re-expose) one checkpoint from retention on all tiers."""
        with self.ctl._lock:
            app = self.ctl._apps.get(app_id)
            meta = app.checkpoints.get(ckpt_id) if app else None
            if meta is None:
                return False
            self.ctl.catalog._journal("pin", app=app_id, ckpt=ckpt_id,
                                      pinned=bool(pinned))
            meta.pinned = bool(pinned)
            return True

    def reset_inflight(self) -> None:
        """Forget in-flight upload dedup state (controller recovery): the
        old closures are epoch-fenced in the background lane, so recovered
        IN_L2 checkpoints must be free to reschedule their trickle."""
        with self._lock:
            self._uploading.clear()

    # ---------------------------------------------------------- bus wiring
    def _on_event(self, ev: E.Event) -> None:
        if ev.name in (E.COMMIT_DONE, E.CKPT_IN_L1, E.SHARD_SPILLED):
            self.run_watermarks()
        elif ev.name == E.CKPT_IN_L2:
            app_id = ev.payload["app"]
            if self.trickle_to_l3:
                self.schedule_upload(app_id, ev.payload["ckpt"])
            self.run_retention(app_id)
            self.run_watermarks()

    # ------------------------------------------------- watermark demotion
    def run_watermarks(self) -> int:
        """Demote cold L1 shards on every node above the high watermark.

        Returns the number of shards demoted.  Hysteresis: a node is only
        touched above ``watermark_high`` and is drained down to
        ``watermark_low``, so occupancy oscillating between the two marks
        causes no churn.
        """
        demoted_total = 0
        for mgr in self.ctl.managers():
            if not mgr.alive():
                continue
            pipe = mgr.store
            if len(pipe.tiers) < 2:
                continue        # nowhere to demote to on this node
            top = pipe.tiers[0]
            cap = float(top.capacity)
            if not cap or cap != cap or cap == float("inf"):
                continue
            occupancy = top.used_bytes / cap
            if occupancy <= self.watermark_high:
                continue
            self.ctl.bus.publish(
                E.WATERMARK_CROSSED, node=mgr.node_id, tier=top.name,
                direction="high", occupancy=occupancy,
                watermark=self.watermark_high)
            target = self.watermark_low * cap
            demoted = 0
            for key in self._cold_first(top.keys()):
                if top.used_bytes <= target:
                    break
                if pipe.demote(key):
                    demoted += 1
                else:
                    # most likely the lower tier is full: retrying the
                    # remaining K cold keys would copy each payload out of
                    # L1 just to fail the same way — stop this pass (the
                    # next commit-class event retries the whole check)
                    break
            demoted_total += demoted
            occupancy = top.used_bytes / cap
            if occupancy <= self.watermark_low:
                self.ctl.bus.publish(
                    E.WATERMARK_CROSSED, node=mgr.node_id, tier=top.name,
                    direction="low", occupancy=occupancy,
                    watermark=self.watermark_low, demoted=demoted)
        return demoted_total

    def _cold_first(self, keys: List[ShardKey]) -> List[ShardKey]:
        """Demotion order: erasure *parity* fragments first (pure redundancy
        — the stripe stays reconstructable from its k data fragments, and a
        demoted parity is still fetchable from the lower tier), then durable
        checkpoints before merely-L1 ones, oldest checkpoint first within
        each class; hot (in-flight) shards never."""
        statuses = {}
        with self.ctl._lock:
            for key in keys:
                app = self.ctl._apps.get(key.app_id)
                meta = app.checkpoints.get(key.ckpt_id) if app else None
                statuses[(key.app_id, key.ckpt_id)] = \
                    meta.status if meta else CkptStatus.IN_L2

        def eligible(key: ShardKey) -> bool:
            return statuses[(key.app_id, key.ckpt_id)] in _DEMOTABLE

        def coldness(key: ShardKey):
            durable = statuses[(key.app_id, key.ckpt_id)] in _DURABLE
            return (0 if ec_is_parity(key.replica) else 1,
                    0 if durable else 1, key.ckpt_id, key.region, key.part)

        return sorted((k for k in keys if eligible(k)), key=coldness)

    # --------------------------------------------------- L2 -> L3 trickle
    MAX_UPLOAD_ATTEMPTS = 3

    def schedule_upload(self, app_id: AppId, ckpt_id: CkptId,
                        attempt: int = 0, trace=None) -> None:
        with self._lock:
            if (app_id, ckpt_id) in self._uploading:
                return
            self._uploading.add((app_id, ckpt_id))
        # the CKPT_IN_L2 handler runs on the drain worker, whose current
        # context is the l2_drain span: capture it into the background-lane
        # closure so the trickle re-joins the checkpoint's trace tree
        tracer = getattr(self.ctl, "tracer", None)
        if trace is None and tracer is not None:
            trace = tracer.current()
        self.ctl.drains.submit_background(
            lambda: self._upload_to_l3(app_id, ckpt_id, attempt, trace))

    def wait_uploads(self, timeout: float = 30.0) -> None:
        """Testing/benchmark helper: block until the trickle lane settles."""
        self.ctl.drains.wait_background(timeout)

    def _upload_to_l3(self, app_id: AppId, ckpt_id: CkptId,
                      attempt: int = 0, trace=None) -> None:
        tracer = getattr(self.ctl, "tracer", None)
        if tracer is not None and tracer.enabled:
            with tracer.use(trace), tracer.span(
                    "l3_trickle", trace_id_for(app_id, ckpt_id),
                    "lifecycle/trickle", attempt=attempt):
                self._upload_attempt(app_id, ckpt_id, attempt, trace)
        else:
            self._upload_attempt(app_id, ckpt_id, attempt, trace)

    def _upload_attempt(self, app_id: AppId, ckpt_id: CkptId,
                        attempt: int = 0, trace=None) -> None:
        try:
            self._upload_to_l3_once(app_id, ckpt_id)
        except Exception as e:  # noqa: BLE001 - must not kill the worker
            with self._lock:
                self._uploading.discard((app_id, ckpt_id))
            if attempt + 1 < self.MAX_UPLOAD_ATTEMPTS:
                # transient (an I/O hiccup, a shard raced a drop): requeue
                # behind whatever live drains arrived meanwhile
                self.schedule_upload(app_id, ckpt_id, attempt + 1,
                                     trace=trace)
            else:
                # terminal: the checkpoint stays IN_L2 (still PFS-durable,
                # and keep_l2 retention never trims a non-L3 checkpoint) —
                # but say so instead of leaving only a drain-stats counter
                self.ctl.bus.publish(E.L3_UPLOAD_FAILED, app=app_id,
                                     ckpt=ckpt_id, attempts=attempt + 1,
                                     error=repr(e))
        else:
            with self._lock:
                self._uploading.discard((app_id, ckpt_id))

    def _upload_to_l3_once(self, app_id: AppId, ckpt_id: CkptId) -> None:
        ctl = self.ctl
        l3 = self.l3
        with ctl._lock:
            app = ctl._apps.get(app_id)
            meta = app.checkpoints.get(ckpt_id) if app else None
        if l3 is None or meta is None or meta.status != CkptStatus.IN_L2:
            return
        t0 = ctl.clock.now()
        total = 0
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                key = ShardKey(app_id, ckpt_id, name, part)
                if l3.has_shard(key):
                    continue
                payload = ctl.pfs.read_shard(key)
                l3.write_shard(key, payload)
                total += len(payload)
        if not l3.checkpoint_complete(meta):
            return              # raced a concurrent drop; stay IN_L2
        ctl.catalog.set_status(meta, CkptStatus.IN_L3)
        l3.write_manifest(meta)
        ctl.bus.publish(E.CKPT_IN_L3, app=app_id, ckpt=ckpt_id, bytes=total,
                        sim_s=max(ctl.clock.now() - t0, 0.0),
                        cost_usd=l3.cost_usd())
        self.run_retention(app_id)

    # ------------------------------------------------------ retention / GC
    def run_retention(self, app_id: AppId) -> None:
        """Keep-last-K per tier: trim PFS copies already safe in L3 beyond
        ``keep_l2``; expire L3 objects beyond ``keep_l3`` (terminal)."""
        ctl = self.ctl
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None:
                return
            metas = sorted(app.checkpoints.values(), key=lambda m: m.ckpt_id)
        if self.keep_l2 > 0:
            # a PFS copy is only droppable once the checkpoint is durable
            # one level further down; the newest keep_l2 durable copies are
            # protected regardless
            durable = [m for m in metas if m.status in _DURABLE]
            protected = {m.ckpt_id for m in durable[-self.keep_l2:]}
            for meta in metas:
                if meta.status != CkptStatus.IN_L3 or meta.pinned \
                        or meta.ckpt_id in protected:
                    continue
                freed = ctl.pfs.drop_checkpoint(app_id, meta.ckpt_id)
                if freed:
                    ctl.bus.publish(E.CKPT_EXPIRED, app=app_id,
                                    ckpt=meta.ckpt_id, tier=ctl.pfs.name,
                                    freed_bytes=freed, terminal=False)
        if self.l3 is not None and self.keep_l3 > 0:
            in_l3 = [m for m in metas
                     if m.status == CkptStatus.IN_L3 and not m.pinned]
            victims = in_l3[:-self.keep_l3]
            victim_ids = {m.ckpt_id for m in victims}
            # q8-delta: a frame referenced by a *surviving* checkpoint's
            # replay chain must outlive it — expiring the keyframe under a
            # retained delta would make that checkpoint unrestorable
            chain_needed = set()
            for m in metas:
                if m.ckpt_id in victim_ids or m.status in (CkptStatus.EXPIRED,
                                                           CkptStatus.FAILED):
                    continue
                for r in m.regions.values():
                    if r.chain:
                        chain_needed.update(r.chain)
            for meta in victims:
                if meta.ckpt_id in chain_needed:
                    continue
                freed = self.l3.drop_checkpoint(app_id, meta.ckpt_id)
                # the L3 copy was the durability floor: scrub the faster
                # tiers too so no unrestorable partial copies linger
                ctl.pfs.drop_checkpoint(app_id, meta.ckpt_id)
                for mgr in ctl.managers():
                    mgr.store.drop_checkpoint(app_id, meta.ckpt_id)
                ctl.catalog.set_status(meta, CkptStatus.EXPIRED)
                ctl.bus.publish(E.CKPT_EXPIRED, app=app_id,
                                ckpt=meta.ckpt_id, tier=self.l3.name,
                                freed_bytes=freed, terminal=True)

    # -------------------------------------------------------------- export
    def stats(self) -> dict:
        with self._lock:
            uploading = len(self._uploading)
        return {
            "watermark_high": self.watermark_high,
            "watermark_low": self.watermark_low,
            "keep_l2": self.keep_l2,
            "keep_l3": self.keep_l3,
            "trickle_to_l3": self.trickle_to_l3,
            "uploads_in_flight": uploading,
        }
