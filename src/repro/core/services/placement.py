"""Policy-driven agent placement + agent-count adaptivity.

Implements paper §II steps 2-6 (node/agent selection via the scheduling
policies) and the ``icheck_probe_agents`` adaptivity loop ("iCheck can
dynamically change the agent count to obtain an optimum checkpoint transfer
rate"), plus capacity-pressure escalation to the RM (§III-A interaction 1).
"""
from __future__ import annotations

from typing import List, Optional

from .. import events as E
from ..agent import Agent
from ..policies import SchedulingPolicy, get_policy
from ..types import AppId, AppRecord


class PlacementService:
    def __init__(self, ctl, policy: "str | SchedulingPolicy" = "adaptive"):
        self.ctl = ctl
        self.policy = get_policy(policy) if isinstance(policy, str) else policy

    # -------------------------------------------------------------- placement
    def place_app(self, app: AppRecord) -> List[Agent]:
        placement = self.policy.place(self.ctl.node_views(), app)
        agents: List[Agent] = []
        for node_id, count in placement:
            mgr = self.ctl._managers[node_id]
            for _ in range(count):
                agents.append(mgr.launch_agent(app.app_id))
        return agents

    def ensure_memory(self, app: AppRecord) -> None:
        ctl = self.ctl
        need = app.ckpt_bytes_estimate * app.replication * max(1, ctl.keep_l1)
        guard = 0
        while ctl.total_free_memory() < need and guard < 16:
            if not ctl.request_more_memory():
                break
            guard += 1

    def handle_capacity_pressure(self, app_id: AppId) -> List[Agent]:
        """A commit hit a full node (paper §III-A: "when iCheck runs out of
        memory in a node, the controller can request more memory and get
        additional nodes from RM").  Grow by one node if the RM has any;
        either way, give the app an agent on the freest node it doesn't
        already use, and return the refreshed agent set."""
        ctl = self.ctl
        ctl.request_more_memory()
        with ctl._lock:
            have = set(ctl._apps[app_id].agents)
        used_nodes = {aid.split("/")[0] for aid in have}
        views = sorted(ctl.node_views(), key=lambda nv: -nv.free_memory)
        for prefer_new in (True, False):
            for nv in views:
                if prefer_new and nv.node_id in used_nodes:
                    continue
                mgr = ctl._managers[nv.node_id]
                if len(mgr.agents()) < mgr.spec.max_agents:
                    agent = mgr.launch_agent(app_id)
                    with ctl._lock:
                        ctl._apps[app_id].agents.append(agent.agent_id)
                    ctl.bus.publish(E.CAPACITY_GROW, app=app_id,
                                    node=nv.node_id, agent=agent.agent_id)
                    return ctl.agents_for(app_id)
        return ctl.agents_for(app_id)

    # ------------------------------------------------------------ adaptivity
    def probe(self, app_id: AppId,
              last_commit_sim_s: Optional[float] = None) -> List[Agent]:
        """``icheck_probe_agents``: re-tune the agent count for transfer rate.

        Heuristic: a commit should take at most ``target_frac`` of the
        checkpoint interval.  Too slow → add an agent on the least-loaded
        node (requesting a new node from the RM if saturated).  More than 2×
        over-provisioned → drop an agent, freeing resources for other apps.
        """
        ctl = self.ctl
        target_frac = 0.25
        with ctl._lock:
            app = ctl._apps[app_id]
        agents = ctl.agents_for(app_id)
        if last_commit_sim_s is None or app.ckpt_interval_s <= 0 or not agents:
            return agents
        budget = app.ckpt_interval_s * target_frac
        if last_commit_sim_s > budget:
            added = self._scale_up(app, agents)
            if added:
                ctl.bus.publish(E.AGENTS_SCALED_UP, app=app_id,
                                n=len(ctl.agents_for(app_id)))
        elif last_commit_sim_s < budget / 4 and len(agents) > 1:
            victim = agents[-1]
            mgr = ctl._managers[victim.node_id]
            mgr.stop_agent(victim.agent_id)
            with ctl._lock:
                app.agents.remove(victim.agent_id)
            ctl.bus.publish(E.AGENTS_SCALED_DOWN, app=app_id,
                            n=len(ctl.agents_for(app_id)))
        return ctl.agents_for(app_id)

    def _scale_up(self, app: AppRecord, agents: List[Agent]) -> bool:
        ctl = self.ctl
        # prefer a node not yet serving this app (fresh NIC)
        used_nodes = {a.node_id for a in agents}
        candidates = [nv for nv in ctl.node_views()
                      if nv.n_agents < nv.max_agents]
        fresh = [nv for nv in candidates if nv.node_id not in used_nodes]
        if not fresh and not ctl.request_more_memory():
            fresh = candidates     # fall back to sharing a NIC
        else:
            fresh = fresh or [nv for nv in ctl.node_views()
                              if nv.node_id not in used_nodes]
        if not fresh:
            return False
        nv = sorted(fresh, key=lambda v: (v.bw_load, v.n_agents))[0]
        agent = ctl._managers[nv.node_id].launch_agent(app.app_id)
        with ctl._lock:
            app.agents.append(agent.agent_id)
        return True
