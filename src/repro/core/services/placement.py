"""Policy-driven agent placement + agent-count adaptivity.

Implements paper §II steps 2-6 (node/agent selection via the scheduling
policies) and the ``icheck_probe_agents`` adaptivity loop ("iCheck can
dynamically change the agent count to obtain an optimum checkpoint transfer
rate"), plus capacity-pressure escalation to the RM (§III-A interaction 1).
"""
from __future__ import annotations

from typing import List, Optional

from .. import events as E
from ..agent import Agent
from ..policies import SchedulingPolicy, get_policy
from ..types import AppId, AppRecord


class PlacementService:
    def __init__(self, ctl, policy: "str | SchedulingPolicy" = "adaptive"):
        self.ctl = ctl
        self.policy = get_policy(policy) if isinstance(policy, str) else policy

    # -------------------------------------------------------------- placement
    def place_app(self, app: AppRecord) -> List[Agent]:
        placement = self.policy.place(self.ctl.node_views(), app)
        agents: List[Agent] = []
        for node_id, count in placement:
            mgr = self.ctl._managers[node_id]
            for _ in range(count):
                agents.append(mgr.launch_agent(app.app_id))
        return agents

    def ensure_memory(self, app: AppRecord) -> None:
        ctl = self.ctl
        # (k+m)/k under erasure coding, the replication factor otherwise
        need = int(app.ckpt_bytes_estimate * app.l1_overhead_factor()
                   * max(1, ctl.keep_l1))
        guard = 0
        while ctl.total_free_memory() < need and guard < 16:
            if not ctl.request_more_memory():
                break
            guard += 1

    # ---------------------------------------------- failure-domain spreading
    def ensure_failure_domains(self, app: AppRecord,
                               domains: int) -> List[Agent]:
        """Erasure-coded stripes only survive node loss when the app's
        agents span enough *nodes* — a stripe scattered over k+m agents on
        one node dies with that node.  Launch one agent on additional live
        nodes (freest first) until the app spans ``min(domains, #live
        nodes)`` distinct failure domains."""
        ctl = self.ctl
        used = {a.node_id for a in ctl.agents_for(app.app_id)}
        guard = 0
        while len(used) < domains and guard < 16:
            guard += 1
            spare = sorted((m for m in ctl.managers()
                            if m.alive() and m.node_id not in used
                            and len(m.agents()) < m.spec.max_agents),
                           key=lambda m: m.store.used_bytes)
            if not spare:
                if not ctl.request_more_memory():
                    break               # RM has nothing left: best effort
                continue
            mgr = spare[0]
            agent = mgr.launch_agent(app.app_id)
            with ctl._lock:
                app.agents.append(agent.agent_id)
            used.add(mgr.node_id)
        return ctl.agents_for(app.app_id)

    def stripe_agents(self, app_id: AppId, n: int,
                      rotation: int = 0) -> List[Agent]:
        """``n`` agents for one stripe (or replica set) with failure-domain
        anti-affinity: interleave across nodes so the first ``n`` picks land
        on ``min(n, #nodes)`` distinct nodes — losing any one node costs at
        most ``ceil(n / #nodes)`` fragments.  ``rotation`` rotates the node
        order so consecutive stripes don't all start on the same node."""
        agents = self.ctl.agents_for(app_id)
        if not agents:
            return []
        by_node = {}
        for a in agents:
            by_node.setdefault(a.node_id, []).append(a)
        nodes = sorted(by_node)
        r = rotation % len(nodes)
        nodes = nodes[r:] + nodes[:r]
        order: List[Agent] = []
        depth = 0
        while len(order) < len(agents):
            for node in nodes:
                lane = by_node[node]
                if depth < len(lane):
                    order.append(lane[depth])
            depth += 1
        return [order[i % len(order)] for i in range(n)]

    def recovery_destination(self, base_key, exclude_nodes=()):
        """Where a recovered copy of ``base_key`` should land: the freest
        *live* node that does not already hold any replica or fragment of
        the same logical shard (re-copying onto a node that already has one
        silently voids durability).  Falls back to the freest live node
        when every survivor already holds a copy."""
        ctl = self.ctl
        base = base_key.base()
        holders = set(exclude_nodes)
        live = [m for m in ctl.managers() if m.alive()]
        for mgr in live:
            if any(k.base() == base for k in mgr.store.keys()):
                holders.add(mgr.node_id)
        clean = [m for m in live if m.node_id not in holders]
        pool = clean or live
        if not pool:
            return None
        return min(pool, key=lambda m: m.store.used_bytes)

    def handle_capacity_pressure(self, app_id: AppId) -> List[Agent]:
        """A commit hit a full node (paper §III-A: "when iCheck runs out of
        memory in a node, the controller can request more memory and get
        additional nodes from RM").  Grow by one node if the RM has any;
        either way, give the app an agent on the freest node it doesn't
        already use, and return the refreshed agent set."""
        ctl = self.ctl
        ctl.request_more_memory()
        with ctl._lock:
            have = set(ctl._apps[app_id].agents)
        used_nodes = {aid.split("/")[0] for aid in have}
        views = sorted(ctl.node_views(), key=lambda nv: -nv.free_memory)
        for prefer_new in (True, False):
            for nv in views:
                if prefer_new and nv.node_id in used_nodes:
                    continue
                mgr = ctl._managers[nv.node_id]
                if len(mgr.agents()) < mgr.spec.max_agents:
                    agent = mgr.launch_agent(app_id)
                    with ctl._lock:
                        ctl._apps[app_id].agents.append(agent.agent_id)
                    ctl.bus.publish(E.CAPACITY_GROW, app=app_id,
                                    node=nv.node_id, agent=agent.agent_id)
                    return ctl.agents_for(app_id)
        return ctl.agents_for(app_id)

    # ------------------------------------------------------------ adaptivity
    def probe(self, app_id: AppId,
              last_commit_sim_s: Optional[float] = None) -> List[Agent]:
        """``icheck_probe_agents``: re-tune the agent count for transfer rate.

        Heuristic: a commit should take at most ``target_frac`` of the
        checkpoint interval.  Too slow → add an agent on the least-loaded
        node (requesting a new node from the RM if saturated).  More than 2×
        over-provisioned → drop an agent, freeing resources for other apps.
        """
        ctl = self.ctl
        target_frac = 0.25
        with ctl._lock:
            app = ctl._apps[app_id]
        agents = ctl.agents_for(app_id)
        if last_commit_sim_s is None or app.ckpt_interval_s <= 0 or not agents:
            return agents
        budget = app.ckpt_interval_s * target_frac
        if last_commit_sim_s > budget:
            added = self._scale_up(app, agents)
            if added:
                ctl.bus.publish(E.AGENTS_SCALED_UP, app=app_id,
                                n=len(ctl.agents_for(app_id)))
        elif last_commit_sim_s < budget / 4 and len(agents) > 1:
            victim = agents[-1]
            mgr = ctl._managers[victim.node_id]
            mgr.stop_agent(victim.agent_id)
            with ctl._lock:
                app.agents.remove(victim.agent_id)
            ctl.bus.publish(E.AGENTS_SCALED_DOWN, app=app_id,
                            n=len(ctl.agents_for(app_id)))
        return ctl.agents_for(app_id)

    def _scale_up(self, app: AppRecord, agents: List[Agent]) -> bool:
        ctl = self.ctl
        # prefer a node not yet serving this app (fresh NIC)
        used_nodes = {a.node_id for a in agents}
        candidates = [nv for nv in ctl.node_views()
                      if nv.n_agents < nv.max_agents]
        fresh = [nv for nv in candidates if nv.node_id not in used_nodes]
        if not fresh and not ctl.request_more_memory():
            fresh = candidates     # fall back to sharing a NIC
        else:
            fresh = fresh or [nv for nv in ctl.node_views()
                              if nv.node_id not in used_nodes]
        if not fresh:
            return False
        nv = sorted(fresh, key=lambda v: (v.bw_load, v.n_agents))[0]
        agent = ctl._managers[nv.node_id].launch_agent(app.app_id)
        with ctl._lock:
            app.agents.append(agent.agent_id)
        return True
