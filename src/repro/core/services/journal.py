"""Write-ahead metadata journal + epoch fencing — the crash-consistent
control plane.

The catalog's in-memory state (checkpoint lifecycle, delta chains, holds,
pins, EC stripe placement) is the one thing a controller crash used to
destroy: every durable byte in L1/L2/L3 was orphaned except what the slow
cold-L3 manifest scan could rediscover.  :class:`MetadataJournal` fixes
that with the classic WAL discipline:

  * every catalog mutation is appended as a **length-prefixed, CRC-framed
    record** to a PFS-backed journal segment *before* the in-memory state
    changes (``IJL1 | u32 len | u32 crc32(body) | JSON body``);
  * periodic **compacted snapshots** (the full serialized state doc,
    written atomically, then the WAL truncated) keep replay O(live state)
    rather than O(history);
  * replay stops cleanly at a truncated or CRC-corrupt tail record — the
    torn final write of a crashing controller loses at most the mutation
    that was never acked;
  * record application is **idempotent** (set/overwrite semantics keyed by
    ids), so double replay of a tail is harmless.

:class:`EpochFence` is the companion zombie-guard: recovery bumps the
controller epoch, every agent inbox op / drain queue entry / RM interaction
is stamped with the epoch current at submit time, and validators raise
:class:`StaleEpochError` for anything stamped before the recovery.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Dict, List, Optional, Tuple
from zlib import crc32

from ..types import (CheckpointMeta, CkptStatus, ICheckError, ShardInfo,
                     ShardKey)

JOURNAL_MAGIC = b"IJL1"
_HEADER = len(JOURNAL_MAGIC) + 4 + 4        # magic + body len + body crc

# Record kinds that may sit in the process write buffer until the next
# barrier record: losing one to a crash never changes journaled *truth*.
# Shard and status records are rediscovered by the recovery reconciliation
# pass (it probes the live tiers and settles each checkpoint where its
# bytes actually are); tier moves and EC stripe placements are audit-only.
# Every other kind — new_ckpt (the identity record that defines per-app
# truth), app/region, pins, chain ops, epoch — is a durability barrier and
# flushes the whole buffered run ahead of it, preserving order.
_LAZY_KINDS = frozenset({"shard", "status", "tier_move", "ec_stripe"})


class StaleEpochError(ICheckError):
    """An op stamped with a pre-recovery controller epoch was refused."""


class EpochFence:
    """Monotonic controller epoch, bumped on every warm recovery.

    ``current`` is stamped on outbound work at submit time; ``check``
    refuses anything stamped with an older epoch.  ``None`` epochs pass —
    unstamped ops come from actors that never route through a recoverable
    controller (direct test harness calls)."""

    def __init__(self, epoch: int = 0):
        self._lock = threading.Lock()
        self._epoch = int(epoch)

    @property
    def current(self) -> int:
        with self._lock:
            return self._epoch

    def bump(self, at_least: Optional[int] = None) -> int:
        """Advance the epoch (to ``at_least`` when that is newer)."""
        with self._lock:
            self._epoch += 1
            if at_least is not None:
                self._epoch = max(self._epoch, int(at_least))
            return self._epoch

    def check(self, epoch: Optional[int], what: str = "op") -> None:
        if epoch is None:
            return
        cur = self.current
        if int(epoch) != cur:
            raise StaleEpochError(
                f"stale-epoch {what}: stamped {epoch}, fence at {cur}")


# --------------------------------------------------------------------------
# serialization helpers (shared with Controller.recover)
# --------------------------------------------------------------------------
def _region_docs(regions: dict) -> dict:
    from ..tiers import region_doc
    return {name: region_doc(r) for name, r in regions.items()}


def meta_from_ckpt_doc(app_id: str, doc: dict) -> CheckpointMeta:
    """Rebuild a CheckpointMeta (regions + shard index) from a journaled
    checkpoint doc."""
    from ..tiers import region_from_doc
    meta = CheckpointMeta(
        app_id=app_id, ckpt_id=int(doc["ckpt"]), step=int(doc["step"]),
        status=CkptStatus(doc.get("status", "pending")),
        userdata=bytes.fromhex(doc.get("userdata_hex", "")),
        pinned=bool(doc.get("pinned", False)))
    for name, r in doc.get("regions", {}).items():
        meta.regions[name] = region_from_doc(name, r)
    for s in doc.get("shards", {}).values():
        key = ShardKey(*s["key"][:3], int(s["key"][3]), int(s["key"][4]))
        meta.shards[key] = ShardInfo(key=key, nbytes=int(s["nbytes"]),
                                     crc32=int(s["crc"]),
                                     agent_id=s.get("agent"))
    return meta


@dataclasses.dataclass
class RecoveredState:
    """What replay (snapshot + tail) yields: the journal's view of truth."""

    epoch: int = 0
    # app_id -> {"ranks", "replication", "ec", "interval_s",
    #            "bytes_estimate", "next_ckpt", "regions", "ckpts"}
    apps: Dict[str, dict] = dataclasses.field(default_factory=dict)
    # (app, region) -> chain frame ids open at crash time
    open_chains: Dict[Tuple[str, str], tuple] = \
        dataclasses.field(default_factory=dict)
    # (app, region) -> hold refcount open at crash time (overlap windows)
    holds: Dict[Tuple[str, str], int] = \
        dataclasses.field(default_factory=dict)
    stats: dict = dataclasses.field(default_factory=dict)

    def truth(self) -> Dict[str, int]:
        """Per-app max journaled checkpoint id (-1 when none)."""
        out = {}
        for app_id, app in self.apps.items():
            cids = [int(c) for c in app.get("ckpts", {})]
            out[app_id] = max(cids) if cids else -1
        return out


def _blank_app() -> dict:
    return {"ranks": 0, "replication": 1, "ec": None, "interval_s": 60.0,
            "bytes_estimate": 0, "next_ckpt": 0, "regions": {}, "ckpts": {}}


def apply_record(state: dict, rec: dict) -> None:
    """Apply one journal record to a state doc (idempotent)."""
    kind = rec.get("kind")
    apps = state.setdefault("apps", {})
    if kind == "epoch":
        state["epoch"] = max(int(state.get("epoch", 0)), int(rec["epoch"]))
        return
    if kind in ("tier_move", "ec_stripe"):
        # placement/audit records: probed live at reconcile time, nothing
        # to fold into the replayed catalog state
        return
    app_id = rec.get("app")
    if app_id is None:
        return
    app = apps.setdefault(app_id, _blank_app())
    if kind == "open_app":
        return
    if kind == "app":
        app.update(ranks=int(rec["ranks"]),
                   replication=int(rec.get("replication", 1)),
                   ec=rec.get("ec"),
                   interval_s=float(rec.get("interval_s", 60.0)),
                   bytes_estimate=int(rec.get("bytes_estimate", 0)))
        return
    if kind == "region":
        app["regions"][rec["name"]] = rec["doc"]
        return
    if kind == "new_ckpt":
        cid = int(rec["ckpt"])
        app["ckpts"][str(cid)] = {
            "ckpt": cid, "step": int(rec["step"]), "status": "pending",
            "userdata_hex": rec.get("userdata_hex", ""),
            "regions": rec.get("regions", {}), "shards": {}}
        app["next_ckpt"] = max(int(app["next_ckpt"]), cid + 1)
        return
    if kind == "shard":
        ck = app["ckpts"].get(str(int(rec["ckpt"])))
        if ck is not None:
            k = rec["key"]
            ck["shards"][f"{k[2]}/{k[3]}/{k[4]}"] = {
                "key": k, "nbytes": int(rec["nbytes"]),
                "crc": int(rec["crc"]), "agent": rec.get("agent")}
        return
    if kind == "status":
        ck = app["ckpts"].get(str(int(rec["ckpt"])))
        if ck is not None:
            ck["status"] = rec["status"]
        return
    if kind == "pin":
        ck = app["ckpts"].get(str(int(rec["ckpt"])))
        if ck is not None:
            ck["pinned"] = bool(rec.get("pinned", True))
        return
    chains = state.setdefault("chains", {})
    holds = state.setdefault("holds", {})
    ckey = f"{app_id}\x00{rec.get('region', '')}"
    if kind == "chain_advance":
        chains[ckey] = list(rec["chain"])
    elif kind == "chain_reset":
        chains.pop(ckey, None)
    elif kind == "chain_hold":
        holds[ckey] = int(holds.get(ckey, 0)) + 1
    elif kind == "chain_release":
        n = int(holds.get(ckey, 0)) - 1
        if n <= 0:
            holds.pop(ckey, None)
        else:
            holds[ckey] = n
    # unknown kinds are ignored: a newer journal replayed by older code
    # loses nothing it understands


class MetadataJournal:
    """PFS-backed write-ahead journal for the checkpoint catalog.

    ``append`` frames one JSON record and flushes it to the WAL segment
    *before* the caller mutates in-memory state; ``write_snapshot``
    atomically publishes a compacted state doc and truncates the WAL.
    ``replay_state`` folds snapshot + surviving tail records into a
    :class:`RecoveredState`.

    The simulated append cost (``len(frame) / byte_rate`` on the shared
    clock) models a dedicated low-latency metadata log device — the WAL is
    tiny sequential writes, deliberately *not* routed through the PFS
    ingest NIC whose per-op latency would put ~0.1 ms on every catalog
    mutation."""

    def __init__(self, root: str, clock=None, byte_rate: float = 2e9,
                 fsync: bool = False, compact_every: int = 256):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.wal_path = os.path.join(root, "wal.bin")
        self.snap_path = os.path.join(root, "snapshot.json")
        self.clock = clock
        self.byte_rate = float(byte_rate)
        self.fsync = bool(fsync)
        self.compact_every = max(1, int(compact_every))
        self.enabled = True
        self._lock = threading.RLock()
        self.appends = 0
        self.appends_since_snapshot = 0
        self.snapshots = 0
        self.bytes_appended = 0
        self._truth: Dict[str, int] = {}
        # warm reopen: pick up truth from whatever is already on disk
        state, _ = self.read_state()
        for app_id, hi in RecoveredState(apps=state.get("apps", {})) \
                .truth().items():
            self._truth[app_id] = hi
        self._fh = open(self.wal_path, "ab")

    # ------------------------------------------------------------- writing
    def append(self, kind: str, **fields) -> None:
        """Frame + flush one record.  MUST be called before the in-memory
        mutation it describes becomes visible."""
        if not self.enabled:
            return
        rec = {"kind": kind, **fields}
        body = json.dumps(rec, separators=(",", ":")).encode()
        frame = JOURNAL_MAGIC + len(body).to_bytes(4, "little") \
            + crc32(body).to_bytes(4, "little") + body
        with self._lock:
            self._fh.write(frame)
            if kind not in _LAZY_KINDS:
                self._fh.flush()
                if self.fsync:
                    os.fsync(self._fh.fileno())
            self.appends += 1
            self.appends_since_snapshot += 1
            self.bytes_appended += len(frame)
            if kind == "new_ckpt":
                app_id = fields["app"]
                self._truth[app_id] = max(self._truth.get(app_id, -1),
                                          int(fields["ckpt"]))
        if self.clock is not None and self.byte_rate > 0:
            self.clock.sleep(len(frame) / self.byte_rate)

    def compaction_due(self) -> bool:
        with self._lock:
            return self.enabled and \
                self.appends_since_snapshot >= self.compact_every

    def write_snapshot(self, state: dict) -> None:
        """Atomically publish a compacted snapshot and truncate the WAL.

        Call with the catalog lock held so the doc is a consistent cut:
        records folded into the snapshot must not also survive in the
        tail."""
        if not self.enabled:
            return
        with self._lock:
            tmp = self.snap_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(state, f, separators=(",", ":"))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.snap_path)
            self._fh.close()
            self._fh = open(self.wal_path, "wb")    # truncate
            self.appends_since_snapshot = 0
            self.snapshots += 1

    # ------------------------------------------------------------- reading
    def read_frames(self) -> Tuple[List[dict], dict]:
        """Decode the WAL tail; stops at the first truncated or CRC-corrupt
        frame (the torn final write of a crash) without raising."""
        records: List[dict] = []
        stats = {"frames": 0, "truncated": 0, "crc_bad": 0}
        with self._lock:
            fh = getattr(self, "_fh", None)
            if fh is not None and not fh.closed:
                fh.flush()      # surface any lazily-buffered tail records
        try:
            with open(self.wal_path, "rb") as f:
                blob = f.read()
        except OSError:
            return records, stats
        off = 0
        while off + _HEADER <= len(blob):
            if blob[off:off + 4] != JOURNAL_MAGIC:
                stats["crc_bad"] += 1
                break
            n = int.from_bytes(blob[off + 4:off + 8], "little")
            crc = int.from_bytes(blob[off + 8:off + 12], "little")
            body = blob[off + 12:off + 12 + n]
            if len(body) < n:
                stats["truncated"] += 1
                break
            if crc32(body) != crc:
                stats["crc_bad"] += 1
                break
            try:
                records.append(json.loads(body))
            except ValueError:
                stats["crc_bad"] += 1
                break
            stats["frames"] += 1
            off += 12 + n
        else:
            if off < len(blob):
                stats["truncated"] += 1
        return records, stats

    def read_state(self) -> Tuple[dict, dict]:
        """Snapshot + tail folded into one state doc (plus replay stats)."""
        state: dict = {"epoch": 0, "apps": {}, "chains": {}, "holds": {}}
        stats = {"snapshot": False, "frames": 0, "truncated": 0,
                 "crc_bad": 0}
        try:
            with open(self.snap_path) as f:
                snap = json.load(f)
            state.update(snap)
            state.setdefault("chains", {})
            state.setdefault("holds", {})
            stats["snapshot"] = True
        except (OSError, ValueError):
            pass
        records, tail_stats = self.read_frames()
        stats.update({k: tail_stats[k]
                      for k in ("frames", "truncated", "crc_bad")})
        for rec in records:
            apply_record(state, rec)
        return state, stats

    def replay_state(self) -> RecoveredState:
        state, stats = self.read_state()
        rs = RecoveredState(epoch=int(state.get("epoch", 0)),
                            apps=state.get("apps", {}), stats=stats)
        for ckey, chain in state.get("chains", {}).items():
            app_id, _, region = ckey.partition("\x00")
            rs.open_chains[(app_id, region)] = tuple(chain)
        for ckey, n in state.get("holds", {}).items():
            app_id, _, region = ckey.partition("\x00")
            rs.holds[(app_id, region)] = int(n)
        return rs

    def truth(self) -> Dict[str, int]:
        """Per-app max checkpoint id ever journaled (the 'never newer than
        journaled truth' bound the recovery_fidelity invariant enforces)."""
        with self._lock:
            return dict(self._truth)

    def stats(self) -> dict:
        with self._lock:
            return {"appends": self.appends,
                    "appends_since_snapshot": self.appends_since_snapshot,
                    "snapshots": self.snapshots,
                    "bytes_appended": self.bytes_appended,
                    "enabled": self.enabled}

    def close(self) -> None:
        with self._lock:
            try:
                self._fh.close()
            except OSError:
                pass
            self.enabled = False

    # -- doc builders (called by the controller under its lock) ------------
    @staticmethod
    def ckpt_doc(meta: CheckpointMeta) -> dict:
        return {
            "ckpt": meta.ckpt_id, "step": meta.step,
            "status": meta.status.value,
            "userdata_hex": meta.userdata.hex(),
            "pinned": meta.pinned,
            "regions": _region_docs(meta.regions),
            "shards": {
                f"{k.region}/{k.part}/{k.replica}": {
                    "key": [k.app_id, k.ckpt_id, k.region, k.part,
                            k.replica],
                    "nbytes": s.nbytes, "crc": s.crc32,
                    "agent": s.agent_id}
                for k, s in meta.shards.items()},
        }
