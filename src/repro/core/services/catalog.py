"""Checkpoint catalog: lifecycle registry + multi-level restart read path.

Owns the PENDING → IN_L1 → DRAINING → IN_L2 → IN_L3 state machine of every
checkpoint (paper §II) and answers "what is the newest restartable
checkpoint and where does each shard live" — L1 via any live holding agent
(replicas tried in turn), else L2 (PFS), else L3 (remote object store,
promote-on-read back into the PFS) — including the cold-restart scan of PFS
manifests (then L3 manifests, when the PFS is empty too) when a fresh
controller knows nothing yet.
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterator, Optional, Tuple

from .. import events as E
from ..types import (AppId, CheckpointMeta, CkptId, CkptStatus, ICheckError,
                     RegionMeta, ShardInfo, ShardKey)


class CheckpointCatalog:
    def __init__(self, ctl):
        self.ctl = ctl
        self._seq: Dict[AppId, itertools.count] = {}

    # ------------------------------------------------------------- lifecycle
    def open_app(self, app_id: AppId) -> None:
        self._seq[app_id] = itertools.count()

    def new_checkpoint(self, app_id: AppId, step: int,
                       regions: Dict[str, RegionMeta],
                       userdata: bytes = b"") -> CheckpointMeta:
        ctl = self.ctl
        with ctl._lock:
            app = ctl._apps[app_id]
            ckpt_id = next(self._seq[app_id])
            meta = CheckpointMeta(app_id=app_id, ckpt_id=ckpt_id, step=step,
                                  regions=dict(regions), userdata=userdata)
            app.checkpoints[ckpt_id] = meta
            total = sum(r.nbytes for r in regions.values())
            app.ckpt_bytes_estimate = max(app.ckpt_bytes_estimate, total)
        return meta

    def record_shard(self, meta: CheckpointMeta, info: ShardInfo) -> None:
        with self.ctl._lock:
            meta.shards[info.key] = info

    def finalize(self, meta: CheckpointMeta, drain: bool = True) -> None:
        """All shards acked in L1 → durable pipeline."""
        ctl = self.ctl
        with ctl._lock:
            if not meta.is_complete_in_l1():
                raise ICheckError(
                    f"checkpoint {meta.ckpt_id} incomplete: "
                    f"{len(meta.shards)}/{meta.expected_shards()} shards")
            meta.status = CkptStatus.IN_L1
            meta.completed_at = ctl.clock.now()
        ctl.bus.publish(E.CKPT_IN_L1, app=meta.app_id, ckpt=meta.ckpt_id,
                        step=meta.step)
        if drain:
            ctl.drains.submit(meta)

    def mark_failed(self, app_id: AppId, ckpt_id: CkptId) -> None:
        ctl = self.ctl
        with ctl._lock:
            app = ctl._apps.get(app_id)
            meta = app.checkpoints.get(ckpt_id) if app else None
            if meta is not None and meta.status not in (CkptStatus.IN_L2,
                                                        CkptStatus.IN_L3):
                meta.status = CkptStatus.FAILED
                ctl.bus.publish(E.CKPT_FAILED, app=app_id, ckpt=ckpt_id)

    # ------------------------------------------------------------- read path
    def latest_restartable(self, app_id: AppId) -> Optional[Tuple[CheckpointMeta, str]]:
        """Newest usable checkpoint: L1 preferred (fast), else L2, else L3."""
        ctl = self.ctl
        l3 = getattr(ctl, "l3", None)
        with ctl._lock:
            app = ctl._apps.get(app_id)
            metas = sorted(app.checkpoints.values(), key=lambda m: -m.ckpt_id) \
                if app else []
        for meta in metas:
            if meta.status in (CkptStatus.IN_L1, CkptStatus.DRAINING) \
                    and self.l1_complete(meta):
                return meta, "l1"
            if meta.status in (CkptStatus.IN_L2, CkptStatus.IN_L3):
                if self.l1_complete(meta):
                    return meta, "l1"
                if ctl.pfs.checkpoint_complete(meta):
                    return meta, "l2"
                # retention may have trimmed the PFS copy: serve from L3
                if l3 is not None and l3.checkpoint_complete(meta):
                    return meta, "l3"
        # cold restart: nothing in memory (e.g. new controller) — scan PFS
        for ckpt_id in reversed(ctl.pfs.list_checkpoints(app_id)):
            meta = ctl.pfs.read_manifest(app_id, ckpt_id)
            if meta is not None and ctl.pfs.checkpoint_complete(meta):
                meta.status = CkptStatus.IN_L2
                with ctl._lock:
                    if app is not None:
                        app.checkpoints.setdefault(ckpt_id, meta)
                return meta, "l2"
        # still nothing: the PFS may have been lost/recycled too — scan the
        # remote object store's manifests (the durability floor)
        if l3 is not None:
            for ckpt_id in reversed(l3.list_checkpoints(app_id)):
                meta = l3.read_manifest(app_id, ckpt_id)
                if meta is not None and l3.checkpoint_complete(meta):
                    meta.status = CkptStatus.IN_L3
                    with ctl._lock:
                        if app is not None:
                            app.checkpoints.setdefault(ckpt_id, meta)
                    return meta, "l3"
        return None

    def l1_complete(self, meta: CheckpointMeta) -> bool:
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                if next(self.agents_with(meta.app_id, meta.ckpt_id, name,
                                         part), None) is None:
                    return False
        return True

    def agents_with(self, app_id: AppId, ckpt_id: CkptId, region: str,
                    part: int) -> Iterator:
        """Live (agent, key) pairs holding any replica of the shard."""
        for mgr in self.ctl.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                if not agent.alive():        # failover: skip dead replicas
                    continue
                for rep in range(4):
                    k = ShardKey(app_id, ckpt_id, region, part, rep)
                    if agent.has(k):
                        yield agent, k

    def fetch_shard(self, app_id: AppId, ckpt_id: CkptId, region: str,
                    part: int) -> bytes:
        """Restart/redistribution read path: L1 via any *live* holding agent
        (replicas tried in turn), else L2 (PFS), else L3 (object store)."""
        for agent, k in self.agents_with(app_id, ckpt_id, region, part):
            try:
                return agent.get(k)
            except (ConnectionError, KeyError):
                continue                     # race with a failure: next copy
        key = ShardKey(app_id, ckpt_id, region, part)
        if self.ctl.pfs.has_shard(key):
            return self.ctl.pfs.read_shard(key)
        l3 = getattr(self.ctl, "l3", None)
        if l3 is not None and l3.has_shard(key):
            payload = l3.read_shard(key)
            # promote-on-read back through the pipeline: repopulate the PFS
            # copy so the remaining shards of this restart (and the next
            # restart) are served at PFS latency instead of object-store
            # request-latency
            self.ctl.pfs.write_shard(key, payload)
            self.ctl.bus.publish(E.SHARD_PROMOTED, node="cluster",
                                 key=str(key), src=l3.name,
                                 dst=self.ctl.pfs.name, nbytes=len(payload))
            return payload
        raise KeyError(f"shard {app_id}/{ckpt_id}/{region}/{part} lost")
