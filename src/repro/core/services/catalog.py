"""Checkpoint catalog: lifecycle registry + multi-level restart read path.

Owns the PENDING → IN_L1 → DRAINING → IN_L2 → IN_L3 state machine of every
checkpoint (paper §II) and answers "what is the newest restartable
checkpoint and where does each shard live" — L1 via any live holding agent
(replicas tried in turn), else L2 (PFS), else L3 (remote object store,
promote-on-read back into the PFS) — including the cold-restart scan of PFS
manifests (then L3 manifests, when the PFS is empty too) when a fresh
controller knows nothing yet.

Also owns the **q8-delta chain state** of the incremental commit path: per
(app, region) the previous-codes handles every part's next delta encodes
against, the keyframe-every-K policy, and the mandatory resets — on
resize/redistribution (the controller calls :meth:`reset_delta_chains` when
a region's partition changes), on rank/agent/node failure, on demotion of a
chain frame out of L1, and on retention expiry of a chain frame.  After a
reset the next commit of that region emits a full keyframe; a restore
replays keyframe + deltas (``chain`` on the per-checkpoint RegionMeta).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, Iterator, List, Optional, Tuple

from ...obs import trace_id_for
from .. import events as E
from ..tiers import (FRAG_DATA0, FRAG_PARITY0, DeltaState, ec_decode_shard,
                     ec_parse_fragment)
from ..types import (AppId, CheckpointMeta, CkptId, CkptStatus, ICheckError,
                     IntegrityError, RegionMeta, ShardInfo, ShardKey)

# any of these may have destroyed (or made unreachable) an L1-only delta
# frame, or invalidated the codes the application will diff against next:
# the affected chains reset so the next commit is a self-contained keyframe
_CHAIN_RESET_EVENTS = (E.APP_RANK_FAILED, E.NODE_FAILED, E.AGENT_FAILED,
                       E.NODE_RETAKEN, E.MIGRATION_LOST_SHARD, E.CKPT_FAILED,
                       E.CKPT_EXPIRED, E.SHARD_DEMOTED)


@dataclasses.dataclass
class RegionChain:
    """Live delta chain of one region: frame ckpt ids + per-part handles."""

    chain: Tuple[CkptId, ...]            # keyframe first, newest last
    parts: Dict[int, DeltaState]         # part -> previous-codes handle


class CheckpointCatalog:
    # while a zero-stall resize holds a chain open, the keyframe-every-K
    # horizon stretches by this factor (a safety cap, not a policy: the
    # overlap window is short, but a wedged cutover must not let the chain
    # grow without bound)
    HOLD_HORIZON_FACTOR = 4

    def __init__(self, ctl, delta_keyframe_every: int = 8):
        self.ctl = ctl
        self._seq: Dict[AppId, itertools.count] = {}
        self.delta_keyframe_every = max(1, int(delta_keyframe_every))
        self._kf_every: Dict[AppId, int] = {}
        self._chain_lock = threading.Lock()
        self._chains: Dict[Tuple[AppId, str], RegionChain] = {}
        # (app, region) -> refcount of open overlap windows; a held chain
        # keeps producing deltas past the keyframe horizon so the window's
        # commits stay replayable tail frames (reset still happens normally
        # — the cutover detects it and re-hydrates instead)
        self._holds: Dict[Tuple[AppId, str], int] = {}
        self._unsub_chain = ctl.bus.subscribe(self._on_chain_event,
                                              events=_CHAIN_RESET_EVENTS)

    def close(self) -> None:
        self._unsub_chain()

    def _journal(self, kind: str, **fields) -> None:
        """Append one WAL record *before* the mutation it describes (no-op
        when the controller runs without a metadata journal)."""
        j = getattr(self.ctl, "journal", None)
        if j is not None:
            j.append(kind, **fields)

    # ------------------------------------------------------------- lifecycle
    def open_app(self, app_id: AppId) -> None:
        self._journal("open_app", app=app_id)
        self._seq[app_id] = itertools.count()

    def set_seq(self, app_id: AppId, next_ckpt: int) -> None:
        """Re-seat the id sequence past recovered history (recovery path)."""
        self._seq[app_id] = itertools.count(int(next_ckpt))

    def new_checkpoint(self, app_id: AppId, step: int,
                       regions: Dict[str, RegionMeta],
                       userdata: bytes = b"") -> CheckpointMeta:
        ctl = self.ctl
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None or app_id not in self._seq:
                raise ICheckError(f"app {app_id} is not registered")
            ckpt_id = next(self._seq[app_id])
            meta = CheckpointMeta(app_id=app_id, ckpt_id=ckpt_id, step=step,
                                  regions=dict(regions), userdata=userdata)
            from ..tiers import region_doc
            self._journal("new_ckpt", app=app_id, ckpt=ckpt_id, step=step,
                          userdata_hex=userdata.hex(),
                          regions={n: region_doc(r)
                                   for n, r in meta.regions.items()})
            app.checkpoints[ckpt_id] = meta
            total = sum(r.nbytes for r in regions.values())
            app.ckpt_bytes_estimate = max(app.ckpt_bytes_estimate, total)
        return meta

    def record_shard(self, meta: CheckpointMeta, info: ShardInfo) -> None:
        with self.ctl._lock:
            k = info.key
            self._journal("shard", app=meta.app_id, ckpt=meta.ckpt_id,
                          key=[k.app_id, k.ckpt_id, k.region, k.part,
                               k.replica],
                          nbytes=info.nbytes, crc=info.crc32,
                          agent=info.agent_id)
            meta.shards[info.key] = info

    def set_status(self, meta: CheckpointMeta, status: CkptStatus) -> None:
        """The single write path for checkpoint status transitions: WAL
        first, then the in-memory flip (under the controller lock)."""
        with self.ctl._lock:
            if meta.status is status:
                return
            self._journal("status", app=meta.app_id, ckpt=meta.ckpt_id,
                          status=status.value)
            meta.status = status

    def finalize(self, meta: CheckpointMeta, drain: bool = True) -> None:
        """All shards acked in L1 → durable pipeline."""
        ctl = self.ctl
        with ctl._lock:
            if not meta.is_complete_in_l1():
                raise ICheckError(
                    f"checkpoint {meta.ckpt_id} incomplete: "
                    f"{len(meta.shards)}/{meta.expected_shards()} shards")
            self.set_status(meta, CkptStatus.IN_L1)
            meta.completed_at = ctl.clock.now()
        ctl.bus.publish(E.CKPT_IN_L1, app=meta.app_id, ckpt=meta.ckpt_id,
                        step=meta.step)
        ctl.maybe_compact_journal()
        if drain:
            ctl.drains.submit(meta)

    # ---------------------------------------------------- q8-delta chains
    def keyframe_every(self, app_id: AppId) -> int:
        return self._kf_every.get(app_id, self.delta_keyframe_every)

    def set_keyframe_every(self, app_id: AppId, k: Optional[int]) -> None:
        """Per-app keyframe cadence override (None restores the default)."""
        if k is None:
            self._kf_every.pop(app_id, None)
        else:
            self._kf_every[app_id] = max(1, int(k))

    def delta_chain(self, app_id: AppId, region: str,
                    num_parts: int) -> Optional[RegionChain]:
        """Previous-codes state the next commit of ``region`` may delta
        against, or None when a keyframe is due (no chain, chain at the
        keyframe-every-K horizon, or a part-count mismatch).  A held chain
        (open overlap window) stretches the horizon so the window's commits
        keep extending the replayable tail instead of keyframing under it."""
        with self._chain_lock:
            rc = self._chains.get((app_id, region))
            horizon = self.keyframe_every(app_id)
            if self._holds.get((app_id, region), 0) > 0:
                horizon *= self.HOLD_HORIZON_FACTOR
            if rc is None or len(rc.chain) >= horizon:
                return None
            if set(rc.parts) != set(range(num_parts)):
                return None
            return rc

    def hold_chain(self, app_id: AppId, region: str) -> None:
        """Keep ``region``'s chain open across a zero-stall resize window
        (ref-counted; pair with :meth:`release_chain`)."""
        with self._chain_lock:
            k = (app_id, region)
            self._journal("chain_hold", app=app_id, region=region)
            self._holds[k] = self._holds.get(k, 0) + 1

    def release_chain(self, app_id: AppId, region: str) -> None:
        with self._chain_lock:
            k = (app_id, region)
            self._journal("chain_release", app=app_id, region=region)
            n = self._holds.get(k, 0) - 1
            if n <= 0:
                self._holds.pop(k, None)
            else:
                self._holds[k] = n

    def advance_chain(self, app_id: AppId, ckpt_id: CkptId, region: str,
                      states: Optional[Dict[int, DeltaState]],
                      frame: str) -> Tuple[CkptId, ...]:
        """Record the frame a commit just encoded; returns the region's new
        chain (what the per-checkpoint RegionMeta must carry for replay)."""
        with self._chain_lock:
            if states is None:          # chainless (non-float passthrough)
                if (app_id, region) in self._chains:
                    self._journal("chain_reset", app=app_id, region=region,
                                  reason="chainless")
                self._chains.pop((app_id, region), None)
                return (ckpt_id,)
            if frame == "key":
                chain: Tuple[CkptId, ...] = (ckpt_id,)
            else:
                rc = self._chains.get((app_id, region))
                if rc is None:
                    raise ICheckError(
                        f"delta frame for {app_id}/{region} without a chain")
                chain = rc.chain + (ckpt_id,)
            self._journal("chain_advance", app=app_id, region=region,
                          chain=list(chain))
            self._chains[(app_id, region)] = RegionChain(chain=chain,
                                                         parts=dict(states))
            return chain

    def reset_delta_chains(self, app_id: Optional[AppId] = None,
                           region: Optional[str] = None,
                           reason: str = "") -> int:
        """Drop matching chains (all when ``app_id`` is None); every dropped
        chain publishes ``DELTA_CHAIN_RESET`` so the policy stays observable.
        """
        with self._chain_lock:
            victims = [k for k in self._chains
                       if (app_id is None or k[0] == app_id)
                       and (region is None or k[1] == region)]
            for app, reg in victims:
                self._journal("chain_reset", app=app, region=reg,
                              reason=reason)
            dropped = [(k, self._chains.pop(k)) for k in victims]
        for (app, reg), rc in dropped:
            self.ctl.bus.publish(E.DELTA_CHAIN_RESET, app=app, region=reg,
                                 reason=reason, chain_len=len(rc.chain))
        return len(dropped)

    def _reset_chains_containing(self, app_id: Optional[AppId],
                                 ckpt_id: Optional[CkptId],
                                 region: Optional[str],
                                 reason: str) -> None:
        """Reset chains that have ``ckpt_id`` as one of their frames (a
        demoted or expired frame makes the replay path slow or impossible)."""
        if app_id is None or ckpt_id is None:
            return
        with self._chain_lock:
            victims = [k for k, rc in self._chains.items()
                       if k[0] == app_id and ckpt_id in rc.chain
                       and (region is None or k[1] == region)]
        for app, reg in victims:
            self.reset_delta_chains(app, reg, reason=reason)

    def _on_chain_event(self, ev: E.Event) -> None:
        name, p = ev.name, ev.payload
        if name in (E.APP_RANK_FAILED, E.CKPT_FAILED):
            self.reset_delta_chains(app_id=p.get("app"), reason=name)
        elif name in (E.CKPT_EXPIRED, E.SHARD_DEMOTED):
            self._reset_chains_containing(p.get("app"), p.get("ckpt"),
                                          p.get("region"), reason=name)
        else:   # node/agent failure, retake, migration loss: L1-only frames
            # may be gone — a keyframe next commit beats decoding garbage
            self.reset_delta_chains(reason=name)

    def chain_stats(self) -> List[dict]:
        with self._chain_lock:
            return [{"app": app, "region": region,
                     "chain_len": len(rc.chain), "root": rc.chain[0],
                     "head": rc.chain[-1]}
                    for (app, region), rc in self._chains.items()]

    def chain_holds(self) -> Dict[Tuple[AppId, str], int]:
        """Open hold refcounts per (app, region) — empty once every overlap
        window has closed (the chaos no-leak invariant reads this)."""
        with self._chain_lock:
            return dict(self._holds)

    # ------------------------------------------------------------- failure
    def mark_failed(self, app_id: AppId, ckpt_id: CkptId) -> None:
        """Mark a checkpoint failed, cascading to its q8-delta dependents:
        any non-durable checkpoint whose replay chain references the failed
        frame can never be reconstructed, so ``latest_restartable`` must
        skip it (and fall back to an older intact checkpoint)."""
        ctl = self.ctl
        failed = []
        with ctl._lock:
            app = ctl._apps.get(app_id)
            meta = app.checkpoints.get(ckpt_id) if app else None
            if meta is not None and meta.status not in (CkptStatus.IN_L2,
                                                        CkptStatus.IN_L3):
                victims = [meta]
                for dep in app.checkpoints.values():
                    if dep.ckpt_id == ckpt_id or \
                            dep.status in (CkptStatus.IN_L2, CkptStatus.IN_L3,
                                           CkptStatus.FAILED):
                        continue
                    if any(r.chain and ckpt_id in r.chain
                           for r in dep.regions.values()):
                        victims.append(dep)
                for v in victims:       # WAL first, then the state flips
                    self.set_status(v, CkptStatus.FAILED)
                    failed.append(v.ckpt_id)
        for cid in failed:
            ctl.bus.publish(E.CKPT_FAILED, app=app_id, ckpt=cid)

    # ------------------------------------------------------------- read path
    def latest_restartable(self, app_id: AppId) -> Optional[Tuple[CheckpointMeta, str]]:
        """Newest usable checkpoint: L1 preferred (fast), else L2, else L3.

        A q8-delta checkpoint is only usable if its whole replay chain is
        still fetchable from *some* tier — a candidate whose keyframe or a
        mid-chain delta is gone (e.g. a partially-drained chain on a cold
        restart) is skipped in favour of an older intact checkpoint.
        """
        ctl = self.ctl
        l3 = getattr(ctl, "l3", None)
        with ctl._lock:
            app = ctl._apps.get(app_id)
            metas = sorted(app.checkpoints.values(), key=lambda m: -m.ckpt_id) \
                if app else []
        for meta in metas:
            if meta.status in (CkptStatus.IN_L1, CkptStatus.DRAINING) \
                    and self.l1_complete(meta) and self.chain_restorable(meta):
                return meta, "l1"
            if meta.status in (CkptStatus.IN_L2, CkptStatus.IN_L3):
                if not self.chain_restorable(meta):
                    continue
                if self.l1_complete(meta):
                    return meta, "l1"
                if ctl.pfs.checkpoint_complete(meta):
                    return meta, "l2"
                # retention may have trimmed the PFS copy: serve from L3
                if l3 is not None and l3.checkpoint_complete(meta):
                    return meta, "l3"
        # cold restart: nothing in memory (e.g. new controller) — scan PFS
        for ckpt_id in reversed(ctl.pfs.list_checkpoints(app_id)):
            meta = ctl.pfs.read_manifest(app_id, ckpt_id)
            if meta is not None and ctl.pfs.checkpoint_complete(meta) \
                    and self.chain_restorable(meta):
                meta.status = CkptStatus.IN_L2
                with ctl._lock:
                    if app is not None:
                        app.checkpoints.setdefault(ckpt_id, meta)
                return meta, "l2"
        # still nothing: the PFS may have been lost/recycled too — scan the
        # remote object store's manifests (the durability floor)
        if l3 is not None:
            for ckpt_id in reversed(l3.list_checkpoints(app_id)):
                meta = l3.read_manifest(app_id, ckpt_id)
                if meta is not None and l3.checkpoint_complete(meta) \
                        and self.chain_restorable(meta):
                    meta.status = CkptStatus.IN_L3
                    with ctl._lock:
                        if app is not None:
                            app.checkpoints.setdefault(ckpt_id, meta)
                    return meta, "l3"
        return None

    def chain_restorable(self, meta: CheckpointMeta) -> bool:
        """Every *ancestor* frame of the checkpoint's delta chains is still
        fetchable (L1 agent, PFS, or L3).  The checkpoint's own frames are
        covered by the caller's completeness check; raw/q8 regions have no
        chain and always pass.  Presence only — a corrupt frame still
        surfaces as RestoreError at replay time."""
        ctl = self.ctl
        l3 = getattr(ctl, "l3", None)
        for name, region in meta.regions.items():
            if region.codec != "q8-delta" or not region.chain:
                continue
            for cid in region.chain[:-1]:
                for part in range(region.partition.num_parts):
                    if next(self.agents_with(meta.app_id, cid, name, part),
                            None) is not None:
                        continue
                    key = ShardKey(meta.app_id, cid, name, part)
                    if ctl.pfs.has_shard(key):
                        continue
                    if l3 is not None and l3.has_shard(key):
                        continue
                    return False
        return True

    def l1_complete(self, meta: CheckpointMeta) -> bool:
        ec = self.ec_geometry(meta.app_id)
        for name, region in meta.regions.items():
            for part in range(region.partition.num_parts):
                if ec is not None:
                    k = ec[0]
                    alive = 0
                    for _ in self.fragments_with(meta.app_id, meta.ckpt_id,
                                                 name, part):
                        alive += 1
                        if alive >= k:
                            break
                    if alive >= k:       # any k fragments reconstruct it
                        continue
                if next(self.agents_with(meta.app_id, meta.ckpt_id, name,
                                         part), None) is None:
                    return False
        return True

    def agents_with(self, app_id: AppId, ckpt_id: CkptId, region: str,
                    part: int) -> Iterator:
        """Live (agent, key) pairs holding any replica of the shard."""
        for mgr in self.ctl.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                if not agent.alive():        # failover: skip dead replicas
                    continue
                for rep in range(4):
                    k = ShardKey(app_id, ckpt_id, region, part, rep)
                    if agent.has(k):
                        yield agent, k

    def ec_geometry(self, app_id: AppId) -> Optional[Tuple[int, int]]:
        """The app's (k, m) stripe geometry, or None when not erasure-coded."""
        with self.ctl._lock:
            app = self.ctl._apps.get(app_id)
            return app.ec if app is not None else None

    def fragments_with(self, app_id: AppId, ckpt_id: CkptId, region: str,
                       part: int) -> Iterator:
        """Live (agent, key) pairs holding erasure fragments of the shard.

        One (agent, key) per *distinct* fragment index — a fragment hosted
        twice (e.g. rebuilt while its original survived a partition) counts
        once, so callers can treat the yield count as surviving-fragment
        count."""
        ec = self.ec_geometry(app_id)
        if ec is None:
            return
        k, m = ec
        reps = [FRAG_DATA0 + i for i in range(k)] + [
            FRAG_PARITY0 + j for j in range(m)
        ]
        seen = set()
        for mgr in self.ctl.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                if not agent.alive():
                    continue
                for rep in reps:
                    if rep in seen:
                        continue
                    fk = ShardKey(app_id, ckpt_id, region, part, rep)
                    if agent.has(fk):
                        seen.add(rep)
                        yield agent, fk

    def fetch_shard(self, app_id: AppId, ckpt_id: CkptId, region: str,
                    part: int) -> bytes:
        """Restart/redistribution read path: L1 via any *live* holding agent
        (replicas tried in turn, then erasure reconstruction from any k
        fragments), else L2 (PFS), else L3 (object store)."""
        for agent, k in self.agents_with(app_id, ckpt_id, region, part):
            try:
                return agent.get(k)
            except (ConnectionError, KeyError):
                continue                     # race with a failure: next copy
        payload = self._fetch_from_fragments(app_id, ckpt_id, region, part)
        if payload is not None:
            return payload
        key = ShardKey(app_id, ckpt_id, region, part)
        if self.ctl.pfs.has_shard(key):
            return self.ctl.pfs.read_shard(key)
        l3 = getattr(self.ctl, "l3", None)
        if l3 is not None and l3.has_shard(key):
            # promote-on-read back through the pipeline: repopulate the PFS
            # copy so the remaining shards of this restart (and the next
            # restart) are served at PFS latency instead of object-store
            # request-latency
            with self.ctl.tracer.span("shard_promote",
                                      trace_id_for(app_id, ckpt_id),
                                      "catalog/fetch", region=region,
                                      part=part):
                payload = l3.read_shard(key)
                self.ctl.pfs.write_shard(key, payload)
            self.ctl.bus.publish(E.SHARD_PROMOTED, node="cluster",
                                 key=str(key), src=l3.name,
                                 dst=self.ctl.pfs.name, nbytes=len(payload))
            return payload
        raise KeyError(f"shard {app_id}/{ckpt_id}/{region}/{part} lost")

    def _fetch_from_fragments(self, app_id: AppId, ckpt_id: CkptId,
                              region: str, part: int) -> Optional[bytes]:
        """Reconstruct one logical shard from any k surviving L1 fragments
        (None when the app isn't erasure-coded or fewer than k survive)."""
        frags: Dict[int, bytes] = {}
        need: Optional[int] = None
        for agent, fk in self.fragments_with(app_id, ckpt_id, region, part):
            try:
                blob = agent.get(fk)
                k_geom, _, idx, _, _, _ = ec_parse_fragment(blob)
            except (ConnectionError, KeyError, IntegrityError):
                continue                     # race with a failure: next one
            need = k_geom
            frags[idx] = blob
            if len(frags) >= need:
                break
        if need is None or len(frags) < need:
            return None
        payload = ec_decode_shard(list(frags.values()))
        if sorted(frags)[:need] != list(range(need)):
            # a data fragment was among the casualties: the read GF-decoded
            # around it (durability held, latency paid) — say so
            self.ctl.bus.publish(E.EC_DEGRADED_READ, app=app_id,
                                 ckpt=ckpt_id, region=region, part=part,
                                 have=sorted(frags))
        return payload
