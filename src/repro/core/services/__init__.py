"""Focused subsystems of the checkpoint-service core.

The paper describes the controller as a composition of independent services
(§II, §III-A); each lives in its own module here and communicates through
the :mod:`repro.core.events` bus:

  * :mod:`placement` — policy-driven agent placement + agent-count adaptivity
  * :mod:`catalog`   — checkpoint lifecycle registry and the restart read path
  * :mod:`drain`     — bounded-concurrency L1→L2 drain orchestration + L1 GC
  * :mod:`health`    — heartbeats, shard re-replication, straggler advice,
                       RM node retake/migration handling
  * :mod:`resize`    — resize forewarning → pre-staged redistribution plans
  * :mod:`telemetry` — bus-fed per-app EWMA estimates (commit cost, drain
                       throughput, failure inter-arrival) + Prometheus export
  * :mod:`interval`  — Young/Daly checkpoint-interval re-solver publishing
                       ``INTERVAL_CHANGED`` events (the adaptive loop)
  * :mod:`lifecycle` — storage lifecycle: watermark demotion, background
                       L2→L3 trickle, keep-last-K retention/GC with pinning
  * :mod:`journal`   — write-ahead metadata journal (CRC-framed WAL +
                       compacted snapshots) and the controller epoch fence
"""
from .catalog import CheckpointCatalog
from .drain import DrainOrchestrator
from .health import HealthMonitor
from .interval import IntervalController, daly_interval, young_interval
from .journal import EpochFence, MetadataJournal, StaleEpochError
from .lifecycle import StorageLifecycleService
from .placement import PlacementService
from .resize import ResizePlanner
from .telemetry import AppTelemetry, TelemetryService

__all__ = ["CheckpointCatalog", "DrainOrchestrator", "HealthMonitor",
           "IntervalController", "PlacementService", "ResizePlanner",
           "StorageLifecycleService", "TelemetryService", "AppTelemetry",
           "EpochFence", "MetadataJournal", "StaleEpochError",
           "daly_interval", "young_interval"]
