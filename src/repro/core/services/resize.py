"""Resize forewarning → pre-staged redistribution plans.

Paper §III-A interaction 4: the RM "informs the controller about an
impending resource change of an application so that agents can prepare ...
ahead of time".  Plans are cached per (app, region, new_parts) so the
adapt-window redistribution (client.redistribute) reuses the pre-staged
moves instead of re-planning under time pressure.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from .. import events as E
from .. import plan as planlib
from ..types import AppId, PartitionScheme


class ResizePlanner:
    def __init__(self, ctl):
        self.ctl = ctl
        # (app_id, region_name, new_parts) -> [Move]
        self.plans: Dict[Tuple[AppId, str, int], List[planlib.Move]] = {}

    def plan_for_resize(self, app_id: AppId, region_name: str,
                        new_parts: int) -> List[planlib.Move]:
        ctl = self.ctl
        key = (app_id, region_name, new_parts)
        with ctl._lock:
            if key in self.plans:
                return self.plans[key]
            region = ctl._regions[app_id][region_name]
        old = region.partition
        new = old.renumbered(new_parts)
        n = region.shape[old.axis] if old.scheme.value != "replicated" else 1
        moves = planlib.redistribution_moves(n, old, new) \
            if old.scheme.value != "replicated" else []
        with ctl._lock:
            self.plans[key] = moves
        return moves

    def on_app_info(self, app_id: str, info: dict) -> None:
        """RM forewarning callback: pre-stage plans for every region."""
        if info.get("event") != "impending_resize":
            return
        ctl = self.ctl
        new_ranks = int(info["new_ranks"])
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None:
                return
            app.pending_resize = new_ranks
            regions = dict(ctl._regions.get(app_id, {}))
        planned = 0
        for name, region in regions.items():
            # MESH regions replan against the *new mesh's* boxes, which only
            # the application knows at adapt time (redistribute_mesh)
            if region.partition.scheme == PartitionScheme.MESH:
                continue
            self.plan_for_resize(app_id, name, new_ranks)
            planned += 1
        ctl.bus.publish(E.RESIZE_FOREWARNED, app=app_id, new_ranks=new_ranks,
                        plans=planned)
