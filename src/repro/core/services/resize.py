"""Resize forewarning → pre-staged plans, and the peer redistribution engine.

Paper §III-A interaction 4: the RM "informs the controller about an
impending resource change of an application so that agents can prepare ...
ahead of time".  Two artifacts are pre-staged per (app, region, new_parts):

  * the *move list* (``plan_for_resize``) — what the legacy client funnel
    and the benchmarks consume;
  * the *transfer programs* (``transfer_programs``) — per-destination-part
    slice reads the agents execute peer-to-peer during the adapt window
    (arXiv:2509.05248 style), so the window only executes, never plans.

Both caches are invalidated when a region's partition changes
(``commit_redistribution`` → ``register_region``): a plan computed against
the old layout must never be reused for the new one.

:class:`PeerRedistributionEngine` (owned by the planner) executes the
programs: it resolves every source shard (live L1 agent, else PFS, else L3),
dispatches one ``assemble`` op per destination part to that part's owning
agent, waits, and reports analytic adapt-window timing — per-node
serialized-at-full-bandwidth sums, exactly the model ``CommitHandle`` uses
for concurrent puts, so concurrency across agent pairs shows up as
wall-clock it actually saves.
"""
from __future__ import annotations

import itertools
import time
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, List, Optional, Tuple

import numpy as np

from ...obs import trace_id_for
from .. import events as E
from .. import plan as planlib
from ..agent import Agent, AssembleSpec, ReplaySpec, SliceFetch
from ..tiers import Q8_EMPTY_DELTA_NBYTES
from ..types import (AppId, ICheckError, NodeSpec, PartitionScheme,
                     RegionMeta, ShardKey)


class OverlapWindow:
    """One zero-stall resize session for one region (two-phase).

    Phase 1 (``streaming`` → ``ready``): the base checkpoint streams to the
    new partition in the background while the application keeps stepping —
    and keeps committing q8-deltas against the held pre-resize chain; the
    window counts those commits and watches for a racing chain reset.

    Phase 2 (``cutover`` → ``done``/``failed``): quiesce, replay the tail
    delta frames that accumulated during the window onto the assembled
    scratch parts (or re-hydrate from the head checkpoint when the chain
    reset or the codec has no replayable tail), switch.  The stall is
    bounded by the tail, not the full stream.
    """

    def __init__(self, engine, app_id: AppId, region: RegionMeta,
                 base_ckpt: int, base_chain: Tuple[int, ...],
                 programs: Dict[int, planlib.TransferProgram],
                 providers: dict, jobs: list):
        self.engine = engine
        self.app_id = app_id
        self.region = region
        self.base_ckpt = base_ckpt
        self.base_chain = base_chain
        self.programs = programs
        self.providers = providers
        self.jobs = jobs
        self.results: Dict[int, Tuple[Agent, ShardKey, int]] = {}
        self.state = "streaming"
        self.overlap_commits = 0
        self.chain_reset_seen = False
        self.rehydrated = False
        self.held = False
        self.t0 = engine.ctl.clock.now()
        self._unsub = engine.ctl.bus.subscribe(
            self._on_event, events=(E.COMMIT_DONE, E.DELTA_CHAIN_RESET))

    def _on_event(self, ev: E.Event) -> None:
        p = ev.payload
        if p.get("app") != self.app_id:
            return
        if ev.name == E.COMMIT_DONE:
            self.overlap_commits += 1
        elif ev.name == E.DELTA_CHAIN_RESET \
                and p.get("region") == self.region.name:
            # a demotion/failure reset raced the window: the tail frames no
            # longer extend the streamed base — cutover must re-hydrate
            self.chain_reset_seen = True

    def ready(self) -> bool:
        """Phase 1 landed (all background assembles resolved — possibly
        with an error, which cutover will surface as a funnel fallback)."""
        if self.state == "streaming" and all(f.done()
                                             for _, _, _, f, _ in self.jobs):
            self.state = "ready"
        return self.state != "streaming"

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for _, _, _, fut, _ in self.jobs:
            remaining = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            try:
                fut.exception(timeout=remaining)
            except _FutureTimeout:
                return False
        return self.ready()

    def close(self) -> None:
        """Drop the bus subscription and the chain hold (idempotent)."""
        self._unsub()
        if self.held:
            self.held = False
            self.engine.ctl.catalog.release_chain(self.app_id,
                                                  self.region.name)


class ResizePlanner:
    def __init__(self, ctl):
        self.ctl = ctl
        # (app_id, region_name, new_parts) -> [Move]
        self.plans: Dict[Tuple[AppId, str, int], List[planlib.Move]] = {}
        # (app_id, region_name, new_parts) -> {dst: TransferProgram} | None
        # (None = layout the peer path cannot express; client funnel only)
        self.programs: Dict[Tuple[AppId, str, int],
                            Optional[Dict[int, planlib.TransferProgram]]] = {}
        # forewarnings already staged, keyed (app, region|"", new_parts): a
        # RM that re-announces the same impending resize (periodic
        # heartbeat-style plugins do) must not re-publish RESIZE_FOREWARNED
        # — every publish marks the app's commit-cost estimate stale in
        # telemetry, so duplicates would keep resetting the adaptive loop
        self._forewarned: set = set()
        self.engine = PeerRedistributionEngine(ctl)

    def plan_for_resize(self, app_id: AppId, region_name: str,
                        new_parts: int) -> List[planlib.Move]:
        ctl = self.ctl
        key = (app_id, region_name, new_parts)
        while True:
            with ctl._lock:
                if key in self.plans:
                    return self.plans[key]
                region = ctl._regions[app_id][region_name]
            old = region.partition
            new = old.renumbered(new_parts)
            n = region.shape[old.axis] if old.scheme.value != "replicated" \
                else 1
            moves = planlib.redistribution_moves(n, old, new) \
                if old.scheme.value != "replicated" else []
            # the plan was computed outside the lock: cache it only if the
            # partition did not change mid-compile (a concurrent
            # commit_redistribution + invalidate must never be overwritten
            # by a stale write-back) — otherwise replan against the new one
            with ctl._lock:
                if ctl._regions[app_id][region_name].partition == old:
                    self.plans[key] = moves
                    return moves

    def transfer_programs(self, app_id: AppId, region_name: str,
                          new_parts: int
                          ) -> Optional[Dict[int, planlib.TransferProgram]]:
        """Pre-staged (or compiled on demand) per-destination transfer
        programs; None when the layout needs the client fallback."""
        ctl = self.ctl
        key = (app_id, region_name, new_parts)
        while True:
            with ctl._lock:
                if key in self.programs:
                    return self.programs[key]
                region = ctl._regions[app_id][region_name]
            old = region.partition
            if old.scheme == PartitionScheme.MESH:
                programs = None  # mesh boxes are only known at adapt time
            else:
                programs = planlib.compile_transfer_programs(
                    region.shape[old.axis]
                    if old.scheme.value != "replicated" else 1,
                    old, old.renumbered(new_parts), region.shape)
            # same stale write-back guard as plan_for_resize
            with ctl._lock:
                if ctl._regions[app_id][region_name].partition == old:
                    self.programs[key] = programs
                    return programs

    def invalidate(self, app_id: AppId, region_name: Optional[str] = None
                   ) -> int:
        """Drop cached plans/programs of one region (its partition changed:
        anything computed against the old layout is stale), or of the whole
        app when ``region_name`` is None (app finished — long-lived
        controllers must not accumulate programs across app turnover)."""
        ctl = self.ctl
        with ctl._lock:
            victims = [k for k in self.plans if k[0] == app_id
                       and (region_name is None or k[1] == region_name)]
            for k in victims:
                del self.plans[k]
            pvictims = [k for k in self.programs if k[0] == app_id
                        and (region_name is None or k[1] == region_name)]
            for k in pvictims:
                del self.programs[k]
            # staged-forewarning memo entries computed against the old
            # layout are stale too: the next forewarning must re-stage
            self._forewarned -= {k for k in self._forewarned
                                 if k[0] == app_id
                                 and (region_name is None
                                      or k[1] in (region_name, ""))}
        return len(set(victims) | set(pvictims))

    def on_app_info(self, app_id: str, info: dict) -> None:
        """RM forewarning callback: pre-stage plans AND transfer programs
        for every region, so the adapt window only executes."""
        if info.get("event") != "impending_resize":
            return
        ctl = self.ctl
        new_ranks = int(info["new_ranks"])
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None:
                return
            app.pending_resize = new_ranks
            regions = dict(ctl._regions.get(app_id, {}))
            # memoize per (app, region, new_parts) — plus one app-level key
            # so a region-less (all-MESH) app still dedups: a repeated
            # forewarning for an already-staged target is a no-op, not a
            # recompile + re-publish
            keys = {(app_id, name, new_ranks) for name, region
                    in regions.items()
                    if region.partition.scheme != PartitionScheme.MESH}
            keys.add((app_id, "", new_ranks))
            if keys <= self._forewarned:
                return
            self._forewarned |= keys
        planned = staged = 0
        for name, region in regions.items():
            # MESH regions replan against the *new mesh's* boxes, which only
            # the application knows at adapt time (redistribute_mesh)
            if region.partition.scheme == PartitionScheme.MESH:
                continue
            self.plan_for_resize(app_id, name, new_ranks)
            planned += 1
            if self.transfer_programs(app_id, name, new_ranks) is not None:
                staged += 1
        ctl.bus.publish(E.RESIZE_FOREWARNED, app=app_id, new_ranks=new_ranks,
                        plans=planned, programs=staged)


class PeerRedistributionEngine:
    """Executes pre-staged transfer programs agent→agent during the adapt
    window; the client only dispatches and later fetches its local parts."""

    def __init__(self, ctl):
        self.ctl = ctl
        self._gen = itertools.count()

    # ------------------------------------------------------------ execution
    def execute(self, app_id: AppId, region: RegionMeta, ckpt_id: int,
                programs: Dict[int, planlib.TransferProgram]
                ) -> Tuple[Dict[int, Tuple[Agent, ShardKey, int]], dict]:
        """Run one region's programs (stop-the-world).  Returns
        ``({dst_part: (owning_agent, scratch_key, nbytes)}, stats)``; raises
        :class:`ICheckError` (or the underlying connection error) when a
        source is unreachable or an agent dies mid-transfer — the caller
        falls back to the client funnel.
        """
        ctl = self.ctl
        t0 = ctl.clock.now()
        chain, providers, jobs = self._dispatch(app_id, region, ckpt_id,
                                                programs, keep_state=False)
        itemsize = max(1, np.dtype(region.dtype).itemsize)
        results, reads, _ = self._collect(jobs, providers, itemsize,
                                          len(chain))
        stats = self._stats(results, reads)
        # analytic vs actual: the model says max-lane, the sim clock says
        # what the serialized sleeps actually accumulated — their ratio is
        # the skew gauge CI uses to validate the CommitHandle lane model
        wall = ctl.clock.now() - t0
        stats["wall_sim_s"] = wall
        stats["window_skew"] = stats["sim_s"] / wall if wall > 0 else 1.0
        ctl.tracer.record("redistribute_window",
                          trace_id_for(app_id, ckpt_id), "resize/engine",
                          t0=t0, dur_s=stats["sim_s"], region=region.name,
                          new_parts=len(programs),
                          peer_hops=stats.get("peer_hops", 0))
        return results, stats

    # ------------------------------------------------- zero-stall (two-phase)
    def begin_overlap(self, app_id: AppId, region: RegionMeta, ckpt_id: int,
                      programs: Dict[int, planlib.TransferProgram]
                      ) -> OverlapWindow:
        """Open phase 1: stream the base checkpoint to the new partition in
        the background and hold the region's delta chain so commits issued
        during the window keep extending it (instead of cutting a keyframe
        and orphaning the streamed base)."""
        ctl = self.ctl
        chain, providers, jobs = self._dispatch(
            app_id, region, ckpt_id, programs,
            # retained slice codes are only useful when a tail of q8-delta
            # frames can be XOR-replayed onto them at cutover
            keep_state=(region.codec == "q8-delta"))
        window = OverlapWindow(self, app_id, region, ckpt_id, chain,
                               programs, providers, jobs)
        ctl.catalog.hold_chain(app_id, region.name)
        window.held = True
        ctl.bus.publish(E.RESIZE_OVERLAP_STARTED, app=app_id,
                        region=region.name, new_parts=len(programs),
                        ckpt=ckpt_id, chain_len=len(chain))
        return window

    def cutover(self, window: OverlapWindow
                ) -> Tuple[Dict[int, Tuple[Agent, ShardKey, int]], dict,
                           Optional[Dict[int, list]]]:
        """Phase 2: land the background stream, then catch the scratch parts
        up to the catalog head.

        Three head shapes:

        * head extends the base chain (the common case: only delta commits
          happened during the window) → replay just the tail frames onto the
          retained slice states; the stall is the tail, and the returned
          patches let the client splice the changed spans instead of
          re-fetching whole parts;
        * head diverged (chain reset raced the window, codec without a
          replayable tail, or a rollback) → re-hydrate from the head
          checkpoint into fresh scratch (full stream charged to the stall);
        * head == base (no commit landed) → nothing to catch up.

        Returns ``(results, stats, patches)``; patches is None unless the
        tail-replay path ran.  Raises on any failure — the caller publishes
        the fallback and funnels through the client from the head.
        """
        ctl = self.ctl
        if window.state in ("done", "failed", "aborted"):
            raise ICheckError(f"overlap window already {window.state}")
        window.state = "cutover"
        try:
            itemsize = max(1, np.dtype(window.region.dtype).itemsize)
            results, reads, _ = self._collect(window.jobs, window.providers,
                                              itemsize,
                                              len(window.base_chain))
            window.results = results
            overlap_stats = self._stats(results, reads)
            head_meta, head_region = self._head_region(window)
            patches: Optional[Dict[int, list]] = None
            tail_frames = 0
            stall_stats = {"sim_s": 0.0, "bytes_moved": 0, "peer_hops": 0,
                           "cross_reads": 0, "intra_reads": 0,
                           "tier_reads": 0}
            if head_meta is not None and head_meta.ckpt_id != window.base_ckpt:
                head_chain: Tuple[int, ...] = tuple(head_region.chain) \
                    if head_region.codec == "q8-delta" and head_region.chain \
                    else (head_meta.ckpt_id,)
                nbase = len(window.base_chain)
                extends = (head_region.codec == "q8-delta"
                           and not window.chain_reset_seen
                           and len(head_chain) > nbase
                           and head_chain[:nbase] == window.base_chain)
                if extends:
                    tail = head_chain[nbase:]
                    patches, stall_stats = self._replay_tail(window, tail)
                    tail_frames = len(tail)
                else:
                    window.rehydrated = True
                    stall_stats = self._rehydrate(window, head_meta,
                                                  head_region)
                    results = window.results
            stall = stall_stats["sim_s"]
            stats = {
                "sim_s": overlap_stats["sim_s"] + stall,
                "overlap_sim_s": overlap_stats["sim_s"],
                "stall_sim_s": stall,
                "bytes_moved": overlap_stats["bytes_moved"]
                + stall_stats["bytes_moved"],
                "peer_hops": overlap_stats["peer_hops"]
                + stall_stats["peer_hops"],
                "cross_reads": overlap_stats["cross_reads"]
                + stall_stats["cross_reads"],
                "intra_reads": overlap_stats["intra_reads"]
                + stall_stats["intra_reads"],
                "tier_reads": overlap_stats["tier_reads"]
                + stall_stats["tier_reads"],
                "overlap_commits": window.overlap_commits,
                "tail_frames": tail_frames,
                "rehydrated": window.rehydrated,
            }
            wall = ctl.clock.now() - window.t0
            stats["wall_sim_s"] = wall
            stats["window_skew"] = stats["sim_s"] / wall if wall > 0 else 1.0
            ctl.bus.publish(E.CUTOVER_DONE, app=window.app_id,
                            region=window.region.name,
                            new_parts=len(window.programs),
                            stall_sim_s=stall,
                            overlap_sim_s=overlap_stats["sim_s"],
                            overlap_commits=window.overlap_commits,
                            tail_frames=tail_frames,
                            rehydrated=window.rehydrated)
            window.state = "done"
            return results, stats, patches
        except BaseException:
            window.state = "failed"
            raise
        finally:
            window.close()

    def abort(self, window: OverlapWindow) -> None:
        """Tear an overlap window down without switching: drop the bus
        subscription and chain hold, then release every scratch part —
        deferring stragglers still assembling to their completion."""
        window.close()
        if window.state not in ("done", "failed"):
            window.state = "aborted"
        if window.results:
            self.release(window.results)
        landed = set(window.results)
        for dp, agent, out_key, fut, _ in window.jobs:
            if dp in landed:
                continue
            fut.add_done_callback(
                lambda f, a=agent, k=out_key:
                (self._try_drop_state(a, k), self._drop_quiet(a, k),
                 self._clear_source_memos(window.providers)))

    def _head_region(self, window: OverlapWindow):
        """The catalog head's per-checkpoint meta for the window's region
        (``(None, None)`` when nothing restartable holds the region)."""
        found = self.ctl.catalog.latest_restartable(window.app_id)
        if found is None:
            return None, None
        meta, _ = found
        region = meta.regions.get(window.region.name)
        if region is None:
            return None, None
        return meta, region

    def _changed_tail_pairs(self, window: OverlapWindow,
                            tail: Tuple[int, ...]) -> set:
        """(ckpt_id, src_part) pairs whose tail delta frame can actually
        carry changes.  A part untouched by a commit stores a header-only
        delta frame (``Q8_EMPTY_DELTA_NBYTES``), and every shard's size is
        already in the commit manifest — so the cutover can prune the
        replay's slice reads *from metadata alone*, no data-plane cost.
        Unknown shards (e.g. manifests restored without sizes) stay
        conservative: read them."""
        ctl = self.ctl
        srcs = {op.src for prog in window.programs.values()
                for op in prog.ops}
        changed = set()
        try:
            app = ctl.app(window.app_id)
        except Exception:   # noqa: BLE001 - pruning is an optimisation only
            app = None
        for cid in tail:
            meta = app.checkpoints.get(cid) if app is not None else None
            for src in srcs:
                if meta is None:
                    changed.add((cid, src))
                    continue
                info = meta.shards.get(
                    ShardKey(window.app_id, cid, window.region.name, src))
                if info is None or info.nbytes > Q8_EMPTY_DELTA_NBYTES:
                    changed.add((cid, src))
        return changed

    def _replay_tail(self, window: OverlapWindow, tail: Tuple[int, ...]
                     ) -> Tuple[Dict[int, list], dict]:
        """Dispatch one ``replay`` per assembled destination part: the same
        slice ranges as phase 1, sourced only from the ``tail`` delta frames,
        XOR-applied to the retained slice codes and patched into the scratch
        payload in place.  Returns ``(patches, stall_stats)``.

        Frames that cannot contain changes (header-only deltas, detected
        from manifest shard sizes) are pruned before any read happens —
        with localized churn the stall collapses to the few slices that
        actually moved, not one read per (part, frame)."""
        region = window.region
        changed = self._changed_tail_pairs(window, tail)
        providers = self._resolve_sources(window.app_id, region.name, tail,
                                          window.programs, want=changed)
        jobs = []
        for dp in sorted(window.results):
            agent, out_key, _ = window.results[dp]
            prog = window.programs[dp]
            # fetch list must stay index-aligned with the retained slice
            # states from phase 1: pruned frames become empty source tuples
            # (a no-op replay), never removed entries
            fetches = tuple(
                SliceFetch(vlo=op.src_lo, vhi=op.src_hi, dst_lo=op.dst_lo,
                           codec=region.codec, dtype=region.dtype,
                           sources=tuple(providers[(cid, op.src)]
                                         for cid in tail
                                         if (cid, op.src) in changed))
                for op in prog.ops)
            if not any(f.sources for f in fetches):
                continue          # no tail frame touches this part
            spec = ReplaySpec(out_key=out_key, dtype=region.dtype,
                              fetches=fetches)
            jobs.append((dp, agent, out_key, agent.replay(spec), prog))
        itemsize = max(1, np.dtype(region.dtype).itemsize)
        rres, reads, patches = self._collect(jobs, providers, itemsize,
                                             len(tail))
        return patches, self._stats(rres, reads)

    def _rehydrate(self, window: OverlapWindow, head_meta, head_region
                   ) -> dict:
        """The tail does not extend the streamed base (chain reset raced the
        window, non-delta codec, rollback): assemble the head checkpoint
        from scratch — a full stream, all of it charged to the stall — and
        swap it in for the stale base-version scratch."""
        chain, providers, jobs = self._dispatch(
            window.app_id, head_region, head_meta.ckpt_id, window.programs,
            keep_state=False)
        itemsize = max(1, np.dtype(head_region.dtype).itemsize)
        results, reads, _ = self._collect(jobs, providers, itemsize,
                                          len(chain))
        self.release(window.results)
        window.results = results
        return self._stats(results, reads)

    @staticmethod
    def _try_drop_state(agent: Agent, key: ShardKey) -> None:
        try:
            agent.drop_assembly_state(key)
        except Exception:  # noqa: BLE001 - scratch GC must never raise
            pass

    def _dispatch(self, app_id: AppId, region: RegionMeta, ckpt_id: int,
                  programs: Dict[int, planlib.TransferProgram],
                  keep_state: bool,
                  scratch_region: Optional[str] = None):
        """Resolve sources and launch one assemble per destination part.
        Returns ``(chain, providers, jobs)`` with jobs =
        ``[(dp, agent, out_key, future, prog), ...]``."""
        ctl = self.ctl
        agents = ctl.agents_for(app_id)
        if not agents:
            raise ICheckError(f"no live agents for {app_id}")
        chain: Tuple[int, ...] = tuple(region.chain) \
            if region.codec == "q8-delta" and region.chain else (ckpt_id,)
        providers = self._resolve_sources(app_id, region.name, chain,
                                          programs)
        if scratch_region is None:
            scratch_region = f"{region.name}.redist{next(self._gen)}"
        by_node: Dict[str, List[Agent]] = {}
        for a in agents:
            by_node.setdefault(a.node_id, []).append(a)
        jobs = []
        for dp in sorted(programs):
            prog = programs[dp]
            out_key = ShardKey(app_id, ckpt_id, scratch_region, dp)
            fetches = tuple(
                SliceFetch(vlo=op.src_lo, vhi=op.src_hi, dst_lo=op.dst_lo,
                           codec=region.codec, dtype=region.dtype,
                           sources=tuple(providers[(cid, op.src)]
                                         for cid in chain))
                for op in prog.ops)
            agent = self._place_destination(dp, prog, chain, providers,
                                            agents, by_node)
            spec = AssembleSpec(out_key=out_key, dtype=region.dtype,
                                nvals=prog.nvals, fetches=fetches,
                                keep_state=keep_state)
            jobs.append((dp, agent, out_key, agent.assemble(spec), prog))
        return chain, providers, jobs

    def _collect(self, jobs, providers, itemsize: int, chain_len: int
                 ) -> Tuple[Dict[int, Tuple[Agent, ShardKey, int]],
                            List[dict], Dict[int, list]]:
        """Await dispatched jobs; on any failure, release what landed and
        defer cleanup of stragglers to their completion, then re-raise.
        The third return element maps dst part → value patches for replay
        jobs (empty for assembles)."""
        ctl = self.ctl
        # wall-clock deadline per job: with scaled real sleeps
        # (time_scale > 0) the simulated transfers take real time, so the
        # timeout must scale with the bytes the program moves (the
        # CommitHandle straggler-deadline pattern); 60 s otherwise
        scale = max(ctl.clock.time_scale, 0.0)
        results: Dict[int, Tuple[Agent, ShardKey, int]] = {}
        reads: List[dict] = []
        patches: Dict[int, list] = {}
        error: Optional[BaseException] = None
        try:
            for dp, agent, out_key, fut, prog in jobs:
                if scale > 0:
                    est_sim = prog.moved_vals * itemsize * chain_len / 1e9
                    wall = est_sim * scale * 4.0 + 10.0
                else:
                    wall = 60.0
                try:
                    res = fut.result(timeout=wall)
                except _FutureTimeout:
                    # on 3.10 this is NOT builtin TimeoutError: convert so
                    # the client's fallback except-tuple always catches it
                    error = error or ICheckError(
                        f"assemble of part {dp} timed out on "
                        f"{agent.agent_id}")
                    continue
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    error = error or e
                    continue
                results[dp] = (agent, out_key, res["nbytes"])
                reads.extend(res["reads"])
                if "patches" in res:
                    patches[dp] = res["patches"]
        finally:
            # decoded-payload memos on the source agents are adapt-window
            # scratch too: drop them with the window
            self._clear_source_memos(providers)
        if error is not None:
            self.release(results)
            for dp, agent, out_key, fut, _ in jobs:
                if dp in results:
                    continue
                # a timed-out assemble may still be running on the agent's
                # worker thread and will store its scratch *after* an eager
                # drop (and repopulate source decode memos after the eager
                # clear) — defer both cleanups to the future's completion
                # (runs immediately when the job already failed)
                fut.add_done_callback(
                    lambda f, a=agent, k=out_key:
                    (self._drop_quiet(a, k),
                     self._clear_source_memos(providers)))
            raise error
        return results, reads, patches

    def release(self, results: Dict[int, Tuple[Agent, ShardKey, int]]) -> None:
        """Drop the scratch redistribution shards (after the adapt window),
        along with any retained assembly state on the owning agents."""
        for agent, key, _ in results.values():
            try:
                agent.drop_assembly_state(key)
            except Exception:  # noqa: BLE001 - scratch GC must never raise
                pass
            self._drop_quiet(agent, key)

    @staticmethod
    def _drop_quiet(agent: Agent, key: ShardKey) -> None:
        try:
            agent.store.drop(key)
        except Exception:  # noqa: BLE001 - scratch GC must never raise
            pass

    @staticmethod
    def _clear_source_memos(providers: dict) -> None:
        for provider, _ in providers.values():
            if isinstance(provider, Agent):
                try:
                    provider.clear_peer_cache()
                except Exception:  # noqa: BLE001 - scratch GC must never raise
                    pass

    # ------------------------------------------------------------ internals
    def _place_destination(self, dp: int, prog: planlib.TransferProgram,
                           chain: Tuple[int, ...], providers: dict,
                           agents: List[Agent],
                           by_node: Dict[str, List[Agent]]) -> Agent:
        """Locality-aware owner for one destination part: the node holding
        most of its source bytes assembles it, so the bulk of the slice
        reads ride the memory bus instead of a NIC.  Ties and tier-resident
        sources fall back to round-robin over the app's agents."""
        node_vals: Dict[str, int] = {}
        head = chain[0]                # keyframe carries the bulk
        for op in prog.ops:
            provider, _ = providers[(head, op.src)]
            if isinstance(provider, Agent):
                node_vals[provider.node_id] = \
                    node_vals.get(provider.node_id, 0) + op.nvals
        best = max(node_vals, key=lambda n: (node_vals[n], n), default=None)
        if best is not None and best in by_node:
            locals_ = by_node[best]
            return locals_[dp % len(locals_)]
        return agents[dp % len(agents)]

    def _resolve_sources(self, app_id: AppId, region: str,
                         chain: Tuple[int, ...],
                         programs: Dict[int, planlib.TransferProgram],
                         want: Optional[set] = None) -> dict:
        """(ckpt_id, src_part) → (provider, key) for every needed source
        frame: a live L1 agent holding a replica, else the PFS, else L3.
        ``want`` (optional) restricts resolution to the given
        (ckpt_id, src_part) pairs — pruned frames never need a provider."""
        ctl = self.ctl
        l3 = getattr(ctl, "l3", None)
        needed = sorted({op.src for prog in programs.values()
                         for op in prog.ops})
        providers = {}
        for part in needed:
            for cid in chain:
                if want is not None and (cid, part) not in want:
                    continue
                pair = next(ctl.catalog.agents_with(app_id, cid, region,
                                                    part), None)
                if pair is not None:
                    providers[(cid, part)] = pair
                    continue
                key = ShardKey(app_id, cid, region, part)
                if ctl.pfs.has_shard(key):
                    providers[(cid, part)] = (ctl.pfs, key)
                elif l3 is not None and l3.has_shard(key):
                    providers[(cid, part)] = (l3, key)
                else:
                    raise ICheckError(
                        f"source shard {app_id}/{cid}/{region}/{part} is "
                        f"unreachable on every tier")
        return providers

    def _stats(self, results: dict, reads: List[dict]) -> dict:
        """Analytic adapt-window timing: per-node serialized-at-full-bw sums
        (== fluid-model concurrent completion), window = busiest lane."""
        ctl = self.ctl
        # fallback bandwidths for a node whose manager vanished mid-window:
        # NodeSpec's own defaults, not re-hardcoded literals
        fallback = NodeSpec(node_id="?")
        lanes: Dict[str, float] = {}
        counts = {"cross": 0, "intra": 0, "tier": 0}
        bytes_moved = 0
        for r in reads:
            counts[r["kind"]] += 1
            bytes_moved += r["bytes"]
            node = r["node"]
            if r["kind"] == "cross":
                mgr = ctl._managers.get(node)
                bw = mgr.nic.bandwidth if mgr else fallback.nic_bandwidth
                lat = mgr.nic.latency if mgr else fallback.nic_latency
                lanes[node] = lanes.get(node, 0.0) + r["bytes"] / bw + lat
            elif r["kind"] == "intra":
                mgr = ctl._managers.get(node)
                bw = mgr.spec.mem_bandwidth if mgr \
                    else fallback.mem_bandwidth
                lanes[f"mem-{node}"] = lanes.get(f"mem-{node}", 0.0) \
                    + r["bytes"] / bw
            else:                         # shared tier (PFS/L3 object store)
                bw = ctl.pfs.ingest.bandwidth if node == ctl.pfs.name else \
                    getattr(getattr(ctl, "l3", None), "link",
                            ctl.pfs.ingest).bandwidth
                lanes[node] = lanes.get(node, 0.0) + r["bytes"] / bw
        # assembled parts are written into the owning node's memory
        for agent, _, nbytes in results.values():
            mgr = ctl._managers.get(agent.node_id)
            bw = mgr.spec.mem_bandwidth if mgr else fallback.mem_bandwidth
            lanes[f"mem-{agent.node_id}"] = \
                lanes.get(f"mem-{agent.node_id}", 0.0) + nbytes / bw
        return {
            "sim_s": max(lanes.values(), default=0.0),
            "bytes_moved": bytes_moved,
            "peer_hops": counts["cross"] + counts["intra"],
            "cross_reads": counts["cross"],
            "intra_reads": counts["intra"],
            "tier_reads": counts["tier"],
        }
