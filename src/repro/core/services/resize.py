"""Resize forewarning → pre-staged plans, and the peer redistribution engine.

Paper §III-A interaction 4: the RM "informs the controller about an
impending resource change of an application so that agents can prepare ...
ahead of time".  Two artifacts are pre-staged per (app, region, new_parts):

  * the *move list* (``plan_for_resize``) — what the legacy client funnel
    and the benchmarks consume;
  * the *transfer programs* (``transfer_programs``) — per-destination-part
    slice reads the agents execute peer-to-peer during the adapt window
    (arXiv:2509.05248 style), so the window only executes, never plans.

Both caches are invalidated when a region's partition changes
(``commit_redistribution`` → ``register_region``): a plan computed against
the old layout must never be reused for the new one.

:class:`PeerRedistributionEngine` (owned by the planner) executes the
programs: it resolves every source shard (live L1 agent, else PFS, else L3),
dispatches one ``assemble`` op per destination part to that part's owning
agent, waits, and reports analytic adapt-window timing — per-node
serialized-at-full-bandwidth sums, exactly the model ``CommitHandle`` uses
for concurrent puts, so concurrency across agent pairs shows up as
wall-clock it actually saves.
"""
from __future__ import annotations

import itertools
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import events as E
from .. import plan as planlib
from ..agent import Agent, AssembleSpec, SliceFetch
from ..types import (AppId, ICheckError, NodeSpec, PartitionScheme,
                     RegionMeta, ShardKey)


class ResizePlanner:
    def __init__(self, ctl):
        self.ctl = ctl
        # (app_id, region_name, new_parts) -> [Move]
        self.plans: Dict[Tuple[AppId, str, int], List[planlib.Move]] = {}
        # (app_id, region_name, new_parts) -> {dst: TransferProgram} | None
        # (None = layout the peer path cannot express; client funnel only)
        self.programs: Dict[Tuple[AppId, str, int],
                            Optional[Dict[int, planlib.TransferProgram]]] = {}
        self.engine = PeerRedistributionEngine(ctl)

    def plan_for_resize(self, app_id: AppId, region_name: str,
                        new_parts: int) -> List[planlib.Move]:
        ctl = self.ctl
        key = (app_id, region_name, new_parts)
        while True:
            with ctl._lock:
                if key in self.plans:
                    return self.plans[key]
                region = ctl._regions[app_id][region_name]
            old = region.partition
            new = old.renumbered(new_parts)
            n = region.shape[old.axis] if old.scheme.value != "replicated" \
                else 1
            moves = planlib.redistribution_moves(n, old, new) \
                if old.scheme.value != "replicated" else []
            # the plan was computed outside the lock: cache it only if the
            # partition did not change mid-compile (a concurrent
            # commit_redistribution + invalidate must never be overwritten
            # by a stale write-back) — otherwise replan against the new one
            with ctl._lock:
                if ctl._regions[app_id][region_name].partition == old:
                    self.plans[key] = moves
                    return moves

    def transfer_programs(self, app_id: AppId, region_name: str,
                          new_parts: int
                          ) -> Optional[Dict[int, planlib.TransferProgram]]:
        """Pre-staged (or compiled on demand) per-destination transfer
        programs; None when the layout needs the client fallback."""
        ctl = self.ctl
        key = (app_id, region_name, new_parts)
        while True:
            with ctl._lock:
                if key in self.programs:
                    return self.programs[key]
                region = ctl._regions[app_id][region_name]
            old = region.partition
            if old.scheme == PartitionScheme.MESH:
                programs = None  # mesh boxes are only known at adapt time
            else:
                programs = planlib.compile_transfer_programs(
                    region.shape[old.axis]
                    if old.scheme.value != "replicated" else 1,
                    old, old.renumbered(new_parts), region.shape)
            # same stale write-back guard as plan_for_resize
            with ctl._lock:
                if ctl._regions[app_id][region_name].partition == old:
                    self.programs[key] = programs
                    return programs

    def invalidate(self, app_id: AppId, region_name: Optional[str] = None
                   ) -> int:
        """Drop cached plans/programs of one region (its partition changed:
        anything computed against the old layout is stale), or of the whole
        app when ``region_name`` is None (app finished — long-lived
        controllers must not accumulate programs across app turnover)."""
        ctl = self.ctl
        with ctl._lock:
            victims = [k for k in self.plans if k[0] == app_id
                       and (region_name is None or k[1] == region_name)]
            for k in victims:
                del self.plans[k]
            pvictims = [k for k in self.programs if k[0] == app_id
                        and (region_name is None or k[1] == region_name)]
            for k in pvictims:
                del self.programs[k]
        return len(set(victims) | set(pvictims))

    def on_app_info(self, app_id: str, info: dict) -> None:
        """RM forewarning callback: pre-stage plans AND transfer programs
        for every region, so the adapt window only executes."""
        if info.get("event") != "impending_resize":
            return
        ctl = self.ctl
        new_ranks = int(info["new_ranks"])
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None:
                return
            app.pending_resize = new_ranks
            regions = dict(ctl._regions.get(app_id, {}))
        planned = staged = 0
        for name, region in regions.items():
            # MESH regions replan against the *new mesh's* boxes, which only
            # the application knows at adapt time (redistribute_mesh)
            if region.partition.scheme == PartitionScheme.MESH:
                continue
            self.plan_for_resize(app_id, name, new_ranks)
            planned += 1
            if self.transfer_programs(app_id, name, new_ranks) is not None:
                staged += 1
        ctl.bus.publish(E.RESIZE_FOREWARNED, app=app_id, new_ranks=new_ranks,
                        plans=planned, programs=staged)


class PeerRedistributionEngine:
    """Executes pre-staged transfer programs agent→agent during the adapt
    window; the client only dispatches and later fetches its local parts."""

    def __init__(self, ctl):
        self.ctl = ctl
        self._gen = itertools.count()

    # ------------------------------------------------------------ execution
    def execute(self, app_id: AppId, region: RegionMeta, ckpt_id: int,
                programs: Dict[int, planlib.TransferProgram]
                ) -> Tuple[Dict[int, Tuple[Agent, ShardKey, int]], dict]:
        """Run one region's programs.  Returns
        ``({dst_part: (owning_agent, scratch_key, nbytes)}, stats)``; raises
        :class:`ICheckError` (or the underlying connection error) when a
        source is unreachable or an agent dies mid-transfer — the caller
        falls back to the client funnel.
        """
        ctl = self.ctl
        agents = ctl.agents_for(app_id)
        if not agents:
            raise ICheckError(f"no live agents for {app_id}")
        chain: Tuple[int, ...] = tuple(region.chain) \
            if region.codec == "q8-delta" and region.chain else (ckpt_id,)
        providers = self._resolve_sources(app_id, region.name, chain,
                                          programs)
        gen = next(self._gen)
        scratch_region = f"{region.name}.redist{gen}"
        by_node: Dict[str, List[Agent]] = {}
        for a in agents:
            by_node.setdefault(a.node_id, []).append(a)
        jobs = []
        for dp in sorted(programs):
            prog = programs[dp]
            out_key = ShardKey(app_id, ckpt_id, scratch_region, dp)
            fetches = tuple(
                SliceFetch(vlo=op.src_lo, vhi=op.src_hi, dst_lo=op.dst_lo,
                           codec=region.codec, dtype=region.dtype,
                           sources=tuple(providers[(cid, op.src)]
                                         for cid in chain))
                for op in prog.ops)
            agent = self._place_destination(dp, prog, chain, providers,
                                            agents, by_node)
            spec = AssembleSpec(out_key=out_key, dtype=region.dtype,
                                nvals=prog.nvals, fetches=fetches)
            jobs.append((dp, agent, out_key, agent.assemble(spec), prog))

        # wall-clock deadline per job: with scaled real sleeps
        # (time_scale > 0) the simulated transfers take real time, so the
        # timeout must scale with the bytes the program moves (the
        # CommitHandle straggler-deadline pattern); 60 s otherwise
        scale = max(ctl.clock.time_scale, 0.0)
        itemsize = max(1, np.dtype(region.dtype).itemsize)
        results: Dict[int, Tuple[Agent, ShardKey, int]] = {}
        reads: List[dict] = []
        error: Optional[BaseException] = None
        try:
            for dp, agent, out_key, fut, prog in jobs:
                if scale > 0:
                    est_sim = prog.moved_vals * itemsize * len(chain) / 1e9
                    wall = est_sim * scale * 4.0 + 10.0
                else:
                    wall = 60.0
                try:
                    res = fut.result(timeout=wall)
                except _FutureTimeout:
                    # on 3.10 this is NOT builtin TimeoutError: convert so
                    # the client's fallback except-tuple always catches it
                    error = error or ICheckError(
                        f"assemble of part {dp} timed out on "
                        f"{agent.agent_id}")
                    continue
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    error = error or e
                    continue
                results[dp] = (agent, out_key, res["nbytes"])
                reads.extend(res["reads"])
        finally:
            # decoded-payload memos on the source agents are adapt-window
            # scratch too: drop them with the window
            self._clear_source_memos(providers)
        if error is not None:
            self.release(results)
            for dp, agent, out_key, fut, _ in jobs:
                if dp in results:
                    continue
                # a timed-out assemble may still be running on the agent's
                # worker thread and will store its scratch *after* an eager
                # drop (and repopulate source decode memos after the eager
                # clear) — defer both cleanups to the future's completion
                # (runs immediately when the job already failed)
                fut.add_done_callback(
                    lambda f, a=agent, k=out_key:
                    (self._drop_quiet(a, k),
                     self._clear_source_memos(providers)))
            raise error
        return results, self._stats(results, reads)

    def release(self, results: Dict[int, Tuple[Agent, ShardKey, int]]) -> None:
        """Drop the scratch redistribution shards (after the adapt window)."""
        for agent, key, _ in results.values():
            self._drop_quiet(agent, key)

    @staticmethod
    def _drop_quiet(agent: Agent, key: ShardKey) -> None:
        try:
            agent.store.drop(key)
        except Exception:  # noqa: BLE001 - scratch GC must never raise
            pass

    @staticmethod
    def _clear_source_memos(providers: dict) -> None:
        for provider, _ in providers.values():
            if isinstance(provider, Agent):
                try:
                    provider.clear_peer_cache()
                except Exception:  # noqa: BLE001 - scratch GC must never raise
                    pass

    # ------------------------------------------------------------ internals
    def _place_destination(self, dp: int, prog: planlib.TransferProgram,
                           chain: Tuple[int, ...], providers: dict,
                           agents: List[Agent],
                           by_node: Dict[str, List[Agent]]) -> Agent:
        """Locality-aware owner for one destination part: the node holding
        most of its source bytes assembles it, so the bulk of the slice
        reads ride the memory bus instead of a NIC.  Ties and tier-resident
        sources fall back to round-robin over the app's agents."""
        node_vals: Dict[str, int] = {}
        head = chain[0]                # keyframe carries the bulk
        for op in prog.ops:
            provider, _ = providers[(head, op.src)]
            if isinstance(provider, Agent):
                node_vals[provider.node_id] = \
                    node_vals.get(provider.node_id, 0) + op.nvals
        best = max(node_vals, key=lambda n: (node_vals[n], n), default=None)
        if best is not None and best in by_node:
            locals_ = by_node[best]
            return locals_[dp % len(locals_)]
        return agents[dp % len(agents)]

    def _resolve_sources(self, app_id: AppId, region: str,
                         chain: Tuple[int, ...],
                         programs: Dict[int, planlib.TransferProgram]) -> dict:
        """(ckpt_id, src_part) → (provider, key) for every needed source
        frame: a live L1 agent holding a replica, else the PFS, else L3."""
        ctl = self.ctl
        l3 = getattr(ctl, "l3", None)
        needed = sorted({op.src for prog in programs.values()
                         for op in prog.ops})
        providers = {}
        for part in needed:
            for cid in chain:
                pair = next(ctl.catalog.agents_with(app_id, cid, region,
                                                    part), None)
                if pair is not None:
                    providers[(cid, part)] = pair
                    continue
                key = ShardKey(app_id, cid, region, part)
                if ctl.pfs.has_shard(key):
                    providers[(cid, part)] = (ctl.pfs, key)
                elif l3 is not None and l3.has_shard(key):
                    providers[(cid, part)] = (l3, key)
                else:
                    raise ICheckError(
                        f"source shard {app_id}/{cid}/{region}/{part} is "
                        f"unreachable on every tier")
        return providers

    def _stats(self, results: dict, reads: List[dict]) -> dict:
        """Analytic adapt-window timing: per-node serialized-at-full-bw sums
        (== fluid-model concurrent completion), window = busiest lane."""
        ctl = self.ctl
        # fallback bandwidths for a node whose manager vanished mid-window:
        # NodeSpec's own defaults, not re-hardcoded literals
        fallback = NodeSpec(node_id="?")
        lanes: Dict[str, float] = {}
        counts = {"cross": 0, "intra": 0, "tier": 0}
        bytes_moved = 0
        for r in reads:
            counts[r["kind"]] += 1
            bytes_moved += r["bytes"]
            node = r["node"]
            if r["kind"] == "cross":
                mgr = ctl._managers.get(node)
                bw = mgr.nic.bandwidth if mgr else fallback.nic_bandwidth
                lat = mgr.nic.latency if mgr else fallback.nic_latency
                lanes[node] = lanes.get(node, 0.0) + r["bytes"] / bw + lat
            elif r["kind"] == "intra":
                mgr = ctl._managers.get(node)
                bw = mgr.spec.mem_bandwidth if mgr \
                    else fallback.mem_bandwidth
                lanes[f"mem-{node}"] = lanes.get(f"mem-{node}", 0.0) \
                    + r["bytes"] / bw
            else:                         # shared tier (PFS/L3 object store)
                bw = ctl.pfs.ingest.bandwidth if node == ctl.pfs.name else \
                    getattr(getattr(ctl, "l3", None), "link",
                            ctl.pfs.ingest).bandwidth
                lanes[node] = lanes.get(node, 0.0) + r["bytes"] / bw
        # assembled parts are written into the owning node's memory
        for agent, _, nbytes in results.values():
            mgr = ctl._managers.get(agent.node_id)
            bw = mgr.spec.mem_bandwidth if mgr else fallback.mem_bandwidth
            lanes[f"mem-{agent.node_id}"] = \
                lanes.get(f"mem-{agent.node_id}", 0.0) + nbytes / bw
        return {
            "sim_s": max(lanes.values(), default=0.0),
            "bytes_moved": bytes_moved,
            "peer_hops": counts["cross"] + counts["intra"],
            "cross_reads": counts["cross"],
            "intra_reads": counts["intra"],
            "tier_reads": counts["tier"],
        }
