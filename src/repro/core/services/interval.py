"""Adaptive checkpoint-interval control (Young/Daly over live telemetry).

Closes the loop the paper calls adaptive checkpoint management: instead of a
static ``ckpt_interval_s`` chosen at registration, the controller re-solves
each application's optimal checkpoint cadence from the TelemetryService's
estimates of commit cost ``C`` and mean time between failures ``M``
(cf. the malleable-interval determination of arXiv:1711.00270):

  Young (1974):  T = sqrt(2*C*M)
  Daly  (2006):  T = sqrt(2*C*M) * (1 + sqrt(C/(2M))/3 + (C/(2M))/9) - C
                 for C < 2M, else T = M

Solutions are published as :data:`~..events.INTERVAL_CHANGED` events and
written back into the controller's :class:`AppRecord` (so scheduling
policies see the app's true demand).  ``ICheckClient`` and the elastic
trainer subscribe and re-pace their commits mid-run.

Triggers:
  * every completed commit (C estimate moved),
  * every failure event (M estimate moved),
  * every resize-class event — these *force* a re-solve and publish even
    inside the hysteresis band, because the commit cost changes with the
    node set and downstream consumers must hear about it promptly.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional

from .. import events as E
from ..types import AppId
from .telemetry import CLUSTER_FAILURE_EVENTS, RESIZE_EVENTS, TelemetryService


def young_interval(commit_cost_s: float, mtbf_s: float) -> float:
    """Young's first-order optimum: sqrt(2*C*M)."""
    return math.sqrt(2.0 * max(commit_cost_s, 0.0) * max(mtbf_s, 1e-12))


def daly_interval(commit_cost_s: float, mtbf_s: float) -> float:
    """Daly's higher-order refinement of Young's formula.

    For C < 2M:  sqrt(2CM) * (1 + (1/3)sqrt(C/2M) + (1/9)(C/2M)) - C
    otherwise the machine fails faster than it checkpoints: T = M.
    """
    c = max(commit_cost_s, 0.0)
    m = max(mtbf_s, 1e-12)
    if c >= 2.0 * m:
        return m
    x = c / (2.0 * m)
    return young_interval(c, m) * (1.0 + math.sqrt(x) / 3.0 + x / 9.0) - c


class IntervalController:
    """Bus-driven Young/Daly solver publishing ``INTERVAL_CHANGED`` events."""

    def __init__(self, ctl, telemetry: TelemetryService,
                 min_interval_s: float = 1e-3,
                 max_interval_s: float = 86400.0,
                 hysteresis: float = 0.1, use_daly: bool = True):
        self.ctl = ctl
        self.telemetry = telemetry
        self.min_interval_s = float(min_interval_s)
        self.max_interval_s = float(max_interval_s)
        self.hysteresis = float(hysteresis)
        self.use_daly = bool(use_daly)
        self._lock = threading.Lock()
        self._solved: Dict[AppId, float] = {}
        self.resolves = 0
        self.publishes = 0
        self._unsubscribe = ctl.bus.subscribe(
            self._on_event,
            events=(E.COMMIT_DONE, E.APP_RANK_FAILED)
            + CLUSTER_FAILURE_EVENTS + RESIZE_EVENTS)

    def close(self) -> None:
        self._unsubscribe()

    # -------------------------------------------------------------- solving
    def solve(self, commit_cost_s: float, mtbf_s: float) -> float:
        t = daly_interval(commit_cost_s, mtbf_s) if self.use_daly \
            else young_interval(commit_cost_s, mtbf_s)
        return min(max(t, self.min_interval_s), self.max_interval_s)

    def interval_for(self, app_id: AppId) -> Optional[float]:
        """Last solved interval for the app (None before first solve)."""
        with self._lock:
            return self._solved.get(app_id)

    def resolve(self, app_id: AppId, force: bool = False,
                reason: str = "resolve") -> Optional[float]:
        """Re-solve one app's interval; publish if it moved (or ``force``)."""
        cost = self.telemetry.commit_cost_s(app_id)
        if cost is None:
            return None                       # nothing observed yet
        mtbf = self.telemetry.mtbf_s(app_id)
        target = self.solve(cost, mtbf)
        with self._lock:
            self.resolves += 1
        ctl = self.ctl
        with ctl._lock:
            app = ctl._apps.get(app_id)
            if app is None:
                return None
            prev = app.ckpt_interval_s
            changed = abs(target - prev) > self.hysteresis * max(prev, 1e-12)
            if changed or force:
                app.ckpt_interval_s = target
        with self._lock:
            self._solved[app_id] = target
        if changed or force:
            with self._lock:
                self.publishes += 1
            ctl.bus.publish(E.INTERVAL_CHANGED, app=app_id,
                            interval_s=target, prev_interval_s=prev,
                            commit_cost_s=cost, mtbf_s=mtbf, reason=reason)
        return target

    def resolve_all(self, force: bool = False, reason: str = "resolve") -> None:
        for app_id in self.telemetry.app_ids():
            self.resolve(app_id, force=force, reason=reason)

    # --------------------------------------------------------------- events
    def _on_event(self, ev: E.Event) -> None:
        name, p = ev.name, ev.payload
        if name == E.COMMIT_DONE:
            self.resolve(p["app"], reason="commit")
        elif name == E.APP_RANK_FAILED:
            self.resolve(p["app"], reason="failure")
        elif name in CLUSTER_FAILURE_EVENTS:
            self.resolve_all(reason="failure")
        elif name in RESIZE_EVENTS:
            # the node set changed: commit cost C is about to move, so the
            # solution must be re-published even inside the hysteresis band
            app_id = p.get("app")
            if app_id:
                self.resolve(app_id, force=True, reason="resize")
            else:
                self.resolve_all(force=True, reason="resize")
