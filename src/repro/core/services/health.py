"""Failure detection + node lifecycle.

Heartbeat-style monitoring (agent/node death), shard re-replication from
surviving replicas or L2, straggler advice for the client's
first-completion-wins retry, and the RM plugin's node retake / migration
interactions (paper §III-A interactions 2-3).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

from ...obs import trace_id_for
from .. import events as E
from ..agent import Agent, RebuildSpec
from ..manager import Manager
from ..tiers import ec_is_fragment
from ..types import ShardKey


class HealthMonitor:
    def __init__(self, ctl, heartbeat_interval_s: float = 0.05):
        self.ctl = ctl
        self.interval = heartbeat_interval_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="icheck-monitor")

    def start(self) -> None:
        self._thread.start()

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5)

    # ----------------------------------------------------- straggler advice
    def transfer_deadline(self, nbytes: int, agent: Agent,
                          factor: float = 4.0, slack: float = 1e-3) -> float:
        """Sim-seconds after which a put to ``agent`` counts as straggling."""
        rate = max(1.0, agent.observed_rate())
        return factor * (nbytes / rate) + slack

    # ------------------------------------------------------------ monitoring
    def _loop(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.interval)
            try:
                self.check()
            except Exception as e:   # noqa: BLE001 - monitor must never die
                # ...but a silently-wedged monitor means failures go unseen:
                # surface every poll error and dump the flight ring so the
                # wedge is diagnosable from the artifacts
                self._report_error(e)

    def _report_error(self, exc: BaseException) -> None:
        ctl = self.ctl
        try:
            ctl.bus.publish(E.MONITOR_ERROR, error=repr(exc))
            flight = getattr(ctl, "flight", None)
            if flight is not None:
                flight.dump("monitor_error", extra={"error": repr(exc)})
        except Exception:   # noqa: BLE001 - reporting must not kill the loop
            pass

    def check(self) -> None:
        ctl = self.ctl
        dead_nodes = [m.node_id for m in ctl.managers() if not m.alive()]
        for node_id in dead_nodes:
            self.handle_node_failure(node_id)
        # single-agent failures (process died, node fine)
        for mgr in ctl.managers():
            if not mgr.alive():
                continue
            for agent in mgr.agents():
                if ctl.fault.agent_dead(agent.agent_id):
                    self.handle_agent_failure(mgr, agent)

    def handle_agent_failure(self, mgr: Manager, agent: Agent) -> None:
        ctl = self.ctl
        ctl.bus.publish(E.AGENT_FAILED, agent=agent.agent_id)
        mgr.stop_agent(agent.agent_id)
        with ctl._lock:
            apps = [a for a in ctl._apps.values() if agent.agent_id in a.agents]
        for app in apps:
            with ctl._lock:
                app.agents.remove(agent.agent_id)
            if mgr.alive() and len(mgr.agents()) < mgr.spec.max_agents:
                na = mgr.launch_agent(app.app_id)    # node memory survived
                with ctl._lock:
                    app.agents.append(na.agent_id)
                ctl.bus.publish(E.AGENT_REPLACED, old=agent.agent_id,
                                new=na.agent_id)

    def handle_node_failure(self, node_id: str) -> None:
        ctl = self.ctl
        with ctl._lock:
            mgr = ctl._managers.pop(node_id, None)
            if mgr is None:
                return
        ctl.bus.publish(E.NODE_FAILED, node=node_id)
        mgr.close()
        # erasure-coded stripes get a peer *rebuild* (a surviving agent
        # regenerates just the lost fragments from any k survivors); whole
        # shards are re-copied from surviving replicas/L2
        lost: List[ShardKey] = mgr.store.keys()
        stripes: Dict[ShardKey, List[int]] = {}
        for key in lost:
            base = key.base()
            if ec_is_fragment(key.replica) \
                    and ctl.catalog.ec_geometry(base.app_id) is not None:
                stripes.setdefault(base, []).append(key.replica)
                continue
            try:
                payload = ctl.catalog.fetch_shard(base.app_id, base.ckpt_id,
                                                  base.region, base.part)
            except KeyError:
                ctl.catalog.mark_failed(base.app_id, base.ckpt_id)
                continue
            # anti-affinity: never land the recovery copy on a node that
            # already holds a replica of the same shard (that would leave
            # the durability loss permanent while looking repaired)
            d = ctl.placement.recovery_destination(base,
                                                   exclude_nodes=(node_id,))
            if d is not None:
                d.store.put(base, payload)
        for base in sorted(stripes, key=str):
            self.rebuild_stripe(base, stripes[base])
        # replace the node's agents
        with ctl._lock:
            apps = list(ctl._apps.values())
        for app in apps:
            gone = [aid for aid in app.agents if aid.split("/")[0] == node_id]
            if not gone:
                continue
            with ctl._lock:
                for aid in gone:
                    app.agents.remove(aid)
            survivors = [m for m in ctl.managers() if m.alive()]
            if not survivors and ctl.request_more_memory():
                survivors = [m for m in ctl.managers() if m.alive()]
            for _ in gone:
                if survivors:
                    d = min(survivors, key=lambda m: len(m.agents()))
                    na = d.launch_agent(app.app_id)
                    with ctl._lock:
                        app.agents.append(na.agent_id)
        ctl.bus.publish(E.NODE_RECOVERED, node=node_id)

    # --------------------------------------------------- erasure rebuilds
    def rebuild_stripe(self, base: ShardKey, lost_replicas: List[int],
                       timeout: float = 30.0) -> bool:
        """Regenerate the lost fragments of one erasure stripe.

        A healthy agent (hosted away from the surviving siblings' nodes)
        gathers any k fragments over MemBus/NIC, GF-decodes the payload and
        re-hosts the lost fragments; when fewer than k peers survive, the
        agent falls back to the PFS/L3 copy of the full shard.  Returns
        True when the stripe is whole again."""
        ctl = self.ctl
        ec = ctl.catalog.ec_geometry(base.app_id)
        if ec is None:
            return False
        k, m = ec
        want = tuple(sorted(set(lost_replicas)))
        sources = tuple(ctl.catalog.fragments_with(
            base.app_id, base.ckpt_id, base.region, base.part))
        agents = [a for a in ctl.agents_for(base.app_id) if a.alive()]
        if not agents:
            ctl.bus.publish(E.EC_REBUILD_FAILED, app=base.app_id,
                            ckpt=base.ckpt_id, region=base.region,
                            part=base.part, error="no live agents")
            self._fail_if_not_durable(base)
            return False
        holder_nodes = {a.node_id for a, _ in sources}
        clean = [a for a in agents if a.node_id not in holder_nodes]
        host = min(clean or agents, key=lambda a: a.store.used_bytes)
        fallback = [(ctl.pfs, base)]
        l3 = getattr(ctl, "l3", None)
        if l3 is not None:
            fallback.append((l3, base))
        spec = RebuildSpec(base_key=base, k=k, m=m, want=want,
                           sources=sources, fallback=tuple(fallback))
        ctl.bus.publish(E.EC_REBUILD_STARTED, app=base.app_id,
                        ckpt=base.ckpt_id, region=base.region,
                        part=base.part, lost=list(want),
                        survivors=len(sources), host=host.agent_id)
        t0 = ctl.clock.now()
        trace_id = trace_id_for(base.app_id, base.ckpt_id)
        try:
            with ctl.tracer.span("ec_rebuild", trace_id, "health/monitor",
                                 region=base.region, part=base.part,
                                 lost=len(want)):
                res = host.rebuild(spec).result(timeout=timeout)
        except Exception as e:  # noqa: BLE001 - a lost stripe, not a crash
            ctl.bus.publish(E.EC_REBUILD_FAILED, app=base.app_id,
                            ckpt=base.ckpt_id, region=base.region,
                            part=base.part, error=repr(e))
            self._fail_if_not_durable(base)
            return False
        ctl.bus.publish(E.EC_REBUILD_DONE, app=base.app_id,
                        ckpt=base.ckpt_id, region=base.region,
                        part=base.part, source=res["source"],
                        degraded=res["degraded"], bytes=res["nbytes"],
                        host=host.agent_id,
                        sim_s=max(ctl.clock.now() - t0, 0.0))
        return True

    def _fail_if_not_durable(self, base: ShardKey) -> None:
        """An unrecoverable L1 stripe only fails the checkpoint when no
        lower tier holds the shard either."""
        ctl = self.ctl
        l3 = getattr(ctl, "l3", None)
        if ctl.pfs.has_shard(base) or (l3 is not None and l3.has_shard(base)):
            return
        ctl.catalog.mark_failed(base.app_id, base.ckpt_id)

    # ------------------------------------------------ RM plugin interactions
    def on_rm_retake(self, node_id: str) -> None:
        """RM pulls a node: migrate its shards to the remaining nodes, move
        its agents, then let the RM have it (paper §III-A interaction 2)."""
        ctl = self.ctl
        with ctl._lock:
            mgr = ctl._managers.get(node_id)
        if mgr is None:
            return
        ctl.bus.publish(E.NODE_RETAKEN, node=node_id)
        others = [m for m in ctl.managers() if m.node_id != node_id and m.alive()]
        if not others:
            if ctl.request_more_memory():
                others = [m for m in ctl.managers()
                          if m.node_id != node_id and m.alive()]
        # migrate shard bytes
        for key in mgr.store.keys():
            payload = mgr.store.get(key, verify=False)
            dst = min(others, key=lambda m: m.store.used_bytes, default=None)
            if dst is None:
                ctl.bus.publish(E.MIGRATION_LOST_SHARD, key=str(key))
                continue
            dst.store.put(key, payload)
        # relocate agents app-by-app
        with ctl._lock:
            apps = list(ctl._apps.values())
        for app in apps:
            moved = [aid for aid in app.agents if aid.split("/")[0] == node_id]
            for aid in moved:
                mgr.stop_agent(aid)
                with ctl._lock:
                    app.agents.remove(aid)
                if others:
                    dst = min(others, key=lambda m: len(m.agents()))
                    na = dst.launch_agent(app.app_id)
                    with ctl._lock:
                        app.agents.append(na.agent_id)
        mgr.close()
        with ctl._lock:
            ctl._managers.pop(node_id, None)

    def on_rm_migrate(self, src: str, dst: str) -> None:
        """RM-directed migration src → dst (paper §III-A interaction 3):
        shard bytes AND the serving agents move, so L1 restart/redistribution
        keeps working from the destination node."""
        ctl = self.ctl
        with ctl._lock:
            src_mgr = ctl._managers.get(src)
            dst_mgr = ctl._managers.get(dst)
        if src_mgr is None or dst_mgr is None:
            return
        for key in src_mgr.store.keys():
            payload = src_mgr.store.get(key, verify=False)
            dst_mgr.store.put(key, payload)
            src_mgr.store.drop(key)
        with ctl._lock:
            apps = list(ctl._apps.values())
        for app in apps:
            moved = [aid for aid in app.agents if aid.split("/")[0] == src]
            for aid in moved:
                src_mgr.stop_agent(aid)
                with ctl._lock:
                    app.agents.remove(aid)
                na = dst_mgr.launch_agent(app.app_id)
                with ctl._lock:
                    app.agents.append(na.agent_id)
        ctl.bus.publish(E.NODE_MIGRATED, src=src, dst=dst)
