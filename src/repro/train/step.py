"""train_step factory: value_and_grad over the backbone loss, microbatch
gradient accumulation via lax.scan, AdamW update.

The returned function is pure (TrainState, batch) -> (TrainState, metrics)
and is meant to be jit'd/pjit'd by the caller with the shardings from
``train_state_specs`` -- the launcher does that, both for real runs and the
multi-pod dry-run.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.optim import AdamWConfig, adamw_update

from .state import TrainState


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    schedule: Optional[Callable] = None,
                    microbatches: int = 1,
                    impl: Optional[str] = None) -> Callable:
    opt_cfg = opt_cfg or AdamWConfig()

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, impl=impl), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict) -> tuple:
        params = state.params
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                gsum, lsum = carry
                loss, _, grads = compute_grads(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        else:
            loss, metrics, grads = compute_grads(params, batch)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, params, opt_cfg, schedule)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        out = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out

    return train_step
