"""TrainState: params (f32 master) + AdamW state + step counter, with
logical-axis trees and sharding resolution for pjit."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import abstract_params, init_params
from repro.models.params import param_specs
from repro.optim import AdamWConfig, AdamWState, adamw_init, opt_state_axes
from repro.sharding import FSDP_RULES, Rules, get_rules


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def make_train_state(cfg: ModelConfig, key,
                     opt_cfg: Optional[AdamWConfig] = None) -> TrainState:
    opt_cfg = opt_cfg or AdamWConfig()
    params, _ = init_params(cfg, key)
    return TrainState(params=params,
                      opt=adamw_init(params, compress=opt_cfg.compress_grads),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(cfg: ModelConfig,
                         opt_cfg: Optional[AdamWConfig] = None):
    """(ShapeDtypeStruct TrainState, axes TrainState) -- no allocation."""
    opt_cfg = opt_cfg or AdamWConfig()
    shapes, axes = abstract_params(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    state = TrainState(
        params=shapes,
        opt=AdamWState(mu=jax.tree.map(f32, shapes),
                       nu=jax.tree.map(f32, shapes),
                       count=jax.ShapeDtypeStruct((), jnp.int32),
                       err=jax.tree.map(f32, shapes)
                       if opt_cfg.compress_grads else None),
        step=jax.ShapeDtypeStruct((), jnp.int32))
    state_axes = TrainState(
        params=axes,
        opt=opt_state_axes(axes, compress=opt_cfg.compress_grads),
        step=())
    return state, state_axes


def train_state_specs(cfg: ModelConfig, mesh, state_shapes, state_axes,
                      rules: Optional[Rules] = None):
    """PartitionSpec tree for the TrainState.

    Params follow the model's rule set; optimizer moments always resolve
    against FSDP rules (ZeRO-1: sharded over ("pod","data") on the embed
    axis) regardless of the model rules.
    """
    rules = rules or get_rules(cfg.rules)
    p_specs = param_specs(state_axes.params, rules, mesh,
                          state_shapes.params)
    mu_specs = param_specs(state_axes.opt.mu, FSDP_RULES, mesh,
                           state_shapes.opt.mu)
    nu_specs = param_specs(state_axes.opt.nu, FSDP_RULES, mesh,
                           state_shapes.opt.nu)
    err_specs = None
    if state_axes.opt.err is not None:
        err_specs = param_specs(state_axes.opt.err, FSDP_RULES, mesh,
                                state_shapes.opt.err)
    from jax.sharding import PartitionSpec as P
    return TrainState(
        params=p_specs,
        opt=AdamWState(mu=mu_specs, nu=nu_specs, count=P(), err=err_specs),
        step=P())
