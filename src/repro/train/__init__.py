from .elastic import ElasticTrainer
from .state import (TrainState, abstract_train_state, make_train_state,
                    train_state_specs)
from .step import make_train_step

__all__ = ["TrainState", "make_train_state", "abstract_train_state",
           "train_state_specs", "make_train_step", "ElasticTrainer"]
