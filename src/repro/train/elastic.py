"""ElasticTrainer: paper Listing 1 driven over a JAX TrainState.

Control flow is exactly the paper's malleable-app skeleton:

    MPI_Init_adapt            -> MalleableApp.init_adapt
    icheck_init               -> ICheckClient.init
    icheck_add_adapt          -> add_adapt_snapshot (every TrainState leaf +
                                 data-iterator state become iCheck regions)
    icheck_restart            -> restart()  (fresh start if no checkpoint)
    loop:
        MPI_Probe_adapt       -> probe_adapt
        [MPI_Comm_adapt_begin -> adapt_begin
         icheck_redistribute  -> redistribute_mesh per region
         MPI_Comm_adapt_commit-> adapt_commit]
        train_step
        icheck_commit         -> commit (non-blocking, async agents)
        icheck_probe_agents   -> probe_agents

A "rank" is a data-parallel slice of the device mesh.  On resize the
TrainState is *not* gathered: agents move only the slices each new part
needs (plan.mesh_moves), then the state is re-materialized under the new
mesh's shardings.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import (ICheckClient, ICheckCluster, MalleableApp,
                        snapshot_pytree)
from repro.core import events as icheck_events
from repro.core import plan as planlib
from repro.core.snapshot import leaf_names, restore_pytree
from repro.data import SyntheticLMData
from repro.optim import AdamWConfig, warmup_cosine
from repro.sharding import get_rules, use_rules

from .state import TrainState, make_train_state
from .step import make_train_step

DATA_REGION = "data_state"


def default_make_mesh(ranks: int) -> Mesh:
    devs = jax.devices()[:ranks]
    if len(devs) < ranks:                    # 1-device CPU: logical ranks
        devs = jax.devices()
    return Mesh(np.asarray(devs).reshape(len(devs)), ("data",))


class ElasticTrainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 cluster: ICheckCluster, app_id: str = "train",
                 ranks: int = 1, seed: int = 0,
                 opt_cfg: Optional[AdamWConfig] = None,
                 commit_every: int = 10, probe_every: int = 100,
                 global_batch: Optional[int] = None,
                 make_mesh: Callable[[int], Mesh] = default_make_mesh,
                 codec: str = "raw", replication: int = 1,
                 total_steps: int = 1000, adaptive_interval: bool = False,
                 step_sim_s: float = 0.0, overlap_resize: bool = False):
        self.cfg = cfg
        self.shape = shape
        self.app = MalleableApp(app_id, cluster.rm, ranks)
        self.proc_type = self.app.init_adapt()
        self.client = ICheckClient(app_id, cluster.controller, ranks=ranks,
                                   codec=codec, replication=replication)
        self.make_mesh = make_mesh
        self.mesh = make_mesh(ranks)
        self.rules = get_rules(cfg.rules)
        self.commit_every = commit_every
        self.probe_every = probe_every
        self.global_batch = global_batch or shape.global_batch
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.schedule = warmup_cosine(self.opt_cfg.lr, warmup=20,
                                      total=total_steps)
        self.data = SyntheticLMData(cfg, shape, seed=seed)
        self.metrics_log: list = []
        self.resizes = 0
        self._pending_commits: list = []
        # zero-stall resize: on a forewarned/probed resize, open overlap
        # windows per region and keep training while the base checkpoint
        # streams; the adapt window proper shrinks to the cutover
        self.overlap_resize = overlap_resize
        self._adapt_handles: Optional[Dict[str, object]] = None
        self._adapt_ctx: Optional[dict] = None
        self.steps_during_resize = 0
        # adaptive checkpoint pacing: when enabled, commits follow the
        # IntervalController's solved cadence (sim-time based, re-announced
        # via INTERVAL_CHANGED events) instead of the static commit_every
        # step count; step_sim_s is the simulated compute cost per training
        # step, which is what advances the cadence clock in tests/benchmarks
        self.adaptive_interval = adaptive_interval
        self.step_sim_s = float(step_sim_s)
        self._clock = cluster.controller.clock
        if adaptive_interval and self.step_sim_s <= 0 \
                and self._clock.time_scale == 0:
            # nothing would ever advance the cadence clock between commits:
            # the trainer would silently never checkpoint
            raise ValueError(
                "adaptive_interval=True needs step_sim_s > 0 (or a cluster "
                "with time_scale > 0) so sim time advances between steps")
        self._last_commit_t = self._clock.now()
        self.interval_changes = 0
        # checkpoint-service telemetry: observe the controller's event bus
        # instead of polling its audit list (drain completions, forewarnings,
        # codec degradations all land here asynchronously)
        self.ckpt_events: list = []
        self._unsubscribe = cluster.controller.bus.subscribe(
            self._on_ckpt_event,
            events=(icheck_events.CKPT_IN_L1, icheck_events.CKPT_IN_L2,
                    icheck_events.DRAIN_FAILED, icheck_events.CODEC_DEGRADED,
                    icheck_events.RESIZE_FOREWARNED,
                    icheck_events.INTERVAL_CHANGED))

        key = jax.random.key(seed)
        self.state = make_train_state(cfg, key, self.opt_cfg)
        self._shard_state()
        self._jit_step()

        # icheck_init + add_adapt + (maybe) restart -- paper lines 5..9
        est = sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                  for leaf in jax.tree.leaves(self.state))
        self.client.init(ckpt_bytes_estimate=int(est))
        self._register_regions()
        restored = self.restart_if_available()
        self.restarted = restored

    def _on_ckpt_event(self, ev) -> None:
        self.ckpt_events.append(ev.as_record())
        if ev.name == icheck_events.INTERVAL_CHANGED \
                and ev.payload.get("app") == self.client.app_id:
            # the client already re-paced its own ckpt_interval_s; count the
            # announcement so runs can report how often the loop retuned us
            self.interval_changes += 1

    def _commit_due(self, step: int) -> bool:
        if self.adaptive_interval:
            return (self._clock.now() - self._last_commit_t
                    >= self.client.ckpt_interval_s)
        return self.commit_every > 0 and step % self.commit_every == 0

    # ----------------------------------------------------------------- setup
    def _batch_sharding(self):
        return NamedSharding(self.mesh, PartitionSpec("data"))

    def _shard_state(self):
        """(Re)commit the TrainState onto the current mesh (DP-replicated
        params; batch over "data")."""
        rep = NamedSharding(self.mesh, PartitionSpec())
        self.state = jax.tree.map(lambda x: jax.device_put(x, rep),
                                  self.state)

    def _jit_step(self):
        step_fn = make_train_step(self.cfg, self.opt_cfg, self.schedule)

        def run(state, batch):
            with use_rules(self.mesh, self.rules):
                return step_fn(state, batch)

        self._step = jax.jit(run, donate_argnums=0)

    def _register_regions(self):
        snap = snapshot_pytree(self.state, step=int(self.state.step))
        self.client.add_adapt_snapshot(snap)
        self.client.add_adapt(DATA_REGION, (2,), "int64",
                              num_parts=1)

    # ----------------------------------------------------------- checkpoints
    def commit(self, blocking: bool = False):
        """icheck_commit: async snapshot -> agents (paper line 26).

        With a q8 codec the snapshot quantizes on device (q8-delta: XOR
        against the catalog's previous codes) before the D2H copy, and
        ``commit_snapshot`` ships those frames as-is."""
        step = int(self.state.step)
        data_parts = {DATA_REGION: {0: self.data.state_array()}}
        if self.client.codec in ("q8", "q8-delta"):
            snap = snapshot_pytree(self.state, step=step,
                                   codec=self.client.codec,
                                   chain_lookup=self.client.delta_chain_lookup)
            h = self.client.commit_snapshot(snap, extra_parts=data_parts,
                                            blocking=blocking)
        else:
            snap = snapshot_pytree(self.state, step=step)
            self.client.add_adapt_snapshot(snap)   # refresh region boxes
            parts = {name: r.parts for name, r in snap.regions.items()}
            parts.update(data_parts)
            h = self.client.commit(step, parts, blocking=blocking)
        self._pending_commits.append(h)
        self._last_commit_t = self._clock.now()
        return h

    def restart_if_available(self) -> bool:
        """icheck_restart: newest complete checkpoint -> TrainState."""
        found = self.client.restart()
        if found is None:
            return False
        meta, regions, level = found
        data_parts = regions.pop(DATA_REGION)
        self.data.restore(data_parts[0])
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        region_meta = {name: meta.regions[name] for name in regions}
        self.state = restore_pytree(template, regions, region_meta)
        self._shard_state()
        return True

    # ---------------------------------------------------------------- resize
    def _redistribute(self, new_ranks: int):
        """Agent-side slice redistribution onto the new mesh (paper SSIII-B).

        Requires a checkpoint: commit (blocking) first, then pull only the
        slices each new part needs from the agents.
        """
        self.commit(blocking=True)
        new_mesh = self.make_mesh(new_ranks)
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        names = leaf_names(self.state)
        flat, treedef = jax.tree_util.tree_flatten(template)
        rep = NamedSharding(new_mesh, PartitionSpec())
        new_leaves = []
        for name, leaf in zip(names, flat):
            boxes = planlib.mesh_part_bounds(leaf.shape, rep)
            parts = self.client.redistribute_mesh(name, boxes)
            full = np.zeros(leaf.shape, leaf.dtype)
            for idx, arr in parts.items():
                sl = tuple(slice(lo, hi) for lo, hi in boxes[idx])
                full[sl] = arr
            new_leaves.append(jax.device_put(full, rep))
        self.mesh = new_mesh
        self.state = jax.tree_util.tree_unflatten(treedef, new_leaves)

    def _begin_overlap_adapt(self, new_ranks: int) -> None:
        """Phase 1: commit a base checkpoint, then open one overlap window
        per TrainState leaf targeting the new mesh's boxes.  The RM's resize
        event stays pending (``adapt_begin`` re-probes it at cutover), so
        training continues on the old ranks while the streams run."""
        self.commit(blocking=True)
        new_mesh = self.make_mesh(new_ranks)
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        names = leaf_names(self.state)
        flat, treedef = jax.tree_util.tree_flatten(template)
        rep = NamedSharding(new_mesh, PartitionSpec())
        handles: Dict[str, object] = {}
        boxes_by_name: Dict[str, tuple] = {}
        for name, leaf in zip(names, flat):
            boxes = planlib.mesh_part_bounds(leaf.shape, rep)
            boxes_by_name[name] = boxes
            handles[name] = self.client.redistribute_mesh(name, boxes,
                                                          overlap=True)
        self._adapt_handles = handles
        self._adapt_ctx = {"new_ranks": new_ranks, "new_mesh": new_mesh,
                           "boxes": boxes_by_name, "treedef": treedef,
                           "names": names, "flat": flat}

    def _finish_overlap_adapt(self) -> None:
        """Phase 2: quiesce (one last delta commit — the only frames the
        cutover still has to replay), switch partitions, rebuild the
        TrainState on the new mesh from the caught-up parts."""
        ctx = self._adapt_ctx
        window = self.app.adapt_begin()
        self.commit(blocking=True)
        new_mesh = ctx["new_mesh"]
        rep = NamedSharding(new_mesh, PartitionSpec())
        new_leaves = []
        for name, leaf in zip(ctx["names"], ctx["flat"]):
            boxes = ctx["boxes"][name]
            parts = self._adapt_handles[name].cutover()
            full = np.zeros(leaf.shape, leaf.dtype)
            for idx, arr in parts.items():
                sl = tuple(slice(lo, hi) for lo, hi in boxes[idx])
                full[sl] = arr
            new_leaves.append(jax.device_put(full, rep))
        self.mesh = new_mesh
        self.state = jax.tree_util.tree_unflatten(ctx["treedef"], new_leaves)
        self.app.adapt_commit()
        self.client.ranks = window.new_ranks
        self._jit_step()
        self.resizes += 1
        self._adapt_handles = None
        self._adapt_ctx = None

    def maybe_adapt(self) -> bool:
        """MPI_Probe_adapt + adapt window (paper lines 17-23).

        With ``overlap_resize`` the window is two-phase: the first probe
        that sees a resize opens background streams and returns False (no
        adaptation yet — training continues); once every stream is ready
        the next call performs the bounded-stall cutover."""
        if self._adapt_handles is not None:
            if all(h.ready() for h in self._adapt_handles.values()):
                self._finish_overlap_adapt()
                return True
            return False
        ev = self.app.probe_adapt()
        if ev is None:
            return False
        if self.overlap_resize:
            self._begin_overlap_adapt(ev.new_ranks)
            return False
        window = self.app.adapt_begin()
        self._redistribute(window.new_ranks)
        self.app.adapt_commit()
        self.client.ranks = window.new_ranks
        self._jit_step()
        self.resizes += 1
        return True

    # ------------------------------------------------------------------ run
    def run(self, steps: int) -> Dict:
        t0 = time.monotonic()
        for _ in range(steps):
            self.maybe_adapt()
            batch = self.data.next_batch(self.global_batch)
            batch = {k: jax.device_put(v, self._batch_sharding())
                     for k, v in batch.items()}
            self.state, metrics = self._step(self.state, batch)
            step = int(self.state.step)
            if self._adapt_handles is not None:
                # work retained inside the adapt window — the whole point of
                # overlapping: a stop-the-world resize gets zero of these
                self.steps_during_resize += 1
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"])})
            if self.step_sim_s > 0:
                self._clock.sleep(self.step_sim_s)
            if self._commit_due(step):
                self.commit()
            if self.probe_every and step % self.probe_every == 0:
                self.client.probe_agents()
        return {"steps": steps, "wall_s": time.monotonic() - t0,
                "final_loss": self.metrics_log[-1]["loss"],
                "resizes": self.resizes,
                "steps_during_resize": self.steps_during_resize,
                "interval_changes": self.interval_changes,
                "ckpt_interval_s": self.client.ckpt_interval_s}

    def finalize(self):
        if self._adapt_handles is not None:
            # run ended mid-window: release the scratch without switching
            for h in self._adapt_handles.values():
                h.cancel()
            self._adapt_handles = None
            self._adapt_ctx = None
        for h in self._pending_commits:
            if not h.done():
                h.wait(timeout=60)
        self.client.finalize()
        self._unsubscribe()
