"""Training driver (CPU-runnable with tiny/reduced configs; the full-size
configs are exercised by the dry-run).

  python -m repro.launch.train --arch yi-6b --tiny --steps 50 \
      --global-batch 8 --seq-len 64 [--icheck] [--resize-at 30 --ranks 2]

With --icheck, the run is driven by the ElasticTrainer: full paper
Listing 1 control flow (register -> add_adapt -> commit/async -> probe ->
redistribute on resize), backed by an in-process iCheck cluster.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--icheck", action="store_true")
    ap.add_argument("--commit-every", type=int, default=10)
    ap.add_argument("--resize-at", type=int, default=0,
                    help="inject an RM resize event at this step")
    ap.add_argument("--ranks", type=int, default=1)
    ap.add_argument("--new-ranks", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data import SyntheticLMData
    from repro.optim import AdamWConfig, warmup_cosine
    from repro.train import make_train_state, make_train_step

    cfg = get_config(args.arch, tiny=args.tiny)
    shape = ShapeConfig("cli", "train", args.seq_len, args.global_batch)
    opt_cfg = AdamWConfig(lr=args.lr)

    if args.icheck:
        from repro.core import ICheckCluster
        from repro.train import ElasticTrainer

        with ICheckCluster(n_icheck_nodes=2) as cluster:
            trainer = ElasticTrainer(
                cfg, shape, cluster, ranks=args.ranks, seed=args.seed,
                opt_cfg=opt_cfg, commit_every=args.commit_every,
                total_steps=args.steps)
            if args.resize_at:
                first = trainer.run(args.resize_at)
                cluster.rm.schedule_resize("train", args.new_ranks)
                rest = trainer.run(args.steps - args.resize_at)
                print(f"[resize] {args.ranks} -> {args.new_ranks} ranks, "
                      f"resizes={trainer.resizes}")
            else:
                rest = trainer.run(args.steps)
            trainer.finalize()
            for m in trainer.metrics_log[:3] + trainer.metrics_log[-3:]:
                print(f"step {m['step']:5d} loss {m['loss']:.4f}")
            print(f"final loss {rest['final_loss']:.4f} "
                  f"({rest['wall_s']:.1f}s)")
        return

    key = jax.random.key(args.seed)
    state = make_train_state(cfg, key, opt_cfg)
    schedule = warmup_cosine(args.lr, warmup=10, total=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, schedule,
                                      microbatches=args.microbatches),
                      donate_argnums=0)
    data = SyntheticLMData(cfg, shape, seed=args.seed)
    t0 = time.monotonic()
    for i in range(args.steps):
        batch = data.next_batch()
        state, metrics = step_fn(state, batch)
        if i < 3 or i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    dt = time.monotonic() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * shape.global_batch * shape.seq_len / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
