import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture x input shape x mesh) cell
lowers, SPMD-partitions and compiles on the production mesh, and extract
the roofline terms from the compiled artifact.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init) -- hence the module's first two lines.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --arch yi-6b            # all shapes
  python -m repro.launch.dryrun --all                   # all 10 archs
  ... [--multipod] [--microbatches N] [--rules tp|fsdp] [--out artifacts/]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402


def lower_cell(cfg, shape, mesh, *, rules=None, opt_cfg=None,
               microbatches=1, donate=True, extra_tag=""):
    """Lower + compile one cell; returns the artifact dict."""
    import jax
    from repro.launch import specs as S
    from repro.launch.hlo import analyze, roofline_terms
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS
    from repro.models import decode_step, prefill
    from repro.sharding import use_rules
    from repro.train import make_train_step

    rules = rules or S.cell_rules(cfg, shape, mesh)
    if microbatches == 0:          # auto
        microbatches = S.default_microbatches(cfg, shape, mesh)
    in_specs = S.input_specs(cfg, shape, opt_cfg)
    in_sh = S.cell_shardings(cfg, shape, mesh, rules, opt_cfg)

    if shape.kind == "train":
        step = make_train_step(cfg, opt_cfg, microbatches=microbatches)

        def fn(state, batch):
            with mesh, use_rules(mesh, rules):
                return step(state, batch)

        jfn = jax.jit(fn, in_shardings=in_sh,
                      out_shardings=(in_sh[0], None),
                      donate_argnums=(0,) if donate else ())
    elif shape.kind == "prefill":
        def fn(params, batch, cache):
            with mesh, use_rules(mesh, rules):
                return prefill(cfg, params, batch, cache)

        jfn = jax.jit(fn, in_shardings=in_sh,
                      out_shardings=(None, in_sh[2]),
                      donate_argnums=(2,) if donate else ())
    else:
        def fn(params, cache, tokens):
            with mesh, use_rules(mesh, rules):
                return decode_step(cfg, params, cache, tokens)

        jfn = jax.jit(fn, in_shardings=in_sh,
                      out_shardings=(None, in_sh[1]),
                      donate_argnums=(1,) if donate else ())

    t0 = time.monotonic()
    lowered = jfn.lower(*in_specs)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):   # older jax: one dict per device
        xla_cost = xla_cost[0] if xla_cost else {}
    hlo_text = compiled.as_text()
    analysis = analyze(hlo_text)
    if os.environ.get("REPRO_DRYRUN_TOPS"):
        from repro.launch.hlo import top_instructions
        tops = top_instructions(hlo_text, k=10)
        for cat in ("bytes", "collectives", "flops"):
            print(f"  --- top {cat} ---")
            for v, comp, line in tops[cat]:
                print(f"   {v:.3e}  {comp[:36]:36s} {line[:130]}")
    coll = analysis["collectives"]
    n_chips = mesh.devices.size
    terms = roofline_terms(analysis, PEAK_FLOPS, HBM_BW, ICI_BW)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    model_flops_per_chip = model_flops / n_chips
    useful = model_flops_per_chip / terms["flops"] if terms["flops"] else 0.0
    roofline_frac = (model_flops_per_chip / PEAK_FLOPS) / terms["bound_s"] \
        if terms["bound_s"] > 0 else 0.0

    art = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "chips": int(n_chips),
        "tag": extra_tag, "microbatches": microbatches,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "cost": {"flops": terms["flops"], "bytes": terms["bytes"],
                 "xla_flops_body_once": float(xla_cost.get("flops", 0.0)),
                 "xla_bytes_body_once": float(
                     xla_cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": {
            "t_compute": terms["t_compute"],
            "t_memory": terms["t_memory"],
            "t_collective": terms["t_collective"],
            "dominant": terms["dominant"],
            "bound_s": terms["bound_s"],
            "model_flops": model_flops,
            "model_flops_per_chip": model_flops_per_chip,
            "useful_flop_ratio": useful,
            "roofline_fraction": roofline_frac,
        },
        "params": {"total": n_params, "active": n_active},
    }
    return art


def run_cell(arch, shape_name, multipod, microbatches=0, rules_name=None,
             out_dir=None, tag="", kv_quant=False, remat=None):
    import dataclasses

    import jax  # noqa: F401
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.sharding import get_rules

    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if remat:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multipod)
    rules = get_rules(rules_name) if rules_name else None
    art = lower_cell(cfg, shape, mesh, rules=rules,
                     microbatches=microbatches, extra_tag=tag)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        pod = "multipod" if multipod else "pod"
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{pod}"
                          + (f"__{tag}" if tag else "") + ".json")
        with open(fn, "w") as f:
            json.dump(art, f, indent=1)
    return art


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (fit HBM)")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (perf variant H8)")
    ap.add_argument("--remat", default=None,
                    help="override remat policy: full|dots|psum|none")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS, get_config, shapes_for

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)] if not args.shape \
            else [args.shape]
        for shape_name in shapes:
            meshes = [False, True] if args.both_meshes else [args.multipod]
            for mp in meshes:
                label = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                try:
                    art = run_cell(arch, shape_name, mp,
                                   microbatches=args.microbatches,
                                   rules_name=args.rules, out_dir=args.out,
                                   tag=args.tag, kv_quant=args.kv_quant,
                                   remat=args.remat)
                    r = art["roofline"]
                    print(f"[OK] {label}: compile={art['compile_s']}s "
                          f"mem/dev={art['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                          f"dominant={r['dominant']} "
                          f"roofline={r['roofline_fraction']:.3f}",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((label, repr(e)))
                    traceback.print_exc()
                    print(f"[FAIL] {label}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for label, err in failures:
            print(f"  {label}: {err}")
        sys.exit(1)
    print("\nALL CELLS PASS")


if __name__ == "__main__":
    main()
