"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).

  single-pod:  (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e-like hardware model used by the roofline analysis (EXPERIMENTS.md)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
