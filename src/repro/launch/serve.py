"""Serving driver (CPU-runnable with tiny configs).

  python -m repro.launch.serve --arch yi-6b --batch 4 --prompt-len 32 \
      --gen 16 [--icheck]

With --icheck, the filled KV cache / recurrent state is committed to agents
after prefill (beyond-paper: serving-state fault tolerance).
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--icheck", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import ServeEngine, serve_max_len

    cfg = get_config(args.arch, tiny=True)
    params, _ = init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len))
             .astype(np.int32)}
    if cfg.frontend == "frames":
        batch["frames"] = rng.standard_normal(
            (args.batch, cfg.num_frames, cfg.d_model)).astype(np.float32)
    if cfg.frontend == "patches":
        batch["patches"] = rng.standard_normal(
            (args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32)

    engine = ServeEngine(cfg, params,
                         max_len=serve_max_len(cfg, args.prompt_len,
                                               args.gen))
    client = None
    cluster = None
    if args.icheck:
        from repro.core import ICheckCluster, ICheckClient
        cluster = ICheckCluster(n_icheck_nodes=1)
        client = ICheckClient("serve", cluster.controller).init()

    t0 = time.monotonic()
    out = engine.generate(batch, gen_len=args.gen, checkpoint_client=client)
    dt = time.monotonic() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("first sequence:", out[0].tolist())
    if cluster is not None:
        client.finalize()
        cluster.close()


if __name__ == "__main__":
    main()
