from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS, make_production_mesh

__all__ = ["make_production_mesh", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
