"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns the abstract inputs for the cell's step
function (train_step / prefill_step / serve_step) without allocating
anything; ``cell_shardings`` resolves the matching NamedShardings on a
mesh.  This is what both the multi-pod dry-run and the roofline benchmarks
lower.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cache_axes, init_cache
from repro.models.params import _is_axes, param_specs
from repro.optim import AdamWConfig
from repro.sharding import Rules, get_rules, spec as axes_spec
from repro.train import abstract_train_state, train_state_specs


# --------------------------------------------------------------------------
# rules adjustment per cell
# --------------------------------------------------------------------------
def cell_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               base: Optional[Rules] = None) -> Rules:
    rules = base or get_rules(cfg.rules)
    if shape.kind in ("prefill", "decode"):
        model = mesh.shape.get("model", 1)
        if cfg.num_kv_heads % model != 0:
            # kv heads don't divide the model axis: shard the KV cache's
            # sequence axis instead (softmax stats all-reduce over "model")
            rules = rules.with_rule("act_kv_heads", None) \
                         .with_rule("kv_seq", "model")
    return rules


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                         mesh: Mesh) -> int:
    """Baseline gradient-accumulation factor so the per-layer scan carry
    (b_mb x T x d_model residual per layer) fits the v5e HBM budget:
    microbatch down to ~1-2 sequences per device for train_4k."""
    if shape.kind != "train":
        return 1
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev = max(shape.global_batch // data, 1)
    return min(8, per_dev)


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------
def _batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds((batch, seq), jnp.int32),
           "labels": sds((batch, seq), jnp.int32)}
    if cfg.frontend == "frames":
        out["frames"] = sds((batch, cfg.num_frames, cfg.d_model),
                            jnp.float32)
    if cfg.frontend == "patches":
        out["patches"] = sds((batch, cfg.num_patches, cfg.d_model),
                             jnp.float32)
    return out


def _abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                    filled_to: int):
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return dict(cache, idx=jax.ShapeDtypeStruct((), jnp.int32)), filled_to


def _serving_dtype(params_shapes, cfg: ModelConfig):
    """Serving holds weights in the compute dtype (bf16), not f32 masters."""
    dt = jnp.dtype(cfg.dtype)

    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dt)
        return s

    return jax.tree.map(cast, params_shapes)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                opt_cfg: Optional[AdamWConfig] = None) -> Tuple[Any, ...]:
    """Abstract inputs of the cell's step function:

      train:   (TrainState, batch)
      prefill: (params, batch, cache)
      decode:  (params, cache, tokens)
    """
    if shape.kind == "train":
        state, _ = abstract_train_state(cfg, opt_cfg)
        batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
        return state, batch
    from repro.models import abstract_params

    params, _ = abstract_params(cfg)
    params = _serving_dtype(params, cfg)
    if shape.kind == "prefill":
        batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch.pop("labels")
        max_len = shape.seq_len + (cfg.num_patches
                                   if cfg.frontend == "patches" else 0)
        cache, _ = _abstract_cache(cfg, shape.global_batch, max_len, 0)
        return params, batch, cache
    # decode: cache of seq_len tokens, one new token
    cache, _ = _abstract_cache(cfg, shape.global_batch, shape.seq_len,
                               shape.seq_len - 1)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return params, cache, tokens


# --------------------------------------------------------------------------
# shardings
# --------------------------------------------------------------------------
def _tree_shardings(axes_tree, shapes_tree, rules: Rules, mesh: Mesh):
    specs = jax.tree.map(
        lambda ax, sh: axes_spec(ax, rules, mesh, sh.shape),
        axes_tree, shapes_tree, is_leaf=_is_axes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def batch_shardings(batch_specs, mesh: Mesh, rules: Rules):
    def shard_one(sds):
        names = ["batch"] + [None] * (len(sds.shape) - 1)
        s = axes_spec(names, rules, mesh, sds.shape)
        return NamedSharding(mesh, s)

    return jax.tree.map(shard_one, batch_specs)


def cell_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   rules: Optional[Rules] = None,
                   opt_cfg: Optional[AdamWConfig] = None) -> Tuple[Any, ...]:
    """NamedShardings matching ``input_specs`` leaf-for-leaf."""
    from repro.models import abstract_params

    rules = rules or cell_rules(cfg, shape, mesh)
    if shape.kind == "train":
        state, state_axes = abstract_train_state(cfg, opt_cfg)
        sspecs = train_state_specs(cfg, mesh, state, state_axes, rules)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                                is_leaf=lambda x: isinstance(x, PartitionSpec))
        batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
        return state_sh, batch_shardings(batch, mesh, rules)

    params, axes = abstract_params(cfg)
    p_specs = param_specs(axes, rules, mesh, params)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))
    c_axes = cache_axes(cfg)
    if shape.kind == "prefill":
        batch = _batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch.pop("labels")
        max_len = shape.seq_len + (cfg.num_patches
                                   if cfg.frontend == "patches" else 0)
        cache_shapes, _ = _abstract_cache(cfg, shape.global_batch, max_len, 0)
        cache_sh = _tree_shardings(c_axes, cache_shapes, rules, mesh)
        return p_sh, batch_shardings(batch, mesh, rules), cache_sh
    cache_shapes, _ = _abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                      shape.seq_len - 1)
    cache_sh = _tree_shardings(c_axes, cache_shapes, rules, mesh)
    tok_sh = NamedSharding(mesh, axes_spec(
        ["batch", None], rules, mesh, (shape.global_batch, 1)))
    return p_sh, cache_sh, tok_sh
